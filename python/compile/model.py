"""MiniStella: Eagle's prompt embedder (Layer 2, JAX).

The paper embeds prompts with stella_en_1.5B_v5 on a GPU; this repo
substitutes a small deterministic transformer encoder (see DESIGN.md
§Substitutions — the routers only consume the cosine geometry of the
embeddings, which a seeded random-feature encoder over a shared hash
tokenizer preserves).

Architecture (pre-LN transformer encoder):

    token ids [B, S] --embedding + positions--> [B, S, D]
    x K blocks: LN -> multi-head flash attention (Pallas) -> residual
                LN -> GeLU MLP                             -> residual
    masked mean pool over S -> LN -> L2 normalize -> [B, D]

All attention math runs through the Pallas kernel in
``kernels/attention.py`` so the kernel lowers into the exported HLO.

Everything here is build-time only: ``aot.py`` lowers ``embed`` once per
batch-size bucket and the rust runtime executes the HLO.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from . import tokenizer as tok


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """MiniStella hyper-parameters (mirrored in artifacts/manifest.json)."""

    vocab_size: int = tok.VOCAB_SIZE
    seq_len: int = tok.SEQ_LEN
    d_model: int = 256
    n_heads: int = 2  # head_dim = 128: one MXU lane-width per head
    n_layers: int = 4
    d_ff: int = 512
    seed: int = 42

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Deterministic parameter order — the rust runtime reads weights.bin in
# exactly this order (manifest.json records name/shape/offset per tensor).
def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) for every parameter, in canonical order."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_embed", (cfg.vocab_size, cfg.d_model)),
        ("pos_embed", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1.scale", (cfg.d_model,)),
            (p + "ln1.bias", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2.scale", (cfg.d_model,)),
            (p + "ln2.bias", (cfg.d_model,)),
            (p + "w_up", (cfg.d_model, cfg.d_ff)),
            (p + "b_up", (cfg.d_ff,)),
            (p + "w_down", (cfg.d_ff, cfg.d_model)),
            (p + "b_down", (cfg.d_model,)),
        ]
    specs += [("ln_out.scale", (cfg.d_model,)), ("ln_out.bias", (cfg.d_model,))]
    return specs


def init_params(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Seeded Lecun-normal init; deterministic given ``cfg.seed``."""
    key = jax.random.PRNGKey(cfg.seed)
    params: Dict[str, jnp.ndarray] = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".bias", "b_up", "b_down")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * std
            )
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    """Parameters as a flat list in canonical order (AOT argument order)."""
    return [params[name] for name, _ in param_specs(cfg)]


def unflatten_params(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Inverse of :func:`flatten_params`."""
    specs = param_specs(cfg)
    if len(flat) != len(specs):
        raise ValueError(f"expected {len(specs)} tensors, got {len(flat)}")
    return {name: t for (name, _), t in zip(specs, flat)}


def _layer_norm(x, scale, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _block(cfg: ModelConfig, p: Dict[str, jnp.ndarray], prefix: str, x, mask, *, interpret: bool):
    """One pre-LN encoder block; attention runs through the Pallas kernel."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim

    y = _layer_norm(x, p[prefix + "ln1.scale"], p[prefix + "ln1.bias"])
    q = (y @ p[prefix + "wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (y @ p[prefix + "wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (y @ p[prefix + "wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    # Fold batch*heads for the kernel; pad mask broadcast per head.
    bh_mask = jnp.repeat(mask, h, axis=0)  # [B*H, S]
    blk = min(s, attn_kernel.DEFAULT_BLOCK_Q)  # small configs in tests
    o = attn_kernel.attention(
        q.reshape(b * h, s, dh),
        k.reshape(b * h, s, dh),
        v.reshape(b * h, s, dh),
        bh_mask,
        block_q=blk,
        block_k=blk,
        interpret=interpret,
    )
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + o @ p[prefix + "wo"]

    y = _layer_norm(x, p[prefix + "ln2.scale"], p[prefix + "ln2.bias"])
    y = jax.nn.gelu(y @ p[prefix + "w_up"] + p[prefix + "b_up"])
    return x + y @ p[prefix + "w_down"] + p[prefix + "b_down"]


def embed(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens, mask, *, interpret: bool = True):
    """Embed token ids into L2-normalized vectors.

    Args:
      tokens: ``[B, S]`` int32 token ids (0 = padding).
      mask:   ``[B, S]`` float32, 1.0 = real token.

    Returns:
      ``[B, D]`` f32 embeddings with unit L2 norm.
    """
    x = params["tok_embed"][tokens] + params["pos_embed"][None, :, :]
    x = x * mask[:, :, None]  # zero padding rows
    for i in range(cfg.n_layers):
        x = _block(cfg, params, f"layer{i}.", x, mask, interpret=interpret)
    # Masked mean pool. All-pad rows (mask sum 0) map to the zero vector
    # pre-normalization; guard the division.
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(x * mask[:, :, None], axis=1) / denom
    pooled = _layer_norm(pooled, params["ln_out.scale"], params["ln_out.bias"])
    norm = jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-6)
    return pooled / norm


def embed_flat(cfg: ModelConfig, tokens, mask, *flat_params, interpret: bool = True):
    """:func:`embed` with parameters as positional args (the AOT signature)."""
    return embed(cfg, unflatten_params(cfg, list(flat_params)), tokens, mask, interpret=interpret)


def embed_texts(cfg: ModelConfig, params: Dict[str, jnp.ndarray], texts: List[str]):
    """Convenience: tokenize + embed a list of strings (tests / golden gen)."""
    ids, masks = [], []
    for t in texts:
        i, m = tok.tokenize(t, cfg.seq_len, cfg.vocab_size)
        ids.append(i)
        masks.append(m)
    tokens = jnp.asarray(ids, jnp.int32)
    mask = jnp.asarray(masks, jnp.float32)
    return embed(cfg, params, tokens, mask)
