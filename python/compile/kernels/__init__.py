"""Layer-1 Pallas kernels for Eagle's request-path compute.

- :mod:`attention` — fused masked flash attention used by every MiniStella
  encoder block (the embedder is the request-path hot-spot).
- :mod:`similarity` — blocked query x corpus cosine scoring, the vector
  database scan offload.
- :mod:`ref` — pure-jnp oracles for both.

All kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); block shapes are still chosen for the TPU memory
hierarchy — see DESIGN.md §Hardware-Adaptation.
"""

from . import attention, ref, similarity  # noqa: F401
