"""Blocked query x corpus similarity scoring as a Pallas kernel.

This is the vector-database scan that Eagle-Local runs on every request:
score the (L2-normalized) query embedding against a slab of historical
prompt embeddings; the rust coordinator merges per-slab top-k.

The grid tiles the corpus into ``(block_n, D)`` VMEM-resident slabs; each
step computes a ``(Q, block_n)`` score tile as one MXU-shaped matmul with
f32 accumulation. This is the HBM->VMEM schedule a FAISS-style GPU scan
expresses with threadblocks (DESIGN.md §Hardware-Adaptation).

Lowered with ``interpret=True`` for CPU PJRT (see attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256


def _similarity_kernel(q_ref, c_ref, o_ref):
    """One grid step: all queries vs one corpus slab."""
    q = q_ref[...].astype(jnp.float32)  # [Q, D]
    c = c_ref[...].astype(jnp.float32)  # [block_n, D]
    o_ref[...] = (q @ c.T).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def similarity(queries, corpus, *, block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """Score ``queries`` against ``corpus`` by dot product.

    Args:
      queries: ``[Q, D]`` — pre-normalize rows for cosine similarity.
      corpus:  ``[N, D]``; N must be divisible by ``block_n`` (callers pad).

    Returns:
      ``[Q, N]`` f32 score matrix.
    """
    q_n, d = queries.shape
    n, dc = corpus.shape
    if d != dc:
        raise ValueError(f"dim mismatch {d} vs {dc}")
    if n % block_n:
        raise ValueError(f"corpus size {n} not divisible by block_n {block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _similarity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_n, d), lambda i: (0, 0)),  # queries stay resident
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),  # corpus slab
        ],
        out_specs=pl.BlockSpec((q_n, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q_n, n), jnp.float32),
        interpret=interpret,
    )(queries, corpus)


def vmem_bytes(q_n: int, block_n: int, d: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one grid step."""
    return (q_n * d + block_n * d + q_n * block_n) * dtype_bytes
