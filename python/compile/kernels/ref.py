"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between kernel and oracle across a hypothesis-driven sweep
of shapes and dtypes (python/tests/test_attention.py, test_similarity.py).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def attention_ref(q, k, v, kv_mask):
    """Masked scaled-dot-product attention.

    Args:
      q, k, v: ``[BH, S, Dh]`` arrays (batch*heads already folded).
      kv_mask: ``[BH, S]`` with 1.0 for real keys and 0.0 for padding.

    Returns:
      ``[BH, S, Dh]`` attention output in f32.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    dh = q.shape[-1]
    scores = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(dh))
    bias = (1.0 - kv_mask.astype(jnp.float32))[:, None, :] * NEG_INF
    scores = scores + bias
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", probs, v)


def similarity_ref(queries, corpus):
    """Dot-product similarity scores.

    Args:
      queries: ``[Q, D]`` (callers pre-normalize rows for cosine similarity).
      corpus:  ``[N, D]``.

    Returns:
      ``[Q, N]`` score matrix in f32.
    """
    return jnp.matmul(
        queries.astype(jnp.float32), corpus.astype(jnp.float32).T
    )
