"""Fused masked flash attention as a Pallas kernel.

This is the request-path hot-spot of Eagle's embedder: every MiniStella
encoder block calls :func:`attention`. The kernel is a streaming-softmax
(flash) formulation:

- the grid iterates over ``(batch*heads, q-blocks)``; each step holds one
  ``(block_q, Dh)`` query tile plus the full ``(S, Dh)`` key/value strips for
  that batch-head in VMEM (S is the prompt length, 64 by default — the K/V
  strips are small; for longer sequences the inner ``fori_loop`` already
  streams K/V in ``block_k`` chunks, so only the BlockSpec needs re-tiling),
- inside the kernel a ``fori_loop`` walks ``block_k`` key chunks keeping the
  running max ``m``, normalizer ``l`` and un-normalized accumulator — the
  ``S x S`` score matrix never materializes,
- accumulation is f32 regardless of input dtype (MXU-style accumulate).

TPU mapping (DESIGN.md §Hardware-Adaptation): ``block_q`` / ``block_k`` are
sublane-multiples and ``Dh`` is a lane-multiple (128), so each ``q_tile @
k_chunk.T`` maps onto MXU passes; the BlockSpec expresses the HBM->VMEM
schedule a CUDA flash kernel would express with threadblock tiling.

Lowered with ``interpret=True``: CPU PJRT cannot run Mosaic custom-calls;
interpret mode stages the same computation as plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_k: int):
    """One grid step: a (block_bh, block_q, Dh) query tile vs all keys.

    The batch-head tile is processed as one batched einsum per key chunk
    (MXU-friendly on TPU; on CPU-interpret it avoids serializing the grid
    into tiny matmuls — the single biggest §Perf win, 3.7x).
    """
    q = q_ref[...].astype(jnp.float32)  # [block_bh, block_q, dh]
    seq_len = k_ref.shape[1]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    num_k_blocks = seq_len // block_k

    block_bh, block_q = q.shape[0], q.shape[1]
    m0 = jnp.full((block_bh, block_q), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_bh, block_q), dtype=jnp.float32)
    acc0 = jnp.zeros((block_bh, block_q, dh), dtype=jnp.float32)

    def chunk(j, carry):
        m, l, acc = carry
        k = pl.load(
            k_ref, (slice(None), pl.dslice(j * block_k, block_k), slice(None))
        ).astype(jnp.float32)  # [block_bh, block_k, dh]
        v = pl.load(
            v_ref, (slice(None), pl.dslice(j * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        kv_mask = pl.load(
            mask_ref, (slice(None), pl.dslice(j * block_k, block_k))
        )
        s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
        s = s + (1.0 - kv_mask.astype(jnp.float32))[:, None, :] * NEG_INF
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # Rescale previous accumulator by exp(m - m_new) (flash rescaling).
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])  # [block_bh, block_q, block_k]
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bqk,bkd->bqd", p, v)
        return m_new, l_new, acc_new

    if num_k_blocks == 1:
        # static unroll: no while-loop in the lowered HLO (XLA CPU fuses)
        _, l, acc = chunk(0, (m0, l0, acc0))
    else:
        _, l, acc = jax.lax.fori_loop(0, num_k_blocks, chunk, (m0, l0, acc0))
    o_ref[...] = (acc / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "block_bh", "interpret")
)
def attention(
    q,
    k,
    v,
    kv_mask,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    block_bh: int | None = None,
    interpret: bool = True,
):
    """Masked scaled-dot-product attention via Pallas.

    Args:
      q, k, v: ``[BH, S, Dh]`` (batch*heads folded into the leading dim).
      kv_mask: ``[BH, S]``, 1.0 = real key, 0.0 = padding.
      block_q/block_k: VMEM tile sizes; must divide S.
      block_bh: batch-head tile per grid step (must divide BH). Defaults
        to all of BH — the CPU-PJRT profile, where one batched grid step
        lowers to fused einsums. A TPU profile would shrink this (and
        block_q/block_k) until one step's tiles fit VMEM; see
        ``vmem_bytes`` and DESIGN.md §Hardware-Adaptation.

    Returns:
      ``[BH, S, Dh]`` attention output with ``q``'s dtype.
    """
    bh, s, dh = q.shape
    if s % block_q or s % block_k:
        raise ValueError(f"seq len {s} not divisible by blocks {block_q}/{block_k}")
    if block_bh is None:
        block_bh = bh
    if bh % block_bh:
        raise ValueError(f"batch-heads {bh} not divisible by block_bh {block_bh}")
    grid = (bh // block_bh, s // block_q)
    kernel = functools.partial(_attention_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_bh, block_q, dh), lambda i, j: (i, j, 0)),  # q tile
            pl.BlockSpec((block_bh, s, dh), lambda i, j: (i, 0, 0)),  # k strip
            pl.BlockSpec((block_bh, s, dh), lambda i, j: (i, 0, 0)),  # v strip
            pl.BlockSpec((block_bh, s), lambda i, j: (i, 0)),  # mask strip
        ],
        out_specs=pl.BlockSpec((block_bh, block_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, kv_mask)


def vmem_bytes(
    block_q: int,
    block_k: int,
    seq: int,
    dh: int,
    dtype_bytes: int = 4,
    block_bh: int = 1,
) -> int:
    """Estimated VMEM residency of one grid step (inputs + acc + output).

    Used by DESIGN.md §Perf to check the schedule against the ~16 MiB VMEM
    budget of a TPU core without running on hardware. The CPU profile sets
    block_bh = batch*heads (interpret mode has no VMEM); a TPU profile
    shrinks block_bh until this fits.
    """
    q_tile = block_bh * block_q * dh * dtype_bytes
    kv_strip = block_bh * 2 * seq * dh * dtype_bytes
    mask = block_bh * seq * 4
    acc = block_bh * (block_q * dh * 4 + 2 * block_q * 4)
    out = block_bh * block_q * dh * dtype_bytes
    return q_tile + kv_strip + mask + acc + out
