"""Hash tokenizer shared between the python compile path and the rust runtime.

The rust coordinator must produce *bit-identical* token ids for the same text
(rust/src/tokenizer/mod.rs mirrors this file; both sides pin the same golden
vectors in their test suites). The scheme is deliberately model-free:

  1. lowercase the input (ASCII case folding only),
  2. split into maximal runs of ASCII alphanumerics (everything else is a
     separator; non-ASCII bytes are separators too),
  3. map each word to ``1 + FNV1a64(word) % (VOCAB_SIZE - 1)``,
  4. truncate / right-pad with PAD_ID (=0) to ``seq_len``.

FNV-1a (64-bit) is tiny, endian-free and trivially portable, which is what
matters for cross-language parity.
"""

from __future__ import annotations

from typing import List, Tuple

VOCAB_SIZE = 8192
SEQ_LEN = 64
PAD_ID = 0

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def fnv1a64(data: bytes) -> int:
    """64-bit FNV-1a hash of ``data``."""
    h = _FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * _FNV_PRIME) & _MASK64
    return h


def words(text: str) -> List[str]:
    """Lowercased maximal ASCII-alphanumeric runs of ``text``, in order."""
    out: List[str] = []
    cur: List[str] = []
    for ch in text:
        o = ord(ch)
        if 0x41 <= o <= 0x5A:  # A-Z -> a-z
            cur.append(chr(o + 0x20))
        elif 0x61 <= o <= 0x7A or 0x30 <= o <= 0x39:  # a-z 0-9
            cur.append(ch)
        else:
            if cur:
                out.append("".join(cur))
                cur = []
    if cur:
        out.append("".join(cur))
    return out


def word_id(word: str, vocab_size: int = VOCAB_SIZE) -> int:
    """Token id of a single (already lowercased) word."""
    return 1 + fnv1a64(word.encode("utf-8")) % (vocab_size - 1)


def tokenize(
    text: str, seq_len: int = SEQ_LEN, vocab_size: int = VOCAB_SIZE
) -> Tuple[List[int], List[float]]:
    """Tokenize ``text`` into (ids, mask), each of length ``seq_len``.

    ``mask[i]`` is 1.0 for a real token and 0.0 for padding.
    """
    ids = [word_id(w, vocab_size) for w in words(text)][:seq_len]
    mask = [1.0] * len(ids)
    pad = seq_len - len(ids)
    ids.extend([PAD_ID] * pad)
    mask.extend([0.0] * pad)
    return ids, mask
