"""AOT pipeline: lower MiniStella + the similarity scorer to HLO text.

Run once at build time (``make artifacts``); the rust runtime
(rust/src/runtime/) loads the HLO text, compiles it on the PJRT CPU client
and executes it on the request path. Python never serves.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  embed_b{B}.hlo.txt      one per batch-size bucket B in EMBED_BATCH_SIZES;
                          signature (tokens i32[B,S], mask f32[B,S],
                          *weights) -> (f32[B,D],)
  scorer_q{Q}_n{N}.hlo.txt similarity scorer buckets;
                          (queries f32[Q,D], corpus f32[N,D]) -> (f32[Q,N],)
  weights.bin             all parameters, little-endian f32, canonical order
  manifest.json           config + artifact shapes + per-tensor offsets
  golden.json             reference embeddings for rust parity tests
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import tokenizer as tok
from .kernels import similarity as sim_kernel

EMBED_BATCH_SIZES = (1, 2, 4, 8, 16, 32)
SCORER_SHAPES = ((1, 1024), (8, 1024))  # (Q, N) buckets

GOLDEN_TEXTS = (
    "What is the capital of France?",
    "Prove that the sum of two even numbers is even.",
    "def quicksort(arr): implement in python",
    "The quick brown fox jumps over the lazy dog",
    "",
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_embed(cfg: model_lib.ModelConfig, batch: int) -> str:
    """Lower ``embed`` for one batch bucket; weights are runtime parameters.

    Keeping weights as parameters (not baked constants) keeps the HLO text
    small and lets the rust runtime transfer them to device once
    (``PjRtClient::buffer_from_host_literal``) and reuse across calls.
    """
    fn = functools.partial(model_lib.embed_flat, cfg)

    def wrapped(tokens, mask, *flat):
        return (fn(tokens, mask, *flat),)

    tokens_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    mask_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.float32)
    flat_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in model_lib.param_specs(cfg)
    ]
    lowered = jax.jit(wrapped).lower(tokens_spec, mask_spec, *flat_specs)
    return to_hlo_text(lowered)


def lower_scorer(dim: int, q_n: int, n: int) -> str:
    """Lower the Pallas similarity kernel for one (Q, N) bucket."""

    def wrapped(queries, corpus):
        return (sim_kernel.similarity(queries, corpus),)

    q_spec = jax.ShapeDtypeStruct((q_n, dim), jnp.float32)
    c_spec = jax.ShapeDtypeStruct((n, dim), jnp.float32)
    lowered = jax.jit(wrapped).lower(q_spec, c_spec)
    return to_hlo_text(lowered)


def write_weights(cfg, params, path: str):
    """weights.bin: concatenated little-endian f32 in canonical order."""
    offsets = []
    off = 0
    with open(path, "wb") as f:
        for name, shape in model_lib.param_specs(cfg):
            arr = np.asarray(params[name], dtype="<f4")
            if tuple(arr.shape) != tuple(shape):
                raise AssertionError(f"{name}: {arr.shape} != {shape}")
            f.write(arr.tobytes())
            offsets.append(
                {"name": name, "shape": list(shape), "offset_elems": off}
            )
            off += arr.size
    return offsets, off


def build(out_dir: str) -> dict:
    cfg = model_lib.ModelConfig()
    params = model_lib.init_params(cfg)
    os.makedirs(out_dir, exist_ok=True)

    artifacts = []
    for b in EMBED_BATCH_SIZES:
        name = f"embed_b{b}"
        path = os.path.join(out_dir, name + ".hlo.txt")
        text = lower_embed(cfg, b)
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(
            {
                "name": name,
                "kind": "embed",
                "file": os.path.basename(path),
                "batch": b,
                "seq_len": cfg.seq_len,
                "out_dim": cfg.d_model,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for q_n, n in SCORER_SHAPES:
        name = f"scorer_q{q_n}_n{n}"
        path = os.path.join(out_dir, name + ".hlo.txt")
        text = lower_scorer(cfg.d_model, q_n, n)
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(
            {
                "name": name,
                "kind": "scorer",
                "file": os.path.basename(path),
                "queries": q_n,
                "corpus": n,
                "dim": cfg.d_model,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    weights_path = os.path.join(out_dir, "weights.bin")
    offsets, total = write_weights(cfg, params, weights_path)
    with open(weights_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    print(f"wrote {weights_path} ({total} f32, sha256={digest[:16]}...)")

    golden = {
        "texts": list(GOLDEN_TEXTS),
        "embeddings": [
            [float(x) for x in row]
            for row in np.asarray(
                model_lib.embed_texts(cfg, params, list(GOLDEN_TEXTS))
            )
        ],
        "tokens": [
            tok.tokenize(t, cfg.seq_len, cfg.vocab_size)[0]
            for t in GOLDEN_TEXTS
        ],
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)

    manifest = {
        "format_version": 1,
        "model": {
            "vocab_size": cfg.vocab_size,
            "seq_len": cfg.seq_len,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "seed": cfg.seed,
        },
        "embed_batch_sizes": list(EMBED_BATCH_SIZES),
        "scorer_shapes": [list(s) for s in SCORER_SHAPES],
        "artifacts": artifacts,
        "weights": {
            "file": "weights.bin",
            "dtype": "f32_le",
            "total_elems": total,
            "sha256": digest,
            "tensors": offsets,
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out_dir}/manifest.json + golden.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build(args.out_dir)


if __name__ == "__main__":
    main()
