"""Build-time compile path for Eagle (never imported at serving time).

Layer 2 (JAX model) + Layer 1 (Pallas kernels) + the AOT pipeline that
lowers everything to HLO text for the rust runtime.
"""
