"""Pallas attention kernel vs pure-jnp oracle (hypothesis shape sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def rand_mask(key, bh, s, p_keep=0.7):
    m = (jax.random.uniform(jax.random.PRNGKey(key), (bh, s)) < p_keep)
    # Keep at least one real key per row — an all-padding prompt never
    # reaches the kernel (the batcher drops empty requests).
    return m.at[:, 0].set(True).astype(jnp.float32)


def check(bh, s, dh, block_q, block_k, mask_key=3, dtype=jnp.float32, atol=2e-5,
          block_bh=None):
    q, k, v = (rand(i, (bh, s, dh), dtype) for i in range(3))
    mask = rand_mask(mask_key, bh, s)
    out = A.attention(q, k, v, mask, block_q=block_q, block_k=block_k,
                      block_bh=block_bh)
    exp = ref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp), atol=atol, rtol=1e-4
    )


class TestAttentionGolden:
    def test_default_shape(self):
        check(bh=4, s=64, dh=128, block_q=64, block_k=64)

    def test_multi_q_blocks(self):
        check(bh=2, s=128, dh=64, block_q=32, block_k=64)

    def test_bh_tiling_variants(self):
        """block_bh=1 (TPU-style tiling) == block_bh=all (CPU profile)."""
        for bb in (1, 2, 4):
            check(bh=4, s=32, dh=16, block_q=32, block_k=16, block_bh=bb)

    def test_rejects_indivisible_block_bh(self):
        q = rand(0, (3, 16, 8))
        with pytest.raises(ValueError):
            A.attention(q, q, q, jnp.ones((3, 16)), block_q=16, block_k=16,
                        block_bh=2)

    def test_multi_k_blocks(self):
        check(bh=2, s=128, dh=64, block_q=128, block_k=32)

    def test_tiny(self):
        check(bh=1, s=8, dh=8, block_q=8, block_k=8)

    def test_full_mask(self):
        q, k, v = (rand(i, (2, 64, 32)) for i in range(3))
        mask = jnp.ones((2, 64), jnp.float32)
        out = A.attention(q, k, v, mask, block_q=32, block_k=32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.attention_ref(q, k, v, mask)),
            atol=2e-5, rtol=1e-4,
        )

    def test_single_real_key(self):
        """With one unmasked key, output rows equal that key's value row."""
        q, k, v = (rand(i, (1, 16, 16)) for i in range(3))
        mask = jnp.zeros((1, 16), jnp.float32).at[0, 5].set(1.0)
        out = A.attention(q, k, v, mask, block_q=16, block_k=16)
        exp = jnp.broadcast_to(v[0, 5], (16, 16))
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(exp), atol=2e-5, rtol=1e-4)

    def test_rejects_indivisible_blocks(self):
        q = rand(0, (1, 48, 16))
        with pytest.raises(ValueError):
            A.attention(q, q, q, jnp.ones((1, 48)), block_q=32, block_k=32)

    def test_softmax_rows_convex(self):
        """Output rows lie in the convex hull of V rows: |out| <= max |v|."""
        q, k, v = (rand(i, (2, 32, 16)) for i in range(3))
        mask = rand_mask(9, 2, 32)
        out = A.attention(q, k, v, mask, block_q=32, block_k=16)
        assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-5


@settings(max_examples=20, deadline=None)
@given(
    bh=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
    dh=st.sampled_from([8, 16, 32, 64, 128]),
    mask_key=st.integers(0, 1000),
    tile_bh=st.booleans(),
)
def test_attention_matches_ref_sweep(bh, s_blocks, block, dh, mask_key, tile_bh):
    check(bh=bh, s=s_blocks * block, dh=dh, block_q=block, block_k=block,
          mask_key=mask_key, block_bh=1 if tile_bh else None)


@settings(max_examples=8, deadline=None)
@given(mask_key=st.integers(0, 1000))
def test_attention_bf16_inputs(mask_key):
    """bf16 inputs, f32 accumulation: looser tolerance."""
    q, k, v = (rand(i, (2, 32, 32), jnp.bfloat16) for i in range(3))
    mask = rand_mask(mask_key, 2, 32)
    out = A.attention(q, k, v, mask, block_q=16, block_k=16)
    exp = ref.attention_ref(q, k, v, mask)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp), atol=0.05, rtol=0.05
    )


class TestVmemEstimate:
    def test_default_schedule_fits_vmem(self):
        """DESIGN §Perf: one grid step must fit the ~16MiB VMEM budget."""
        b = A.vmem_bytes(A.DEFAULT_BLOCK_Q, A.DEFAULT_BLOCK_K, seq=64, dh=128)
        assert b < 16 * 1024 * 1024

    def test_tpu_profile_block_bh_fits_vmem(self):
        """A TPU profile tiles block_bh=8: still well under budget."""
        b = A.vmem_bytes(A.DEFAULT_BLOCK_Q, A.DEFAULT_BLOCK_K, seq=64,
                         dh=128, block_bh=8)
        assert b < 16 * 1024 * 1024

    def test_monotone_in_block_q(self):
        assert A.vmem_bytes(128, 64, 128, 128) > A.vmem_bytes(64, 64, 128, 128)
