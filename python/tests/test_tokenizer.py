"""Tokenizer unit + property tests.

The golden values here are duplicated verbatim in
rust/src/tokenizer/mod.rs tests — they pin cross-language parity. If you
change one side you must change both.
"""

import string

import pytest
from hypothesis import given, strategies as st

from compile import tokenizer as tok


class TestFnv1a:
    def test_golden_hello(self):
        assert tok.fnv1a64(b"hello") == 11831194018420276491

    def test_empty(self):
        assert tok.fnv1a64(b"") == 0xCBF29CE484222325

    def test_single_byte(self):
        assert tok.fnv1a64(b"a") == ((0xCBF29CE484222325 ^ 0x61) * 0x100000001B3) % (1 << 64)

    @given(st.binary(max_size=64))
    def test_64bit_range(self, data):
        assert 0 <= tok.fnv1a64(data) < (1 << 64)

    @given(st.binary(min_size=1, max_size=32))
    def test_prefix_sensitivity(self, data):
        # Appending a byte changes the hash (FNV-1a mixes every byte).
        assert tok.fnv1a64(data) != tok.fnv1a64(data + b"\x00") or data == b""


class TestWords:
    def test_golden_split(self):
        assert tok.words("a-b_c  D9") == ["a", "b", "c", "d9"]

    def test_case_folding(self):
        assert tok.words("HeLLo WORLD") == ["hello", "world"]

    def test_punctuation_only(self):
        assert tok.words("!!! ... ???") == []

    def test_empty(self):
        assert tok.words("") == []

    def test_unicode_is_separator(self):
        assert tok.words("café bar") == ["caf", "bar"]

    def test_digits_kept(self):
        assert tok.words("gpt4 v2.5") == ["gpt4", "v2", "5"]

    @given(st.text(max_size=200))
    def test_words_are_lower_alnum(self, text):
        for w in tok.words(text):
            assert w
            assert all(c in string.ascii_lowercase + string.digits for c in w)

    @given(st.text(max_size=200))
    def test_idempotent_on_join(self, text):
        ws = tok.words(text)
        assert tok.words(" ".join(ws)) == ws


class TestTokenize:
    def test_golden_ids(self):
        ids, mask = tok.tokenize("Hello, World! 42", 8)
        assert ids == [8181, 5097, 5912, 0, 0, 0, 0, 0]
        assert mask == [1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]

    def test_word_ids_golden(self):
        assert tok.word_id("hello") == 8181
        assert tok.word_id("world") == 5097
        assert tok.word_id("the") == 4062
        assert tok.word_id("42") == 5912

    def test_truncation(self):
        ids, mask = tok.tokenize(" ".join(["w"] * 100), 16)
        assert len(ids) == 16 and len(mask) == 16
        assert all(m == 1.0 for m in mask)

    def test_empty_text(self):
        ids, mask = tok.tokenize("", 8)
        assert ids == [0] * 8
        assert mask == [0.0] * 8

    def test_pad_id_never_collides(self):
        # word ids live in [1, vocab-1]; PAD=0 is reserved.
        for w in ["a", "b", "zzz", "9", "hello"]:
            assert tok.word_id(w) >= 1

    @given(st.text(max_size=300), st.integers(min_value=1, max_value=128))
    def test_shapes_and_mask_consistency(self, text, seq_len):
        ids, mask = tok.tokenize(text, seq_len)
        assert len(ids) == seq_len and len(mask) == seq_len
        for i, m in zip(ids, mask):
            assert (m == 1.0) == (i != tok.PAD_ID)
        # mask is a prefix of ones
        first_pad = mask.index(0.0) if 0.0 in mask else seq_len
        assert all(m == 1.0 for m in mask[:first_pad])
        assert all(m == 0.0 for m in mask[first_pad:])

    @given(st.text(max_size=100))
    def test_deterministic(self, text):
        assert tok.tokenize(text) == tok.tokenize(text)

    @given(st.integers(min_value=2, max_value=1 << 16))
    def test_vocab_bound(self, vocab):
        ids, _ = tok.tokenize("alpha beta gamma delta", 8, vocab)
        assert all(0 <= i < vocab for i in ids)
