"""AOT pipeline tests: lowering, weight serialization, manifest consistency.

These use a tiny config (lowering the full model per test is slow); the real
artifacts are built by ``make artifacts`` and consumed by rust integration
tests, which compare against golden.json.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as m

TINY = m.ModelConfig(vocab_size=128, seq_len=8, d_model=16, n_heads=2,
                     n_layers=1, d_ff=32, seed=3)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_embed_hlo_text(self):
        text = aot.lower_embed(TINY, batch=2)
        assert "ENTRY" in text and "HloModule" in text
        # weights are parameters, not baked constants
        n_params = len(m.param_specs(TINY)) + 2  # + tokens + mask
        assert text.count("parameter(") >= n_params

    def test_scorer_hlo_text(self):
        text = aot.lower_scorer(dim=16, q_n=2, n=512)
        assert "ENTRY" in text
        assert "f32[2,512]" in text

    def test_embed_batch_dim_in_text(self):
        text = aot.lower_embed(TINY, batch=3)
        assert "s32[3,8]" in text  # tokens arg

    def test_no_64bit_proto_serialization(self):
        """We must ship text, not .serialize() protos (xla 0.5.1 id limit)."""
        text = aot.lower_embed(TINY, batch=1)
        assert isinstance(text, str)


class TestWeightsBin:
    def test_roundtrip(self, tmp_path):
        params = m.init_params(TINY)
        path = str(tmp_path / "w.bin")
        offsets, total = aot.write_weights(TINY, params, path)
        raw = np.fromfile(path, dtype="<f4")
        assert raw.size == total
        for rec in offsets:
            arr = np.asarray(params[rec["name"]]).reshape(-1)
            got = raw[rec["offset_elems"]: rec["offset_elems"] + arr.size]
            np.testing.assert_array_equal(got, arr.astype("<f4"))

    def test_offsets_contiguous(self, tmp_path):
        params = m.init_params(TINY)
        offsets, total = aot.write_weights(TINY, params, str(tmp_path / "w.bin"))
        expect = 0
        for rec, (_, shape) in zip(offsets, m.param_specs(TINY)):
            assert rec["offset_elems"] == expect
            expect += int(np.prod(shape))
        assert expect == total

    def test_canonical_order(self, tmp_path):
        params = m.init_params(TINY)
        offsets, _ = aot.write_weights(TINY, params, str(tmp_path / "w.bin"))
        assert [r["name"] for r in offsets] == [n for n, _ in m.param_specs(TINY)]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    """Consistency checks over the real artifacts/ directory."""

    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, manifest):
        for art in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ART_DIR, art["file"])), art

    def test_weights_size_matches(self, manifest):
        w = manifest["weights"]
        size = os.path.getsize(os.path.join(ART_DIR, w["file"]))
        assert size == w["total_elems"] * 4

    def test_manifest_model_matches_default_config(self, manifest):
        cfg = m.ModelConfig()
        assert manifest["model"]["d_model"] == cfg.d_model
        assert manifest["model"]["vocab_size"] == cfg.vocab_size
        assert manifest["model"]["seq_len"] == cfg.seq_len

    def test_golden_embeddings_reproduce(self, manifest):
        """Re-embed golden texts with fresh params: must match golden.json."""
        with open(os.path.join(ART_DIR, "golden.json")) as f:
            golden = json.load(f)
        cfg = m.ModelConfig()
        params = m.init_params(cfg)
        e = np.asarray(m.embed_texts(cfg, params, golden["texts"]))
        np.testing.assert_allclose(
            e, np.asarray(golden["embeddings"]), atol=1e-4, rtol=1e-4
        )

    def test_golden_norms(self):
        """Non-empty texts embed to unit norm; empty text to the zero vector."""
        with open(os.path.join(ART_DIR, "golden.json")) as f:
            golden = json.load(f)
        e = np.asarray(golden["embeddings"])
        for text, row in zip(golden["texts"], e):
            expected = 0.0 if not text.strip() else 1.0
            assert abs(np.linalg.norm(row) - expected) < 1e-4, text
