"""Pallas similarity kernel vs jnp oracle (hypothesis shape sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import similarity as S

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


class TestSimilarityGolden:
    def test_bucket_shapes(self):
        """The AOT buckets: (1,1024) and (8,1024) at D=256."""
        for qn in (1, 8):
            q, c = rand(0, (qn, 256)), rand(1, (1024, 256))
            out = S.similarity(q, c)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref.similarity_ref(q, c)),
                atol=1e-4, rtol=1e-4,
            )

    def test_single_block(self):
        q, c = rand(0, (4, 64)), rand(1, (256, 64))
        out = S.similarity(q, c, block_n=256)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.similarity_ref(q, c)), atol=1e-4
        )

    def test_identity_corpus(self):
        """Normalized query scored against itself scores 1.0."""
        q = rand(0, (1, 128))
        q = q / jnp.linalg.norm(q)
        c = jnp.concatenate([q, rand(1, (255, 128))], axis=0)
        out = S.similarity(q, c, block_n=128)
        assert abs(float(out[0, 0]) - 1.0) < 1e-5

    def test_rejects_dim_mismatch(self):
        with pytest.raises(ValueError):
            S.similarity(rand(0, (1, 32)), rand(1, (256, 64)))

    def test_rejects_indivisible_corpus(self):
        with pytest.raises(ValueError):
            S.similarity(rand(0, (1, 32)), rand(1, (100, 32)), block_n=256)

    def test_vmem_estimate_fits(self):
        assert S.vmem_bytes(8, S.DEFAULT_BLOCK_N, 256) < 16 * 1024 * 1024


@settings(max_examples=25, deadline=None)
@given(
    qn=st.integers(1, 8),
    blocks=st.integers(1, 6),
    block_n=st.sampled_from([16, 64, 128]),
    d=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 10_000),
)
def test_similarity_matches_ref_sweep(qn, blocks, block_n, d, seed):
    q = rand(seed, (qn, d))
    c = rand(seed + 1, (blocks * block_n, d))
    out = S.similarity(q, c, block_n=block_n)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.similarity_ref(q, c)),
        atol=1e-3, rtol=1e-3,
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_similarity_bf16_corpus(seed):
    q = rand(seed, (4, 64), jnp.bfloat16)
    c = rand(seed + 1, (128, 64), jnp.bfloat16)
    out = S.similarity(q, c, block_n=64)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.similarity_ref(q, c)),
        atol=0.5, rtol=0.05,
    )
