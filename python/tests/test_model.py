"""MiniStella model tests: shapes, invariants, determinism, param plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m
from compile import tokenizer as tok

jax.config.update("jax_platform_name", "cpu")

# A small config keeps interpret-mode pallas fast in tests.
SMALL = m.ModelConfig(vocab_size=512, seq_len=16, d_model=32, n_heads=2,
                      n_layers=2, d_ff=64, seed=7)


@pytest.fixture(scope="module")
def small_params():
    return m.init_params(SMALL)


def embed_text(params, texts):
    return np.asarray(m.embed_texts(SMALL, params, texts))


class TestParamSpecs:
    def test_count(self):
        assert len(m.param_specs(SMALL)) == 2 + 12 * SMALL.n_layers + 2

    def test_flatten_roundtrip(self, small_params):
        flat = m.flatten_params(SMALL, small_params)
        back = m.unflatten_params(SMALL, flat)
        assert set(back) == set(small_params)
        for k in small_params:
            np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(small_params[k]))

    def test_unflatten_rejects_wrong_arity(self):
        with pytest.raises(ValueError):
            m.unflatten_params(SMALL, [jnp.zeros((1,))])

    def test_init_deterministic(self):
        a = m.init_params(SMALL)
        b = m.init_params(SMALL)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))

    def test_seed_changes_weights(self):
        other = m.init_params(m.ModelConfig(**{**SMALL.__dict__, "seed": 8}))
        base = m.init_params(SMALL)
        assert any(
            not np.array_equal(np.asarray(base[k]), np.asarray(other[k]))
            for k in base if "embed" in k
        )

    def test_default_config_param_count(self):
        cfg = m.ModelConfig()
        total = sum(int(np.prod(s)) for _, s in m.param_specs(cfg))
        assert total == 4_218_368  # pinned: matches artifacts/weights.bin


class TestEmbed:
    def test_shape_and_norm(self, small_params):
        e = embed_text(small_params, ["hello world", "abc def ghi"])
        assert e.shape == (2, SMALL.d_model)
        np.testing.assert_allclose(np.linalg.norm(e, axis=1), 1.0, atol=1e-5)

    def test_deterministic(self, small_params):
        a = embed_text(small_params, ["the same text"])
        b = embed_text(small_params, ["the same text"])
        np.testing.assert_array_equal(a, b)

    def test_punctuation_invariance(self, small_params):
        """Tokenizer strips punctuation, so embeddings must match exactly."""
        a = embed_text(small_params, ["hello world"])
        b = embed_text(small_params, ["Hello, world!"])
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_padding_invariance(self, small_params):
        """Same tokens, different batch padding context -> same embedding."""
        a = embed_text(small_params, ["alpha beta"])
        b = embed_text(small_params, ["alpha beta", "a much longer string of words here"])
        np.testing.assert_allclose(a[0], b[0], atol=1e-5)

    def test_empty_text_is_finite(self, small_params):
        e = embed_text(small_params, [""])
        assert np.all(np.isfinite(e))

    def test_distinct_texts_distinct_embeddings(self, small_params):
        e = embed_text(small_params, ["solve this integral", "write a poem about cats"])
        assert float(e[0] @ e[1]) < 0.999

    def test_order_sensitivity(self, small_params):
        """Positional embeddings make token order matter."""
        e = embed_text(small_params, ["alpha beta gamma", "gamma beta alpha"])
        assert not np.allclose(e[0], e[1])

    def test_interpret_flag_matches_noninterpret_lowering(self, small_params):
        """interpret=True is required on CPU, but the math is identical."""
        ids, mask = tok.tokenize("hello there", SMALL.seq_len, SMALL.vocab_size)
        tokens = jnp.asarray([ids], jnp.int32)
        maskv = jnp.asarray([mask], jnp.float32)
        a = m.embed(SMALL, small_params, tokens, maskv, interpret=True)
        assert np.all(np.isfinite(np.asarray(a)))

    def test_embed_flat_matches_dict(self, small_params):
        ids, mask = tok.tokenize("flat params path", SMALL.seq_len, SMALL.vocab_size)
        tokens = jnp.asarray([ids], jnp.int32)
        maskv = jnp.asarray([mask], jnp.float32)
        a = m.embed(SMALL, small_params, tokens, maskv)
        flat = m.flatten_params(SMALL, small_params)
        b = m.embed_flat(SMALL, tokens, maskv, *flat)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


class TestGeometry:
    """The property Eagle-Local depends on: shared tokens => similar vectors."""

    def test_domain_clustering(self, small_params):
        math_q = [
            "solve the equation 3x plus 5 equals 20 for x",
            "solve the equation 7x minus 2 equals 12 for x",
        ]
        code_q = ["write a python function to sort a list of numbers"]
        e = embed_text(small_params, math_q + code_q)
        same = float(e[0] @ e[1])
        cross = float(e[0] @ e[2])
        assert same > cross
