//! Quickstart: build a small synthetic RouterBench, fit Eagle, route a few
//! queries under different budgets, give feedback, route again.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Works without artifacts too (falls back to the pure-rust hash embedder
//! with a note — the serving path is the PJRT one).

use eagle::config::EagleParams;
use eagle::coordinator::router::Observation;
use eagle::coordinator::Router;
use eagle::elo::{Comparison, Outcome};
use eagle::eval::harness::{bench_data_params, EmbedderRig, Experiment};
use eagle::routerbench::models::MODELS;
use eagle::routerbench::DATASETS;

fn main() -> anyhow::Result<()> {
    let rig = EmbedderRig::auto(std::path::Path::new("artifacts"));
    println!(
        "embedder: {}",
        if rig.is_pjrt { "MiniStella via PJRT (AOT artifacts)" } else { "hash fallback" }
    );

    // 1. a small benchmark: 7 datasets x 300 prompts, 70/30 split
    println!("generating synthetic RouterBench (300 prompts/dataset)...");
    let exp = Experiment::build(&bench_data_params(42, 300), &rig);

    // 2. fit Eagle on the GSM8K feedback stream (paper defaults P=.5 N=20 K=32)
    let gsm8k = DATASETS.iter().position(|d| *d == "gsm8k").unwrap();
    let mut router = exp.fit_eagle(gsm8k, EagleParams::default(), 1.0);
    println!(
        "fitted eagle on {} pairwise feedback records\n",
        router.feedback_len()
    );

    // 3. global ranking
    println!("global ELO ranking (gsm8k feedback):");
    for (rank, m) in router.global().ranking().iter().take(5).enumerate() {
        println!(
            "  {}. {:<20} {:7.1} elo   (${:.5}/query)",
            rank + 1,
            MODELS[*m].name,
            router.global().ratings()[*m],
            MODELS[*m].expected_cost()
        );
    }

    // 4. route a math query under three budgets
    let query = "Solve this word problem about train speed distance hours: \
                 a train travels 120 miles in 2 hours, what is its speed?";
    let emb = rig.embed_texts(&[query]).remove(0);
    let scores = router.scores(&emb);
    println!("\nrouting: {query:?}");
    for budget in [0.0005, 0.005, 0.05] {
        let choice = exp.policy.select(&scores, budget);
        println!(
            "  budget ${budget:<7}: -> {:<20} (expected ${:.5})",
            MODELS[choice].name,
            MODELS[choice].expected_cost()
        );
    }

    // 5. live feedback: user says mixtral beat gpt-4 on this prompt
    let mixtral = MODELS.iter().position(|m| m.name == "mixtral-8x7b-chat").unwrap();
    router.observe(Observation::single(
        emb.clone(),
        Comparison { a: mixtral, b: 0, outcome: Outcome::WinA },
    ));
    let scores2 = router.scores(&emb);
    let rank_of = |scores: &[f64], m: usize| {
        scores.iter().filter(|&&s| s > scores[m]).count() + 1
    };
    println!("\nafter 1 feedback record (mixtral beat gpt-4 on this prompt):");
    println!(
        "  mixtral rank for this query: {} -> {} (score {:+.2} elo)",
        rank_of(&scores, mixtral),
        rank_of(&scores2, mixtral),
        scores2[mixtral] - scores[mixtral]
    );

    // 6. AUC on the held-out test split
    let auc = exp.eval(&router, gsm8k).auc();
    println!("\ngsm8k test AUC: {auc:.4}");
    Ok(())
}
