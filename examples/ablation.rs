//! Ablation driver (paper Appendix B): the global/local mixing weight P
//! and the local neighbor size N.
//!
//! ```bash
//! cargo run --release --example ablation
//! ```

use eagle::bench::{fmt, print_table};
use eagle::config::EagleParams;
use eagle::eval::harness::{bench_data_params, EmbedderRig, Experiment};
use eagle::routerbench::DATASETS;

fn main() {
    let rig = EmbedderRig::auto(std::path::Path::new("artifacts"));
    let exp = Experiment::build(&bench_data_params(13, 600), &rig);

    // --- Fig 4a: Eagle vs its components ---
    let mut rows = vec![vec!["variant".to_string(), "summed AUC".to_string()]];
    for (name, p) in [("eagle-global (P=1)", 1.0), ("eagle-local (P=0)", 0.0), ("eagle (P=0.5)", 0.5)] {
        let sum: f64 = (0..DATASETS.len())
            .map(|si| {
                let r = exp.fit_eagle(si, EagleParams { p, ..Default::default() }, 1.0);
                exp.eval(&r, si).auc()
            })
            .sum();
        rows.push(vec![name.to_string(), fmt(sum, 4)]);
    }
    print_table("Fig 4a — component ablation", &rows);

    // --- P sweep (finer than the paper's three points) ---
    let mut rows = vec![vec!["P".to_string(), "summed AUC".to_string()]];
    for p in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let sum: f64 = (0..DATASETS.len())
            .map(|si| {
                let r = exp.fit_eagle(si, EagleParams { p, ..Default::default() }, 1.0);
                exp.eval(&r, si).auc()
            })
            .sum();
        rows.push(vec![fmt(p, 2), fmt(sum, 4)]);
    }
    print_table("P sweep", &rows);

    // --- Fig 4b: neighbor size N (local-emphasis per the paper) ---
    let mut rows = vec![vec!["N".to_string(), "summed AUC (eagle-local)".to_string()]];
    for n in [1usize, 5, 10, 20, 40, 80] {
        let sum: f64 = (0..DATASETS.len())
            .map(|si| {
                let r = exp.fit_eagle(
                    si,
                    EagleParams { p: 0.0, n_neighbors: n, ..Default::default() },
                    1.0,
                );
                exp.eval(&r, si).auc()
            })
            .sum();
        rows.push(vec![n.to_string(), fmt(sum, 4)]);
    }
    print_table("Fig 4b — local neighbor size sweep", &rows);
}
