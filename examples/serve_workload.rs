//! End-to-end serving driver (DESIGN.md "end-to-end validation").
//!
//! Boots the full stack — PJRT embedder (AOT MiniStella artifacts), Eagle
//! router pre-fitted on a synthetic RouterBench feedback history, TCP
//! front-end — then drives concurrent client load (routes + feedback) and
//! reports latency percentiles, throughput, batching efficiency, and the
//! realized quality/cost of the routed decisions.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_workload
//! ```
//!
//! Flags: --requests N (default 2000), --clients N (8), --budget X (0.002)

use std::sync::Arc;
use std::time::Instant;

use eagle::config::EagleParams;
use eagle::coordinator::registry::ModelRegistry;
use eagle::embedding::{BatcherOptions, EmbedService};
use eagle::eval::harness::{bench_data_params, EmbedderRig, Experiment};
use eagle::metrics::Metrics;
use eagle::server::client::EagleClient;
use eagle::server::{Server, ServerState};
use eagle::vectordb::ReadIndex;
use eagle::util::{percentile, Rng};

fn arg(name: &str, default: f64) -> f64 {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_requests = arg("--requests", 2000.0) as usize;
    let n_clients = arg("--clients", 8.0) as usize;
    let budget = arg("--budget", 0.002);
    let artifacts = std::path::Path::new("artifacts");

    // --- build the routing state from a synthetic feedback history ---
    println!("building synthetic RouterBench + fitting eagle...");
    let rig = EmbedderRig::auto(artifacts);
    anyhow::ensure!(
        rig.is_pjrt,
        "serve_workload requires AOT artifacts (run `make artifacts`)"
    );
    let exp = Experiment::build(&bench_data_params(7, 400), &rig);
    // one router over the union of all datasets' feedback
    let mut all_obs = Vec::new();
    for si in 0..exp.benchmark.splits.len() {
        all_obs.extend(exp.observations(si, 1.0));
    }
    let mut rng = Rng::new(99);
    rng.shuffle(&mut all_obs);
    let router = eagle::coordinator::router::EagleRouter::fit(
        EagleParams::default(),
        exp.n_models(),
        eagle::vectordb::flat::FlatStore::with_capacity(256, all_obs.len()),
        &all_obs,
    );
    println!(
        "router ready: {} feedback comparisons, {} stored prompts",
        router.feedback_len(),
        router.store().len()
    );

    // --- boot the serving stack ---
    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start(
        artifacts,
        BatcherOptions { batch_window_us: 300, max_batch: 32 },
        metrics.clone(),
    )?;
    let registry = ModelRegistry::routerbench();
    let state =
        Arc::new(ServerState::builder(router, registry, service.handle(), metrics.clone()).build());
    let server = Server::start(state, "127.0.0.1:0", n_clients.max(2))?;
    let addr = server.addr.to_string();
    println!("serving on {addr}; {n_clients} clients x {} requests", n_requests / n_clients);

    // --- workload: route + occasional feedback, measure client-side ---
    let test_prompts: Vec<String> = exp
        .benchmark
        .splits
        .iter()
        .flat_map(|s| s.test.iter().map(|x| x.text.clone()))
        .collect();
    let per_client = n_requests / n_clients;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = addr.clone();
            let prompts = test_prompts.clone();
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let mut client = EagleClient::connect(&addr)?;
                let mut rng = Rng::new(c as u64 + 1);
                let mut lat = Vec::with_capacity(per_client);
                let mut i = 0usize;
                while i < per_client {
                    // alternate single routes with batched slabs of 8 to
                    // exercise the amortized route path
                    if rng.chance(0.5) && per_client - i >= 8 {
                        let slab: Vec<&str> = (0..8)
                            .map(|j| prompts[(c * per_client + i + j) % prompts.len()].as_str())
                            .collect();
                        let t = Instant::now();
                        let ds = client.route_batch(&slab, budget)?;
                        let per = t.elapsed().as_secs_f64() * 1e3 / ds.len() as f64;
                        lat.extend(std::iter::repeat(per).take(ds.len()));
                        i += ds.len();
                        continue;
                    }
                    let prompt = &prompts[(c * per_client + i) % prompts.len()];
                    let t = Instant::now();
                    let d = client.route(prompt, budget)?;
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                    i += 1;
                    // 20% of requests yield a comparison verdict
                    if let Some(other) = d.compare_with {
                        if rng.chance(0.66) {
                            let score = if rng.chance(0.5) { 1.0 } else { 0.0 };
                            client.feedback(prompt, &d.model, &other, score)?;
                        }
                    }
                }
                Ok(lat)
            })
        })
        .collect();

    let mut latencies = Vec::new();
    for h in handles {
        latencies.extend(h.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();

    // --- report ---
    let n = latencies.len();
    println!("\n== serve_workload results ==");
    println!("requests        : {n}");
    println!("wall time       : {wall:.2} s");
    println!("throughput      : {:.0} routes/s", n as f64 / wall);
    println!(
        "client latency  : p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
        percentile(&latencies, 50.0),
        percentile(&latencies, 90.0),
        percentile(&latencies, 99.0)
    );
    println!(
        "embed batching  : {} queries in {} batches (avg {:.2}/batch)",
        metrics.embed_queries.get(),
        metrics.embed_batches.get(),
        metrics.embed_queries.get() as f64 / metrics.embed_batches.get().max(1) as f64
    );
    println!("server metrics  :\n{}", metrics.report());
    println!("ingest          : {}", server.state.ingest_metrics().report());
    let fb = server.state.ingest_metrics().folded_global.get();
    let snap = server.state.snapshots.load();
    println!("feedback folded : {fb} comparisons (online, no retraining)");
    println!(
        "snapshot epoch  : {} ({} records visible to the route path)",
        snap.epoch(),
        snap.history_len()
    );

    server.shutdown();
    Ok(())
}
