//! Online adaptation demo (paper §3.2): stage the feedback stream
//! 70% -> 85% -> 100%, compare Eagle's incremental update against full
//! baseline retraining — both wall-clock and routing quality.
//!
//! Eagle's updates run through the **serving path**: a `RouterWriter`
//! ingests the delta and republishes RCU snapshots, and quality is
//! evaluated against what `SnapshotRing::load` actually serves — the
//! Table-3a incremental-update story measured end to end, not on a
//! detached router object.
//!
//! ```bash
//! cargo run --release --example online_adaptation
//! ```

use std::sync::Arc;

use eagle::baselines::knn::KnnPredictor;
use eagle::baselines::mlp::{MlpOptions, MlpPredictor};
use eagle::baselines::svm::{SvmOptions, SvmPredictor};
use eagle::baselines::QualityPredictor;
use eagle::bench::{fmt, print_table, time_once};
use eagle::config::{EagleParams, EpochParams};
use eagle::coordinator::snapshot::{RouterWriter, SnapshotRing};
use eagle::coordinator::PredictorRouter;
use eagle::eval::harness::{bench_data_params, EmbedderRig, Experiment};
use eagle::routerbench::DATASETS;

fn main() {
    let rig = EmbedderRig::auto(std::path::Path::new("artifacts"));
    let exp = Experiment::build(&bench_data_params(5, 600), &rig);
    let stages = [0.7, 0.85, 1.0];

    let mut time_rows = vec![vec![
        "router".to_string(),
        "70% (init)".to_string(),
        "+15% (update)".to_string(),
        "+15% (update)".to_string(),
    ]];
    let mut auc_rows = vec![vec![
        "router".to_string(),
        "70%".to_string(),
        "85%".to_string(),
        "100%".to_string(),
    ]];

    // --- Eagle: init once, then incremental updates through the RCU
    // serving path (writer ingest -> snapshot publish -> ring scoring) ---
    {
        let cadence = EpochParams { publish_every: 64, publish_interval_ms: 25 };
        let mut times = Vec::new();
        let mut aucs = Vec::new();
        let (mut writers, t_init) = time_once(|| {
            (0..DATASETS.len())
                .map(|si| {
                    RouterWriter::from_router(
                        exp.fit_eagle(si, EagleParams::default(), stages[0]),
                        cadence.clone(),
                    )
                })
                .collect::<Vec<_>>()
        });
        times.push(t_init);
        let rings: Vec<_> = writers.iter().map(|w| w.ring()).collect();
        // evaluate through the published snapshots — the route read path
        let auc_through_rings = |rings: &[Arc<SnapshotRing>]| {
            (0..DATASETS.len()).map(|si| exp.eval(&*rings[si], si).auc()).sum::<f64>()
        };
        aucs.push(auc_through_rings(&rings));
        for w in stages.windows(2) {
            let (_, t) = time_once(|| {
                for (si, writer) in writers.iter_mut().enumerate() {
                    let old = exp.observations(si, w[0]).len();
                    let newer = exp.observations(si, w[1]);
                    for obs in &newer[old..] {
                        writer.observe(obs.clone());
                    }
                    // make the tail of the delta visible to the ring
                    writer.publish();
                }
            });
            times.push(t);
            aucs.push(auc_through_rings(&rings));
        }
        time_rows.push(vec![
            "eagle".into(),
            format!("{:.4}s", times[0]),
            format!("{:.4}s", times[1]),
            format!("{:.4}s", times[2]),
        ]);
        auc_rows.push(vec![
            "eagle".into(),
            fmt(aucs[0], 4),
            fmt(aucs[1], 4),
            fmt(aucs[2], 4),
        ]);
    }

    // --- baselines: full retrain at every stage ---
    run_baseline(&exp, &stages, "knn", &mut time_rows, &mut auc_rows, || {
        Box::new(KnnPredictor::new(40))
    });
    run_baseline(&exp, &stages, "mlp", &mut time_rows, &mut auc_rows, || {
        Box::new(MlpPredictor::new(MlpOptions::default()))
    });
    run_baseline(&exp, &stages, "svm", &mut time_rows, &mut auc_rows, || {
        Box::new(SvmPredictor::new(SvmOptions::default()))
    });

    print_table("adaptation wall-clock (Table 3a protocol)", &time_rows);
    print_table("summed AUC by data stage (Fig 3b protocol)", &auc_rows);
    println!("\nEagle folds new feedback in O(new records) *through the serving path*");
    println!("(writer ingest + snapshot publish; AUC is scored off the ring); baselines");
    println!("re-train on the full accumulated set (sklearn-equivalent online behavior).");
}

#[allow(clippy::type_complexity)]
fn run_baseline(
    exp: &Experiment,
    stages: &[f64],
    name: &str,
    time_rows: &mut Vec<Vec<String>>,
    auc_rows: &mut Vec<Vec<String>>,
    mk: impl Fn() -> Box<dyn QualityPredictor>,
) {
    let mut times = Vec::new();
    let mut aucs = Vec::new();
    let mut preds: Vec<Box<dyn QualityPredictor>> =
        (0..DATASETS.len()).map(|_| mk()).collect();
    let (_, t_init) = time_once(|| {
        for (si, p) in preds.iter_mut().enumerate() {
            p.fit(&exp.train_set_feedback(si, stages[0]));
        }
    });
    times.push(t_init);
    aucs.push(eval_all(exp, &preds));
    for w in stages.windows(2) {
        let (_, t) = time_once(|| {
            for (si, p) in preds.iter_mut().enumerate() {
                let old = exp.train_set_feedback(si, w[0]).len();
                let full = exp.train_set_feedback(si, w[1]);
                p.update(&full.suffix(old));
            }
        });
        times.push(t);
        aucs.push(eval_all(exp, &preds));
    }
    time_rows.push(vec![
        name.into(),
        format!("{:.4}s", times[0]),
        format!("{:.4}s", times[1]),
        format!("{:.4}s", times[2]),
    ]);
    auc_rows.push(vec![name.into(), fmt(aucs[0], 4), fmt(aucs[1], 4), fmt(aucs[2], 4)]);
}

fn eval_all(exp: &Experiment, preds: &[Box<dyn QualityPredictor>]) -> f64 {
    preds
        .iter()
        .enumerate()
        .map(|(si, p)| {
            let r = PredictorRouter::new(ShimPredictor(p.as_ref()));
            exp.eval(&r, si).auc()
        })
        .sum()
}

/// Borrowed-predictor shim so we can evaluate without cloning trainers.
struct ShimPredictor<'a>(&'a dyn QualityPredictor);

impl QualityPredictor for ShimPredictor<'_> {
    fn name(&self) -> &'static str {
        "shim"
    }
    fn fit(&mut self, _d: &eagle::baselines::TrainSet) {}
    fn update(&mut self, _d: &eagle::baselines::TrainSet) {}
    fn predict(&self, q: &[f32]) -> Vec<f64> {
        self.0.predict(q)
    }
}
