//! Kernel-subsystem equivalence suite (ISSUE 5 acceptance):
//!
//! 1. every available SIMD backend is **bit-identical** to the portable
//!    reference on random dims, including non-multiple-of-lane tails;
//! 2. the query-blocked multi-query scans return exactly the per-query
//!    hits on `FrozenView` and `IvfView` (ids, scores, tie-breaks);
//! 3. the batched route path (`RouterSnapshot::score_batch`,
//!    `ShardedSnapshot::score_batch{,_scatter}`) scores bit-identically
//!    to the single-query path over flat and IVF views at any K;
//! 4. the int8 quantized kernels (ISSUE 8) match the portable int8
//!    reference exactly on every available backend (integer
//!    accumulation — equality, not tolerance), and the SQ8 view with a
//!    corpus-covering rerank returns the flat path's exact hits.
//!
//! The whole suite (and the rest of tier-1) also runs in CI with
//! `EAGLE_KERNEL=portable` (and again with `EAGLE_QUANT=1`), so both
//! dispatch arms stay covered.

use eagle::config::{EagleParams, EpochParams, IvfPublishParams, ShardParams};
use eagle::coordinator::router::Observation;
use eagle::coordinator::sharded::ShardedRouter;
use eagle::coordinator::snapshot::RouterWriter;
use eagle::elo::{Comparison, Outcome};
use eagle::util::{l2_normalize, prop, Rng};
use eagle::vectordb::kernel::{self, Backend};
use eagle::vectordb::quant::{QuantCache, QuantView};
use eagle::vectordb::view::SegmentStore;
use eagle::vectordb::{Feedback, ReadIndex, VectorIndex};

fn unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn rand_obs(rng: &mut Rng, dim: usize, n_models: usize) -> Observation {
    let a = rng.below(n_models);
    let mut b = rng.below(n_models - 1);
    if b >= a {
        b += 1;
    }
    let outcome = match rng.below(3) {
        0 => Outcome::WinA,
        1 => Outcome::WinB,
        _ => Outcome::Draw,
    };
    Observation::single(unit(rng, dim), Comparison { a, b, outcome })
}

fn available_backends() -> Vec<Backend> {
    [Backend::Portable, Backend::Avx2, Backend::Neon]
        .into_iter()
        .filter(|b| b.available())
        .collect()
}

#[test]
fn backends_bit_identical_across_dims_and_tails() {
    prop::check("kernel backends bit-identical", 150, |rng| {
        // cover every tail residue (n % 8) plus serving-scale dims
        let n = match rng.below(3) {
            0 => rng.below(33),
            1 => 250 + rng.below(14),
            _ => 1 + rng.below(1024),
        };
        let a = prop::vec_f32(rng, n);
        let b = prop::vec_f32(rng, n);
        let want = Backend::Portable.dot(&a, &b);
        for backend in available_backends() {
            let got = backend.dot(&a, &b);
            prop::assert_prop(
                got.to_bits() == want.to_bits(),
                &format!("{} != portable at n={n}", backend.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn blocked_scan_bit_identical_across_backends_and_shapes() {
    prop::check("blocked scan bit-identical", 40, |rng| {
        let dim = 1 + rng.below(300);
        let n_rows = rng.below(40);
        let n_q = rng.below(9);
        let rows = prop::vec_f32(rng, n_rows * dim);
        let queries: Vec<Vec<f32>> = (0..n_q).map(|_| prop::vec_f32(rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let mut want = vec![0.0f32; n_q * n_rows];
        Backend::Portable.scan_block_into(&qrefs, dim, &rows, &mut want);
        // the blocked grid must equal per-pair portable dots...
        for (q, query) in qrefs.iter().enumerate() {
            for r in 0..n_rows {
                let single = Backend::Portable.dot(query, &rows[r * dim..(r + 1) * dim]);
                prop::assert_prop(
                    want[q * n_rows + r].to_bits() == single.to_bits(),
                    "portable blocked != portable single",
                )?;
            }
        }
        // ...and every backend must reproduce it bit-for-bit
        for backend in available_backends() {
            let mut got = vec![0.0f32; n_q * n_rows];
            backend.scan_block_into(&qrefs, dim, &rows, &mut got);
            for (g, w) in got.iter().zip(&want) {
                prop::assert_prop(
                    g.to_bits() == w.to_bits(),
                    &format!("{} blocked scan != portable", backend.name()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn frozen_and_ivf_view_batch_search_equals_singles() {
    // end-to-end through the published snapshot views, flat and IVF
    // (partial probe), batch sizes straddling the query tile
    prop::check("view batch == singles", 20, |rng| {
        let dim = 32;
        let n = 40 + rng.below(300);
        let mut writer = RouterWriter::new(
            EagleParams::default(),
            4,
            dim,
            EpochParams { publish_every: 16, publish_interval_ms: 10_000 },
        );
        if rng.below(2) == 1 {
            writer.set_ivf(IvfPublishParams {
                publish_threshold: 50,
                n_cells: 8,
                nprobe: 1 + rng.below(8),
            });
        }
        for _ in 0..n {
            writer.observe(rand_obs(rng, dim, 4));
        }
        writer.publish();
        let snap = writer.ring().load();
        let k = 1 + rng.below(25);
        let n_q = 1 + rng.below(11);
        let queries: Vec<Vec<f32>> = (0..n_q).map(|_| unit(rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let batch = snap.view().search_batch(&qrefs, k);
        for (q, hits) in qrefs.iter().zip(&batch) {
            prop::assert_prop(
                hits == &snap.view().search(q, k),
                "batch hits != single hits through the snapshot view",
            )?;
        }
        Ok(())
    });
}

#[test]
fn segment_store_batch_default_matches_singles() {
    // the trait's default (map singles) must also hold, e.g. on the
    // writer-side segment store that has no blocked override
    let mut rng = Rng::new(7);
    let dim = 16;
    let mut store = SegmentStore::new(dim);
    for i in 0..120 {
        let v = unit(&mut rng, dim);
        store.add(
            &v,
            Feedback::single(Comparison { a: i % 3, b: (i + 1) % 3, outcome: Outcome::WinA }),
        );
        if i % 31 == 0 {
            let _ = store.freeze();
        }
    }
    let queries: Vec<Vec<f32>> = (0..5).map(|_| unit(&mut rng, dim)).collect();
    let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
    let batch = store.search_batch(&qrefs, 10);
    for (q, hits) in qrefs.iter().zip(&batch) {
        assert_eq!(hits, &store.search(q, 10));
    }
}

#[test]
fn sharded_score_batch_bit_identical_to_singles_at_k1_and_k3() {
    for shards in [1usize, 3] {
        let mut rng = Rng::new(0xEA + shards as u64);
        let dim = 24;
        let mut router = ShardedRouter::new(
            EagleParams::default(),
            5,
            dim,
            EpochParams { publish_every: 64, publish_interval_ms: 10_000 },
            ShardParams { count: shards, hash_seed: 0xEA61E },
        );
        for _ in 0..400 {
            router.observe(rand_obs(&mut rng, dim, 5));
        }
        router.publish_all();
        let snap = router.handle().load();
        let queries: Vec<Vec<f32>> = (0..10).map(|_| unit(&mut rng, dim)).collect();
        let batch = snap.score_batch(&queries);
        let scatter = snap.score_batch_scatter(&queries);
        for (i, q) in queries.iter().enumerate() {
            let single = snap.scores(q);
            assert_eq!(batch[i], single, "K={shards}: batch diverged at query {i}");
            assert_eq!(scatter[i], single, "K={shards}: scatter diverged at query {i}");
        }
    }
}

#[test]
fn int8_kernels_exact_across_backends_dims_and_tails() {
    // the int8 path accumulates in i32, so this is integer equality on
    // every backend, not a floating-point reduction contract
    prop::check("int8 kernels exact", 60, |rng| {
        let dim = 1 + rng.below(300);
        let n_rows = rng.below(20);
        let n_q = rng.below(6);
        let code = |rng: &mut Rng| (rng.below(255) as i32 - 127) as i8;
        let rows: Vec<i8> = (0..n_rows * dim).map(|_| code(rng)).collect();
        let queries: Vec<Vec<i8>> = (0..n_q).map(|_| (0..dim).map(|_| code(rng)).collect()).collect();
        let qrefs: Vec<&[i8]> = queries.iter().map(|q| q.as_slice()).collect();
        let mut want = vec![0i32; n_q * n_rows];
        Backend::Portable.scan_i8_block_into(&qrefs, dim, &rows, &mut want);
        for backend in available_backends() {
            // single dots against the portable scalar reference
            for (q, query) in qrefs.iter().enumerate() {
                for r in 0..n_rows {
                    let got = backend.dot_i8(query, &rows[r * dim..(r + 1) * dim]);
                    prop::assert_prop(
                        got == want[q * n_rows + r],
                        &format!("{} dot_i8 != portable at dim={dim}", backend.name()),
                    )?;
                }
            }
            let mut got = vec![0i32; n_q * n_rows];
            backend.scan_i8_block_into(&qrefs, dim, &rows, &mut got);
            prop::assert_prop(
                got == want,
                &format!("{} scan_i8_block_into != portable", backend.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn quant_full_rerank_returns_exact_flat_hits() {
    // corpus-covering rerank (factor * k >= rows) means every candidate
    // is rescored by the exact f32 kernel, so hits must be bitwise the
    // flat path's — on whatever backend this process dispatched to
    prop::check("quant full rerank == flat", 15, |rng| {
        let dim = 1 + rng.below(130);
        let n = 1 + rng.below(400);
        let mut store = SegmentStore::new(dim);
        for i in 0..n {
            let v = unit(rng, dim);
            store.add(
                &v,
                Feedback::single(Comparison {
                    a: i % 3,
                    b: (i + 1) % 3,
                    outcome: Outcome::WinA,
                }),
            );
        }
        let view = store.freeze();
        let mut cache = QuantCache::new();
        // min_rows = 1: every segment quantized, no exact-tail shortcut
        let qview = QuantView::build(view.clone(), &mut cache, 1, n.max(1));
        let k = 1 + rng.below(20);
        let n_q = 1 + rng.below(7);
        let queries: Vec<Vec<f32>> = (0..n_q).map(|_| unit(rng, dim)).collect();
        let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
        let batch = qview.search_batch(&qrefs, k);
        for (q, hits) in qrefs.iter().zip(&batch) {
            prop::assert_prop(
                hits == &view.search(q, k),
                "quantized full-rerank batch hits != flat hits",
            )?;
            prop::assert_prop(
                hits == &qview.search(q, k),
                "quantized batch hits != quantized single hits",
            )?;
        }
        Ok(())
    });
}

#[test]
fn active_backend_is_available_and_parseable() {
    let b = kernel::active();
    assert!(b.available(), "active backend must run on this host");
    assert_eq!(kernel::parse_choice(b.name()), Ok(Some(b)));
    // when CI forces the portable arm, dispatch must honor it
    if std::env::var("EAGLE_KERNEL").as_deref() == Ok("portable") {
        assert_eq!(b, Backend::Portable);
    }
}
