//! Follower replication over the durable log: property tests that a
//! follower tailing a live leader's store rebuilds bit-identical routing
//! state, that promotion ≡ crash recovery (same bytes, same router),
//! that a newer manifest format is a clear error, and a full wire-level
//! failover e2e (SIGKILL the leader mid-ingest, promote the follower,
//! zero acked-feedback loss past the snapshot cut).

use std::path::{Path, PathBuf};
use std::time::Duration;

use eagle::config::{EagleParams, EpochParams, ShardParams};
use eagle::coordinator::durable::{DurableLaneWriter, DurableOptions, DurableStore, StoreMeta};
use eagle::coordinator::replica::Follower;
use eagle::coordinator::router::Observation;
use eagle::coordinator::sharded::ShardedRouter;
use eagle::elo::{Comparison, Outcome};
use eagle::json::{self, Value};
use eagle::util::{l2_normalize, Rng};

const DIM: usize = 16;
const N_MODELS: usize = 5;
const HASH_SEED: u64 = 0xEA61E;

fn unit(rng: &mut Rng) -> Vec<f32> {
    let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn rand_obs(rng: &mut Rng) -> Observation {
    let a = rng.below(N_MODELS);
    let mut b = rng.below(N_MODELS - 1);
    if b >= a {
        b += 1;
    }
    let outcome = match rng.below(3) {
        0 => Outcome::WinA,
        1 => Outcome::WinB,
        _ => Outcome::Draw,
    };
    Observation::single(unit(rng), Comparison { a, b, outcome })
}

fn cadence() -> EpochParams {
    EpochParams { publish_every: 16, publish_interval_ms: 10_000 }
}

/// Follower cadence: publish every record so the replica's snapshots are
/// exactly caught up after a quiescent poll (comparisons below are
/// against fully published state on both sides).
fn tail_cadence() -> EpochParams {
    EpochParams { publish_every: 1, publish_interval_ms: 10_000 }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("eagle_replication_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn meta(k: usize) -> StoreMeta {
    StoreMeta {
        params: EagleParams::default(),
        n_models: N_MODELS,
        dim: DIM,
        shards: ShardParams { count: k, hash_seed: HASH_SEED },
    }
}

fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap().flatten() {
        let from = entry.path();
        let to = dst.join(entry.file_name());
        if from.is_dir() {
            copy_dir(&from, &to);
        } else {
            std::fs::copy(&from, &to).unwrap();
        }
    }
}

/// Poll until a round applies nothing and leaves no lag (the leader-side
/// writers must be synced first).
fn quiesce(f: &mut Follower) {
    for _ in 0..200 {
        let s = f.poll().expect("tail poll");
        if s.applied == 0 && s.lag_bytes == 0 && s.pending_folds == 0 {
            return;
        }
    }
    panic!("follower failed to drain a quiescent store");
}

/// Leader-side published state vs the follower's replica snapshots:
/// store length, global ratings, and scored batches, all bitwise.
fn assert_follower_matches(leader: &mut ShardedRouter, f: &Follower, rng: &mut Rng, what: &str) {
    leader.publish_all();
    let a = leader.handle().load();
    let b = f.handle().load();
    assert_eq!(a.store_len(), b.store_len(), "{what}: store length");
    assert_eq!(a.global_ratings(), b.global_ratings(), "{what}: global ratings");
    let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(rng)).collect();
    assert_eq!(a.score_batch(&queries), b.score_batch(&queries), "{what}: score_batch");
}

fn assert_equivalent(a: &mut ShardedRouter, b: &mut ShardedRouter, rng: &mut Rng, what: &str) {
    a.publish_all();
    b.publish_all();
    assert_eq!(a.store_len(), b.store_len(), "{what}: store length");
    assert_eq!(a.history_len(), b.history_len(), "{what}: history length");
    assert_eq!(
        a.global_elo().export_state(),
        b.global_elo().export_state(),
        "{what}: global-ELO state"
    );
    let snap_a = a.handle().load();
    let snap_b = b.handle().load();
    assert_eq!(snap_a.global_ratings(), snap_b.global_ratings(), "{what}: ratings");
    let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(rng)).collect();
    assert_eq!(
        snap_a.score_batch(&queries),
        snap_b.score_batch(&queries),
        "{what}: score_batch"
    );
}

/// One leader-side ingest step: observe in memory, append to the shard's
/// delta log, interleave seals / syncs / global checkpoints.
fn leader_step(
    i: usize,
    leader: &mut ShardedRouter,
    writers: &mut [DurableLaneWriter],
    store: &DurableStore,
    rng: &mut Rng,
) {
    let obs = rand_obs(rng);
    let shard = leader.shard_for(&obs.embedding);
    let gid = leader.next_global_id();
    leader.observe(obs.clone());
    writers[shard].append(gid, &obs).unwrap();
    let k = writers.len();
    if i % 23 == 22 {
        writers[rng.below(k)].sync().unwrap();
    }
    if i % 37 == 36 {
        writers[rng.below(k)].seal().unwrap();
    }
    if i % 61 == 60 {
        for w in writers.iter_mut() {
            w.sync().unwrap();
        }
        store
            .checkpoint_global(leader.next_global_id(), leader.global_elo().export_state())
            .unwrap();
    }
}

#[test]
fn follower_tails_leader_bit_identically() {
    // the tentpole property: a follower attached mid-storm, polling a
    // *live* store (buffered writers, seal races, checkpoint swaps),
    // converges to the leader's exact published state at every quiescent
    // point — for one shard and several
    for &k in &[1usize, 3] {
        let mut rng = Rng::new(0xF0110 + k as u64 * 7);
        let dir = tmp_dir(&format!("tail_k{k}"));
        let opts = DurableOptions { seal_bytes: 900, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(k), opts.clone()).unwrap();
        let mut writers: Vec<DurableLaneWriter> =
            (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
        let mut leader =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);

        let mut follower: Option<Follower> = None;
        for i in 0..400usize {
            leader_step(i, &mut leader, &mut writers, &store, &mut rng);
            if i == 150 {
                // attach mid-storm: open-time catch-up against moving files
                follower = Some(Follower::open(&dir, tail_cadence()).unwrap());
            }
            if i > 150 && i % 20 == 0 {
                // live polls race seals and buffered appends; they must
                // never error or apply out of order
                follower.as_mut().unwrap().poll().unwrap();
            }
        }
        for w in &mut writers {
            w.sync().unwrap();
        }
        let mut f = follower.unwrap();
        quiesce(&mut f);
        assert_follower_matches(&mut leader, &f, &mut rng, &format!("k={k} first wave"));

        // a second storm wave: this exercises the steady-state tail, not
        // the open-time catch-up
        for i in 400..520usize {
            leader_step(i, &mut leader, &mut writers, &store, &mut rng);
            if i % 15 == 0 {
                f.poll().unwrap();
            }
        }
        for w in &mut writers {
            w.sync().unwrap();
        }
        quiesce(&mut f);
        assert_follower_matches(&mut leader, &f, &mut rng, &format!("k={k} second wave"));
        assert!(f.applied_records() > 0);
        assert!(f.metrics().manifest_generation() >= 1, "seals must bump the generation");
        assert_eq!(f.metrics().lag_bytes(), 0);

        drop(writers);
        drop(store);
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn promote_matches_crash_recovery_bitwise() {
    // promotion and crash recovery consume the same bytes through the
    // same CatchUp path; the routers they produce must be bit-identical —
    // and the promoted one must stay live (ingest resumes durably)
    let k = 4usize;
    let mut rng = Rng::new(0x9107E);
    let dir = tmp_dir("promote");
    let opts = DurableOptions { seal_bytes: 1200, fsync: false, mmap: true };
    {
        let store = DurableStore::create(&dir, meta(k), opts.clone()).unwrap();
        let mut writers: Vec<DurableLaneWriter> =
            (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
        let mut leader =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);
        for i in 0..260usize {
            leader_step(i, &mut leader, &mut writers, &store, &mut rng);
        }
        for w in &mut writers {
            w.sync().unwrap();
        }
        // writers + store drop here: the lock is released, files quiesce
    }
    let dir_ref = tmp_dir("promote_ref");
    copy_dir(&dir, &dir_ref);

    // reference: plain single-node crash recovery of the copied bytes
    let (_store_ref, recovery) = DurableStore::open(&dir_ref, opts.clone()).unwrap();
    let mut reference = recovery.into_router(cadence()).unwrap();

    // candidate: follow, then promote
    let mut f = Follower::open(&dir, cadence()).unwrap();
    quiesce(&mut f);
    let pre_handle = f.handle();
    let promotion = match f.promote(opts.clone()) {
        Ok(p) => p,
        Err(e) => panic!("promote failed: {:#}", e.error),
    };
    let mut promoted = promotion.router;
    assert_equivalent(&mut reference, &mut promoted, &mut rng, "promote vs crash recovery");

    // reader handles taken before promotion keep serving the same rings
    let q = unit(&mut rng);
    assert_eq!(
        pre_handle.load().scores(&q),
        promoted.handle().load().scores(&q),
        "pre-promotion reader handle diverged"
    );

    // the promoted node is a real leader: lane writers resume at the
    // recovered tail and the trajectory matches the reference exactly
    let store = promotion.store;
    let mut writers: Vec<DurableLaneWriter> =
        (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
    for _ in 0..60 {
        let obs = rand_obs(&mut rng);
        let shard = promoted.shard_for(&obs.embedding);
        let gid = promoted.next_global_id();
        reference.observe(obs.clone());
        promoted.observe(obs.clone());
        writers[shard].append(gid, &obs).unwrap();
    }
    for w in &mut writers {
        w.sync().unwrap();
    }
    assert_equivalent(&mut reference, &mut promoted, &mut rng, "post-promotion ingest");

    drop(writers);
    drop(store);
    drop(_store_ref);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir_ref).ok();
}

#[test]
fn newer_manifest_version_is_a_clear_error() {
    // forward compatibility: a manifest written by a future format must
    // produce a clear refusal, not a panic or a silent misparse
    let dir = tmp_dir("fwdcompat");
    let opts = DurableOptions { seal_bytes: 4096, fsync: false, mmap: true };
    drop(DurableStore::create(&dir, meta(2), opts).unwrap());
    let path = dir.join("MANIFEST.json");
    let mut v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    match &mut v {
        Value::Obj(map) => {
            map.insert("format_version".to_string(), json::num(9.0));
        }
        other => panic!("manifest is not an object: {other:?}"),
    }
    std::fs::write(&path, v.to_json()).unwrap();

    let err = Follower::open(&dir, cadence()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("newer than supported"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---- wire-level failover e2e -------------------------------------------

/// Spawn `eagle serve` on a free port with a durable dir and the hash
/// embedder (no artifacts needed), returning the child + bound address.
fn spawn_server(durable_dir: &Path, extra: &[&str]) -> (std::process::Child, String) {
    use std::io::{BufRead, BufReader};
    let mut args: Vec<String> = [
        "serve",
        "--addr",
        "127.0.0.1:0",
        "--workers",
        "2",
        "--set",
        "persist.interval_ms=20",
        "--set",
        "persist.seal_bytes=16384",
        "--set",
        "persist.fsync=false",
        "--set",
        "shards.count=2",
        "--set",
        "epoch.publish_every=8",
        "--set",
        "replica.poll_ms=10",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    args.push("--set".to_string());
    args.push(format!("persist.dir={}", durable_dir.display()));
    args.extend(extra.iter().map(|s| s.to_string()));
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_eagle"))
        .args(&args)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn eagle serve");
    let stdout = child.stdout.take().expect("child stdout");
    let mut reader = BufReader::new(stdout);
    let mut addr = None;
    let mut line = String::new();
    // the banner line is printed once serving starts
    for _ in 0..64 {
        line.clear();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(rest) = line.strip_prefix("eagle serving on ") {
            addr = rest.split_whitespace().next().map(str::to_string);
            break;
        }
    }
    // keep draining the pipe so the server never blocks on a full pipe
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).unwrap_or(0) > 0 {
            sink.clear();
        }
    });
    let addr = addr.expect("server banner with bound address");
    (child, addr)
}

#[test]
fn failover_e2e_promote_preserves_acked_feedback() {
    use eagle::server::client::EagleClient;

    let root = tmp_dir("failover");
    let durable = root.join("durable");
    std::fs::create_dir_all(&root).unwrap();

    // phase 1: leader serves; storm acked feedback, then cut a durable
    // snapshot (the acked-loss reference point)
    let (mut leader, leader_addr) = spawn_server(&durable, &[]);
    let mut lc = EagleClient::connect(&leader_addr).expect("connect leader");
    assert_eq!(lc.hello().expect("leader hello").role, "leader");
    for i in 0..300 {
        lc.feedback(&format!("failover prompt {i}"), "gpt-4", "mistral-7b-chat", 1.0)
            .expect("feedback accepted");
    }
    let (snap_path, entries) = lc.snapshot().expect("durable snapshot op");
    assert_eq!(entries, 300, "snapshot cut must cover every acked record");
    assert_eq!(snap_path, durable.display().to_string());

    // phase 2: warm standby tails the same store over the filesystem
    let (mut follower, follower_addr) = spawn_server(&durable, &["--role", "follower"]);
    let mut fc = EagleClient::connect(&follower_addr).expect("connect follower");
    let hello = fc.hello().expect("follower hello");
    assert_eq!(hello.role, "follower");
    // read path works on the replica...
    let decision = fc.route("which model should answer this?", 0.02).expect("replica route");
    assert!(!decision.model.is_empty());
    // ...mutating ops get the typed redirect...
    let err = fc
        .feedback("rejected on the replica", "gpt-4", "gpt-3.5-turbo", 0.0)
        .expect_err("follower must reject feedback");
    assert!(format!("{err:#}").contains("not the leader"), "untyped redirect: {err:#}");
    let err = fc.snapshot().expect_err("follower must reject snapshot");
    assert!(format!("{err:#}").contains("not the leader"), "untyped redirect: {err:#}");
    // ...and the stats report grows a replica section
    let (report, _, _) = fc.stats().expect("follower stats");
    assert!(report.contains("replica: role=follower"), "no replica section in: {report}");

    // phase 3: keep ingesting on the leader, then SIGKILL it mid-stream
    for i in 300..400 {
        let _ = lc.feedback(&format!("failover prompt {i}"), "gpt-4", "gpt-3.5-turbo", 0.0);
    }
    leader.kill().expect("SIGKILL leader");
    let _ = leader.wait();
    drop(lc);

    // reference copy of the quiescent store, before promotion mutates it
    let ref_copy = root.join("reference");
    copy_dir(&durable, &ref_copy);

    // phase 4: promote the follower (retry while the old leader's lock
    // liveness check settles)
    let mut role = String::new();
    for _ in 0..50 {
        match fc.promote() {
            Ok(r) => {
                role = r;
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    assert_eq!(role, "leader", "promotion did not succeed");
    assert_eq!(fc.hello().expect("post-promote hello").role, "leader");
    // promote is idempotent on a leader
    assert_eq!(fc.promote().expect("repeat promote"), "leader");

    // zero acked loss: everything covered by the snapshot cut survives,
    // and the promoted corpus equals the single-node replay reference
    let (_, entries) = fc.snapshot().expect("snapshot after promote");
    assert!(entries >= 300, "promoted follower lost acked feedback ({entries} records)");
    let opts = DurableOptions { seal_bytes: 16384, fsync: false, mmap: true };
    let (_store_ref, recovery) = DurableStore::open(&ref_copy, opts).unwrap();
    let reference = recovery.into_router(EpochParams::default()).expect("reference replay");
    assert_eq!(
        entries,
        reference.store_len() as u64,
        "promoted corpus diverged from the single-node replay reference"
    );

    // the promoted node accepts feedback and persists it
    fc.feedback("accepted after promotion", "gpt-4", "mistral-7b-chat", 0.5)
        .expect("feedback on promoted leader");
    let (_, entries_after) = fc.snapshot().expect("snapshot after new feedback");
    assert!(entries_after > entries, "promoted leader did not ingest");
    let (report, _, _) = fc.stats().expect("promoted stats");
    assert!(report.contains("role=leader"), "stats role did not flip: {report}");
    assert!(!report.contains("replica:"), "stale replica section in: {report}");

    follower.kill().ok();
    let _ = follower.wait();
    std::fs::remove_dir_all(&root).ok();
}
