//! End-to-end runtime integration: load real AOT artifacts, execute on the
//! PJRT CPU client, and compare against golden vectors emitted by the
//! python compile path. Skipped (with a message) if `make artifacts` has
//! not been run.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use eagle::embedding::{BatcherOptions, EmbedService, Embedder, ServiceEmbedder};
use eagle::json;
use eagle::metrics::Metrics;
use eagle::runtime::{Manifest, Runtime};
use eagle::util::cosine;
use eagle::vectordb::flat::FlatStore;
use eagle::vectordb::VectorIndex;

fn artifacts_dir() -> Option<PathBuf> {
    if !Runtime::available() {
        eprintln!(
            "skipping: PJRT runtime not compiled in (build with `--features pjrt` \
             in an environment that provides the xla crate)"
        );
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

struct Golden {
    texts: Vec<String>,
    embeddings: Vec<Vec<f32>>,
    tokens: Vec<Vec<i32>>,
}

fn load_golden(dir: &Path) -> Golden {
    let text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    let v = json::parse(&text).unwrap();
    let texts = v
        .get("texts")
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_str().unwrap().to_string())
        .collect();
    let embeddings = v
        .get("embeddings")
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap() as f32).collect())
        .collect();
    let tokens = v
        .get("tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|x| x.as_i64().unwrap() as i32).collect())
        .collect();
    Golden { texts, embeddings, tokens }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.model.seq_len, eagle::tokenizer::SEQ_LEN);
    assert_eq!(m.model.vocab_size, eagle::tokenizer::VOCAB_SIZE);
    assert!(!m.embed_batch_sizes.is_empty());
    assert!(!m.scorer_shapes.is_empty());
    let w = eagle::runtime::read_weights(&m).unwrap();
    assert_eq!(w.len(), m.weights_total_elems);
}

#[test]
fn tokenizer_parity_with_python() {
    let Some(dir) = artifacts_dir() else { return };
    let golden = load_golden(&dir);
    for (text, expected) in golden.texts.iter().zip(&golden.tokens) {
        let t = eagle::tokenizer::tokenize_default(text);
        assert_eq!(&t.ids, expected, "tokenizer parity broke for {text:?}");
    }
}

#[test]
fn embed_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Runtime::load(&dir).unwrap();
    let golden = load_golden(&dir);
    let m = runtime.manifest();
    let seq = m.model.seq_len;
    let d = m.model.d_model;

    for (text, expected) in golden.texts.iter().zip(&golden.embeddings) {
        let t = eagle::tokenizer::tokenize_default(text);
        let out = runtime.embed_batch(&t.ids, &t.mask, 1).unwrap();
        assert_eq!(out.len(), d);
        let _ = seq;
        let cos = cosine(&out, expected);
        let max_err = out
            .iter()
            .zip(expected)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // CPU XLA vs jax CPU: tiny numeric drift is expected.
        if expected.iter().any(|&x| x != 0.0) {
            assert!(cos > 0.9999, "cosine {cos} for {text:?}");
        }
        assert!(max_err < 1e-3, "max err {max_err} for {text:?}");
    }
}

#[test]
fn embed_batched_buckets_agree_with_b1() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Runtime::load(&dir).unwrap();
    let m = runtime.manifest();
    let seq = m.model.seq_len;
    let d = m.model.d_model;
    let texts = ["alpha beta gamma", "the quick brown fox", "solve for x"];

    // batch of 3 -> bucket 4 (padded)
    let bucket = m.pick_bucket(texts.len()).unwrap();
    let mut tokens = vec![0i32; bucket * seq];
    let mut mask = vec![0f32; bucket * seq];
    for (i, t) in texts.iter().enumerate() {
        let tok = eagle::tokenizer::tokenize_default(t);
        tokens[i * seq..(i + 1) * seq].copy_from_slice(&tok.ids);
        mask[i * seq..(i + 1) * seq].copy_from_slice(&tok.mask);
    }
    let batched = runtime.embed_batch(&tokens, &mask, bucket).unwrap();

    for (i, t) in texts.iter().enumerate() {
        let tok = eagle::tokenizer::tokenize_default(t);
        let single = runtime.embed_batch(&tok.ids, &tok.mask, 1).unwrap();
        let row = &batched[i * d..(i + 1) * d];
        let max_err = row
            .iter()
            .zip(&single)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-4, "bucket/b1 mismatch {max_err} for {t:?}");
    }
}

#[test]
fn scorer_hlo_matches_rust_scan() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Runtime::load(&dir).unwrap();
    let m = runtime.manifest();
    let d = m.model.d_model;
    let (q_n, n) = m.scorer_shapes[0];

    let mut rng = eagle::util::Rng::new(99);
    let mut store = FlatStore::new(d);
    let mut corpus = Vec::with_capacity(n * d);
    for i in 0..n {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        eagle::util::l2_normalize(&mut v);
        corpus.extend_from_slice(&v);
        store.add(
            &v,
            eagle::vectordb::Feedback::single(eagle::elo::Comparison {
                a: 0,
                b: 1,
                outcome: eagle::elo::Outcome::WinA,
            }),
        );
        let _ = i;
    }
    let mut queries = Vec::with_capacity(q_n * d);
    for _ in 0..q_n {
        let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        eagle::util::l2_normalize(&mut v);
        queries.extend_from_slice(&v);
    }

    let hlo_scores = runtime.score(&queries, q_n, &corpus, n).unwrap();
    for qi in 0..q_n {
        let q = &queries[qi * d..(qi + 1) * d];
        let rust_scores = store.score_all(q);
        for i in 0..n {
            let diff = (hlo_scores[qi * n + i] - rust_scores[i]).abs();
            assert!(diff < 1e-4, "scorer mismatch at ({qi},{i}): {diff}");
        }
    }
}

#[test]
fn embed_service_batches_concurrent_callers() {
    let Some(dir) = artifacts_dir() else { return };
    let metrics = Arc::new(Metrics::new());
    let svc = EmbedService::start(
        &dir,
        BatcherOptions { batch_window_us: 2000, max_batch: 16 },
        metrics.clone(),
    )
    .unwrap();
    let handle = svc.handle();

    let threads: Vec<_> = (0..8)
        .map(|i| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let text = format!("request number {i} about topic {}", i % 3);
                h.embed_one(&text).unwrap()
            })
        })
        .collect();
    let results: Vec<Vec<f32>> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    for v in &results {
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3);
    }
    assert_eq!(metrics.embed_queries.get(), 8);
    // with an ample window at least some requests must have shared a batch
    assert!(
        metrics.embed_batches.get() < 8,
        "no batching happened: {} batches",
        metrics.embed_batches.get()
    );

    // identical text embeds identically through the service
    let a = handle.embed_one("same text twice").unwrap();
    let b = handle.embed_one("same text twice").unwrap();
    assert_eq!(a, b);
}

#[test]
fn service_embedder_trait_adapter() {
    let Some(dir) = artifacts_dir() else { return };
    let metrics = Arc::new(Metrics::new());
    let svc = EmbedService::start(&dir, BatcherOptions::default(), metrics).unwrap();
    let embedder = ServiceEmbedder::new(svc.handle());
    assert_eq!(embedder.dim(), 256);
    let vs = embedder.embed(&["one", "two"]);
    assert_eq!(vs.len(), 2);
    assert_ne!(vs[0], vs[1]);
}
