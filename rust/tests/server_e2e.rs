//! Full-stack serving tests: TCP server + embedder + Eagle router.
//!
//! Two tiers:
//! - **hash-backed** tests (`EmbedService::start_hash`) run everywhere —
//!   no artifacts needed — and cover the sharded ingest pipeline
//!   end-to-end, including the K>1 applier feedback storm;
//! - **PJRT** tests skip when artifacts are missing (run `make
//!   artifacts`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use eagle::config::{EagleParams, EpochParams, ShardParams};
use eagle::coordinator::registry::ModelRegistry;
use eagle::coordinator::router::EagleRouter;
use eagle::elo::{Comparison, GlobalElo, Outcome};
use eagle::embedding::{BatcherOptions, EmbedService, Embedder, HashEmbedder};
use eagle::metrics::Metrics;
use eagle::runtime::Runtime;
use eagle::server::client::EagleClient;
use eagle::server::{Server, ServerOptions, ServerState};
use eagle::util::Rng;
use eagle::vectordb::flat::FlatStore;

fn artifacts_dir() -> Option<PathBuf> {
    if !Runtime::available() {
        eprintln!(
            "skipping: PJRT runtime not compiled in (build with `--features pjrt` \
             in an environment that provides the xla crate)"
        );
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Feedback records folded into the shared global table (stream order,
/// published or not).
fn ingested(server: &Server) -> usize {
    server.state.ingest_metrics().folded_global.get() as usize
}

fn start_server(dir: &Path) -> (Server, EmbedService, String) {
    start_server_with_snapshot(dir, None)
}

fn start_server_with_snapshot(
    dir: &Path,
    snapshot: Option<std::path::PathBuf>,
) -> (Server, EmbedService, String) {
    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start(
        dir,
        BatcherOptions { batch_window_us: 100, max_batch: 16 },
        metrics.clone(),
    )
    .unwrap();
    let registry = ModelRegistry::routerbench();
    let router = EagleRouter::new(EagleParams::default(), registry.len(), FlatStore::new(256));
    // tight cadence so feedback becomes routable quickly in tests
    let epoch = EpochParams { publish_every: 8, publish_interval_ms: 10 };
    let mut builder =
        ServerState::builder(router, registry, service.handle(), metrics).epoch(epoch);
    if let Some(p) = snapshot {
        builder = builder.snapshot_path(p);
    }
    let state = Arc::new(builder.build());
    let server = Server::start(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    (server, service, addr)
}

/// Hash-embedder-backed server: the full serving stack minus PJRT, so the
/// ingest pipeline is exercised on any machine. `dim` must match the
/// reference [`HashEmbedder`] used to replay the stream.
fn start_hash_server(
    dim: usize,
    shards: usize,
    workers: usize,
    snapshot: Option<PathBuf>,
) -> (Server, EmbedService, String) {
    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start_hash(
        dim,
        BatcherOptions { batch_window_us: 100, max_batch: 16 },
        metrics.clone(),
    );
    let registry = ModelRegistry::routerbench();
    let router = EagleRouter::new(EagleParams::default(), registry.len(), FlatStore::new(dim));
    let mut builder =
        ServerState::builder(router, registry, service.handle(), metrics).options(ServerOptions {
            epoch: EpochParams { publish_every: 16, publish_interval_ms: 5 },
            shards: ShardParams { count: shards, hash_seed: 0xEA61E },
            ..Default::default()
        });
    if let Some(p) = snapshot {
        builder = builder.snapshot_path(p);
    }
    let state = Arc::new(builder.build());
    let server = Server::start(state, "127.0.0.1:0", workers).unwrap();
    let addr = server.addr.to_string();
    (server, service, addr)
}

/// Hash-backed server with the durable segment store attached
/// (`[persist] dir` equivalent): the builder creates the store
/// on first boot and recovers from it on the next.
fn start_hash_server_durable(
    dim: usize,
    shards: usize,
    durable_dir: &Path,
) -> (Server, EmbedService, String) {
    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start_hash(
        dim,
        BatcherOptions { batch_window_us: 100, max_batch: 16 },
        metrics.clone(),
    );
    let registry = ModelRegistry::routerbench();
    let router = EagleRouter::new(EagleParams::default(), registry.len(), FlatStore::new(dim));
    let state = ServerState::builder(router, registry, service.handle(), metrics)
        .options(ServerOptions {
            epoch: EpochParams { publish_every: 16, publish_interval_ms: 5 },
            shards: ShardParams { count: shards, hash_seed: 0xEA61E },
            persist_interval_ms: 10,
            persist_dir: Some(durable_dir.to_path_buf()),
            seal_bytes: 8192,
            fsync: false,
            ..Default::default()
        })
        .build();
    let server = Server::start(Arc::new(state), "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    (server, service, addr)
}

#[test]
fn hash_server_durable_dir_survives_restart() {
    let dim = 64;
    let root = std::env::temp_dir()
        .join(format!("eagle_server_durable_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let durable = root.join("store");

    // first boot: bootstrap the store, ingest, checkpoint via the admin op
    let (server, _service, addr) = start_hash_server_durable(dim, 2, &durable);
    let mut client = EagleClient::connect(&addr).unwrap();
    for (text, a, b, score) in feedback_stream(120, 0xD1, 4) {
        let names = server.state.registry.entries();
        client.feedback(&text, &names[a].name, &names[b].name, score).unwrap();
    }
    let (snap_path, entries) = client.snapshot().unwrap();
    assert_eq!(entries, 120);
    assert_eq!(snap_path, durable.display().to_string());
    drop(client);
    server.shutdown();

    // second boot: the builder recovers the corpus from the durable dir
    let (server, _service, addr) = start_hash_server_durable(dim, 2, &durable);
    let snap = server.state.snapshots.load();
    assert_eq!(snap.store_len(), 120, "restart lost the durable corpus");
    assert_eq!(snap.history_len(), 120);
    let mut client = EagleClient::connect(&addr).unwrap();
    let decision = client.route("does routing still work after recovery?", 0.02).unwrap();
    assert!(!decision.model.is_empty());
    // and ingest keeps extending the same store across the restart
    for (text, a, b, score) in feedback_stream(30, 0xD2, 4) {
        let names = server.state.registry.entries();
        client.feedback(&text, &names[a].name, &names[b].name, score).unwrap();
    }
    let (_, entries) = client.snapshot().unwrap();
    assert_eq!(entries, 150);
    drop(client);
    server.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// A deterministic feedback stream over the RouterBench model pool:
/// (text, a, b, score). Outcomes vary so the global ELO trajectory is
/// order-sensitive — matching the in-order replay proves stream order.
fn feedback_stream(n: usize, seed: u64, n_models: usize) -> Vec<(String, usize, usize, f64)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let a = rng.below(n_models);
            let mut b = rng.below(n_models - 1);
            if b >= a {
                b += 1;
            }
            let score = [0.0, 0.5, 1.0][rng.below(3)];
            let text = format!("storm prompt {i} about topic {}", i % 17);
            (text, a, b, score)
        })
        .collect()
}

#[test]
fn hash_server_route_feedback_stats_roundtrip() {
    let (server, _service, addr) = start_hash_server(64, 1, 2, None);
    let mut client = EagleClient::connect(&addr).unwrap();
    client.ping().unwrap();

    let registry = ModelRegistry::routerbench();
    let d = client.route("solve the equation 3x + 5 = 20", 1.0).unwrap();
    assert!(registry.index_of(&d.model).is_some(), "unknown model {}", d.model);

    // tiny budget -> cheapest model
    let cheap = client.route("cheap question", 1e-9).unwrap();
    assert_eq!(cheap.model_index, registry.cheapest_available().unwrap());

    client
        .feedback("solve the equation 3x + 5 = 20", "gpt-4", "llama-2-13b-chat", 1.0)
        .unwrap();
    // barrier: everything accepted above is applied and published
    server.state.force_publish();
    assert_eq!(ingested(&server), 1);
    let snap = server.state.snapshots.load();
    assert_eq!(snap.history_len(), 1);
    let g = registry.index_of("gpt-4").unwrap();
    let l = registry.index_of("llama-2-13b-chat").unwrap();
    assert!(snap.global_ratings()[g] > snap.global_ratings()[l]);

    let (report, requests, feedback) = client.stats().unwrap();
    assert!(requests >= 2, "requests = {requests}");
    assert_eq!(feedback, 1);
    assert!(report.contains("route_latency"));
    assert!(report.contains("ingest:"), "stats missing ingest section: {report}");
    assert!(report.contains("applied=1"), "ingest counters not reported: {report}");

    server.shutdown();
}

/// The ISSUE acceptance test: a feedback storm through K=4 shard-applier
/// threads must (a) preserve global-ELO stream order exactly, (b) keep
/// route reads progressing throughout, and (c) end bit-identical to a
/// single-threaded in-order replay of the same stream.
#[test]
fn feedback_storm_k4_preserves_stream_order_and_routes_progress() {
    const DIM: usize = 64;
    const N_FEEDBACK: usize = 500;
    let (server, _service, addr) = start_hash_server(DIM, 4, 3, None);
    let registry = ModelRegistry::routerbench();
    let n_models = registry.len();
    let stream = feedback_stream(N_FEEDBACK, 0x57AB1E, n_models);

    // route readers hammer concurrently with the storm; every route must
    // come back (progress), none may error
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let readers: Vec<_> = (0..2)
        .map(|r| {
            let addr = addr.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut c = EagleClient::connect(&addr).unwrap();
                let mut routed = 0u64;
                let mut i = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let d = c
                        .route(&format!("reader {r} query {i}"), 0.5)
                        .expect("route failed during feedback storm");
                    assert!(!d.model.is_empty());
                    routed += 1;
                    i += 1;
                }
                routed
            })
        })
        .collect();

    // the storm: one connection => server-side arrival order == send order
    let mut client = EagleClient::connect(&addr).unwrap();
    for (text, a, b, score) in &stream {
        let name_a = &registry.entry(*a).name;
        let name_b = &registry.entry(*b).name;
        client.feedback(text, name_a, name_b, *score).unwrap();
    }

    // barrier: everything accepted is applied + published
    server.state.force_publish();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let routed: u64 = readers.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(routed >= 20, "route readers starved during the storm ({routed} routes)");

    let m = server.state.ingest_metrics();
    assert_eq!(m.queued.get() as usize, N_FEEDBACK);
    assert_eq!(m.folded_global.get() as usize, N_FEEDBACK, "records lost in the pipeline");
    assert_eq!(m.applied.get() as usize, N_FEEDBACK);
    assert_eq!(m.dropped_total(), 0);
    // work actually spread across the K=4 appliers
    let busy_shards = (0..4).filter(|&s| m.shard(s).applied.get() > 0).count();
    assert!(busy_shards >= 3, "only {busy_shards}/4 shard appliers saw work");

    // (a) global-ELO stream order: the shared table equals an in-order
    // replay (ELO updates do not commute, so any reordering diverges)
    let params = EagleParams::default();
    let mut reference_global = GlobalElo::new(n_models, params.k_factor);
    for (_, a, b, score) in &stream {
        let outcome = Outcome::decode(*score).unwrap();
        reference_global.apply_new(&[Comparison { a: *a, b: *b, outcome }]);
    }
    let snap = server.state.snapshots.load();
    assert_eq!(snap.history_len(), N_FEEDBACK);
    assert_eq!(
        snap.global_ratings(),
        &reference_global.ratings()[..],
        "global ELO diverged from stream order under K=4 appliers"
    );

    // (c) full scoring equivalence: server state == single-threaded
    // replay through a flat-store router over hash embeddings
    let embedder = HashEmbedder::new(DIM);
    let mut reference = EagleRouter::new(params, n_models, FlatStore::new(DIM));
    for (text, a, b, score) in &stream {
        let emb = embedder.embed(&[text.as_str()]).pop().unwrap();
        let outcome = Outcome::decode(*score).unwrap();
        reference.observe(eagle::coordinator::router::Observation::single(
            emb,
            Comparison { a: *a, b: *b, outcome },
        ));
    }
    assert_eq!(snap.store_len(), N_FEEDBACK);
    let mut rng = Rng::new(0xFACADE);
    for i in 0..5 {
        let probe = embedder
            .embed(&[format!("equivalence probe {} {}", i, rng.below(1000)).as_str()])
            .pop()
            .unwrap();
        assert_eq!(
            snap.scores(&probe),
            reference.combined_scores(&probe),
            "sharded embed-on-applier ingest diverged from in-order replay"
        );
    }

    server.shutdown();
}

#[test]
fn hash_server_snapshot_op_flushes_and_persists() {
    let snap_path = std::env::temp_dir()
        .join(format!("eagle_hash_server_snap_{}.json", std::process::id()));
    let (server, _service, addr) = start_hash_server(64, 2, 2, Some(snap_path.clone()));
    let mut client = EagleClient::connect(&addr).unwrap();
    for i in 0..5 {
        client
            .feedback(&format!("snapshot test prompt {i}"), "gpt-4", "mistral-7b-chat", 1.0)
            .unwrap();
    }
    // no waiting: the snapshot op runs a pipeline flush barrier itself
    let (path, entries) = client.snapshot().unwrap();
    assert_eq!(path, snap_path.display().to_string());
    assert_eq!(entries, 5);

    let restored = eagle::coordinator::state::load_from(&snap_path).unwrap();
    assert_eq!(restored.feedback_len(), 5);
    assert_eq!(restored.store().len(), 5);
    let g = ModelRegistry::routerbench().index_of("gpt-4").unwrap();
    let m = ModelRegistry::routerbench().index_of("mistral-7b-chat").unwrap();
    assert!(restored.global().ratings()[g] > restored.global().ratings()[m]);

    std::fs::remove_file(&snap_path).ok();
    server.shutdown();
}

#[test]
fn hash_server_overload_drops_are_observable_not_fatal() {
    // a burst bigger than anything a test should drop: every record must
    // be either applied or counted in a drop counter — never lost
    let (server, _service, addr) = start_hash_server(32, 2, 2, None);
    let mut client = EagleClient::connect(&addr).unwrap();
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    for i in 0..300 {
        match client.feedback(&format!("burst {i}"), "gpt-4", "claude-v2", 1.0) {
            Ok(()) => accepted += 1,
            Err(_) => rejected += 1,
        }
    }
    server.state.force_publish();
    let m = server.state.ingest_metrics();
    assert_eq!(m.queued.get(), accepted);
    // conservation: every accepted record is either applied or counted in
    // exactly one post-acceptance drop bucket. A full shard lane stalls
    // the dispatcher instead of dropping, so lane backlog never accounts
    // for missing records — acknowledged feedback is never lost.
    assert_eq!(
        m.folded_global.get() + m.dropped_embed.get() + m.dropped_invalid.get(),
        accepted
    );
    assert_eq!(
        m.applied.get(),
        m.folded_global.get(),
        "applied diverged from globally folded"
    );
    assert_eq!(rejected, m.dropped_overflow.get());
    // connection still healthy after the burst
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn snapshot_op_persists_live_state() {
    let Some(dir) = artifacts_dir() else { return };
    let snap_path = std::env::temp_dir()
        .join(format!("eagle_server_snap_{}.json", std::process::id()));
    let (server, _service, addr) = start_server_with_snapshot(&dir, Some(snap_path.clone()));
    let mut client = EagleClient::connect(&addr).unwrap();

    for i in 0..5 {
        client
            .feedback(&format!("snapshot test prompt {i}"), "gpt-4", "mistral-7b-chat", 1.0)
            .unwrap();
    }
    // the snapshot op flushes the ingest pipeline before persisting
    let (path, entries) = client.snapshot().unwrap();
    assert_eq!(path, snap_path.display().to_string());
    assert_eq!(entries, 5);
    assert_eq!(ingested(&server), 5);

    // the snapshot restores to an equivalent router
    let restored = eagle::coordinator::state::load_from(&snap_path).unwrap();
    assert_eq!(restored.feedback_len(), 5);
    let g = ModelRegistry::routerbench().index_of("gpt-4").unwrap();
    let m = ModelRegistry::routerbench().index_of("mistral-7b-chat").unwrap();
    assert!(restored.global().ratings()[g] > restored.global().ratings()[m]);

    std::fs::remove_file(&snap_path).ok();
    server.shutdown();
}

#[test]
fn snapshot_op_disabled_without_path() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);
    let mut client = EagleClient::connect(&addr).unwrap();
    let err = client.snapshot();
    assert!(err.is_err());
    assert!(format!("{:#}", err.err().unwrap()).contains("disabled"));
    server.shutdown();
}

#[test]
fn route_feedback_stats_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    let mut client = EagleClient::connect(&addr).unwrap();
    client.ping().unwrap();

    // generous budget -> strongest global model initially arbitrary, but a
    // decision must come back with a known model name
    let d = client.route("solve the equation 3x + 5 = 20", 1.0).unwrap();
    let registry = ModelRegistry::routerbench();
    assert!(registry.index_of(&d.model).is_some(), "unknown model {}", d.model);
    assert_eq!(registry.index_of(&d.model), Some(d.model_index));

    // tiny budget -> cheapest model
    let cheap = client.route("cheap question", 1e-9).unwrap();
    let cheapest = registry.cheapest_available().unwrap();
    assert_eq!(cheap.model_index, cheapest);

    // feedback: gpt-4 beat llama-2-13b-chat on a math prompt
    client
        .feedback("solve the equation 3x + 5 = 20", "gpt-4", "llama-2-13b-chat", 1.0)
        .unwrap();

    // barrier: the record is embedded on the applier, applied, published
    server.state.force_publish();
    assert_eq!(ingested(&server), 1);
    let snap = server.state.snapshots.load();
    assert_eq!(snap.history_len(), 1);
    let g = registry.index_of("gpt-4").unwrap();
    let l = registry.index_of("llama-2-13b-chat").unwrap();
    assert!(snap.global_ratings()[g] > snap.global_ratings()[l]);

    let (report, requests, feedback) = client.stats().unwrap();
    assert!(requests >= 2, "requests = {requests}");
    assert_eq!(feedback, 1);
    assert!(report.contains("route_latency"));
    assert!(report.contains("ingest:"));

    server.shutdown();
}

#[test]
fn feedback_moves_routing_decisions() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);
    let mut client = EagleClient::connect(&addr).unwrap();

    // hammer feedback: mistral-7b-chat (cheap) beats everything on "poetry"
    for i in 0..40 {
        let text = format!("write a short poem about the sea {i}");
        client.feedback(&text, "mistral-7b-chat", "gpt-4", 1.0).unwrap();
        client.feedback(&text, "mistral-7b-chat", "claude-v2", 1.0).unwrap();
    }
    // make everything ingested visible to the route path immediately
    server.state.force_publish();
    assert_eq!(ingested(&server), 80);
    assert_eq!(server.state.snapshots.load().history_len(), 80);

    // now route a poetry query with a huge budget: trained preference wins
    let d = client.route("write a short poem about the sea", 10.0).unwrap();
    assert_eq!(d.model, "mistral-7b-chat", "routing ignored feedback");

    server.shutdown();
}

#[test]
fn route_batch_matches_singles() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);
    let mut client = EagleClient::connect(&addr).unwrap();

    // seed some feedback so scores are non-uniform, then publish
    for i in 0..10 {
        client
            .feedback(&format!("math problem {i}"), "gpt-4", "claude-v2", 1.0)
            .unwrap();
    }
    server.state.force_publish();
    assert_eq!(ingested(&server), 10);

    let texts = [
        "solve the equation 3x + 5 = 20",
        "write a poem about the sea",
        "translate hello to french",
        "what is the capital of peru",
    ];
    let batch = client.route_batch(&texts, 0.5).unwrap();
    assert_eq!(batch.len(), texts.len());
    for (text, b) in texts.iter().zip(&batch) {
        let single = client.route(text, 0.5).unwrap();
        assert_eq!(single.model, b.model, "batch/single diverge for {text:?}");
        assert_eq!(single.model_index, b.model_index);
        assert_eq!(single.expected_cost, b.expected_cost);
    }

    // batch of one works, and oversized batches are rejected cleanly
    let one = client.route_batch(&["just one"], 0.5).unwrap();
    assert_eq!(one.len(), 1);
    let too_many: Vec<String> = (0..300).map(|i| format!("q{i}")).collect();
    let refs: Vec<&str> = too_many.iter().map(|s| s.as_str()).collect();
    assert!(client.route_batch(&refs, 0.5).is_err());

    server.shutdown();
}

#[test]
fn pipelined_routes_are_cobatched() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // write 8 pipelined route requests in one burst; the worker should
    // answer all of them, in order
    let mut burst = String::new();
    for i in 0..8 {
        burst.push_str(&format!(
            "{{\"op\":\"route\",\"text\":\"pipelined query {i}\",\"budget\":0.5}}\n"
        ));
    }
    writer.write_all(burst.as_bytes()).unwrap();
    for _ in 0..8 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "bad response: {line}");
        assert!(line.contains("\"model\""), "bad response: {line}");
    }
    assert!(server.state.metrics.requests.get() >= 8);
    server.shutdown();
}

#[test]
fn malformed_requests_get_errors_not_disconnects() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    for bad in [
        "this is not json\n",
        "{\"op\":\"bogus\"}\n",
        "{\"op\":\"route\",\"text\":\"x\"}\n",
        "{\"op\":\"feedback\",\"text\":\"x\",\"model_a\":\"gpt-4\",\"model_b\":\"gpt-4\",\"score_a\":1}\n",
        "{\"op\":\"feedback\",\"text\":\"x\",\"model_a\":\"gpt-4\",\"model_b\":\"nope\",\"score_a\":1}\n",
        "{\"op\":\"feedback\",\"text\":\"x\",\"model_a\":\"gpt-4\",\"model_b\":\"claude-v2\",\"score_a\":0.3}\n",
    ] {
        writer.write_all(bad.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "expected error for {bad:?}, got {line}");
    }

    // connection still usable
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"pong\":true"));

    server.shutdown();
}

#[test]
fn concurrent_clients_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    let handles: Vec<_> = (0..6)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = EagleClient::connect(&addr).unwrap();
                let mut models = Vec::new();
                for i in 0..10 {
                    let d = c.route(&format!("query {t}-{i} about topic {}", t % 3), 0.5).unwrap();
                    models.push(d.model);
                }
                models
            })
        })
        .collect();
    for h in handles {
        let models = h.join().unwrap();
        assert_eq!(models.len(), 10);
    }
    assert!(server.state.metrics.requests.get() >= 60);
    server.shutdown();
}

/// Hash-backed server with explicit admission limits (connection cap /
/// in-flight budget / idle-timeout tests).
fn start_hash_server_admission(
    dim: usize,
    workers: usize,
    admission: eagle::server::Admission,
) -> (Server, EmbedService, String) {
    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start_hash(
        dim,
        BatcherOptions { batch_window_us: 100, max_batch: 16 },
        metrics.clone(),
    );
    let registry = ModelRegistry::routerbench();
    let router = EagleRouter::new(EagleParams::default(), registry.len(), FlatStore::new(dim));
    let state = Arc::new(
        ServerState::builder(router, registry, service.handle(), metrics)
            .options(ServerOptions {
                epoch: EpochParams { publish_every: 16, publish_interval_ms: 5 },
                admission,
                ..Default::default()
            })
            .build(),
    );
    let server = Server::start(state, "127.0.0.1:0", workers).unwrap();
    let addr = server.addr.to_string();
    (server, service, addr)
}

#[test]
fn idle_keepalive_clients_do_not_starve_active_routes() {
    // regression: the old thread-per-connection pool gave every idle
    // keep-alive client a worker, so `workers` quiet sockets starved all
    // active clients (slow loris); the event loop parks them for free
    let workers = 2;
    let (server, _service, addr) = start_hash_server(32, 1, workers, None);
    let idle: Vec<std::net::TcpStream> = (0..workers + 4)
        .map(|_| std::net::TcpStream::connect(&addr).unwrap())
        .collect();
    // let the event loop register all of them before the active client
    std::thread::sleep(std::time::Duration::from_millis(50));
    let mut client = EagleClient::connect(&addr).unwrap();
    let t0 = std::time::Instant::now();
    let d = client.route("am i still being served?", 1.0).unwrap();
    assert!(!d.model.is_empty());
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "route behind {} idle connections took {:?}",
        idle.len(),
        t0.elapsed()
    );
    drop(idle);
    server.shutdown();
}

#[test]
fn inflight_budget_sheds_with_in_order_error_replies() {
    use std::io::{BufRead, BufReader, Write};

    use eagle::server::protocol::{parse_response, Response};

    let (server, _service, addr) = start_hash_server_admission(
        32,
        2,
        eagle::server::Admission { max_inflight: 2, ..Default::default() },
    );
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();

    // one pipelined burst far over the budget, written in a single
    // segment so it reaches the dispatcher as one unit
    const N: usize = 32;
    let mut burst = String::new();
    for i in 0..N {
        burst.push_str(&format!("{{\"op\":\"route\",\"text\":\"q{i}\",\"budget\":1.0}}\n"));
    }
    stream.write_all(burst.as_bytes()).unwrap();

    let mut reader = BufReader::new(stream);
    let mut routed = 0usize;
    let mut shed = 0usize;
    for _ in 0..N {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed early");
        match parse_response(&line).unwrap() {
            Response::Routed { .. } => routed += 1,
            Response::Error(msg) => {
                assert!(msg.contains("load shed"), "unexpected error: {msg}");
                shed += 1;
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }
    // every line got exactly one reply: admitted ones routed, the rest
    // shed — nothing dropped, nothing duplicated
    assert_eq!(routed + shed, N);
    assert!(routed >= 2, "budget admits at least one full unit slice");
    assert!(shed >= 1, "a {N}-line burst must overrun a budget of 2");
    assert_eq!(server.state.shed.shed_inflight.get() as usize, shed);

    // the per-reason taxonomy is visible through the stats op
    let mut client = EagleClient::connect(&addr).unwrap();
    let (report, _requests, _feedback) = client.stats().unwrap();
    assert!(report.contains("server: shed("), "no shed section in: {report}");
    assert!(report.contains(&format!("inflight={shed}")), "{report}");
    server.shutdown();
}

#[test]
fn connection_cap_refuses_with_load_shed_reply() {
    use std::io::{BufRead, BufReader};

    use eagle::server::protocol::{parse_response, Response};

    let (server, _service, addr) = start_hash_server_admission(
        32,
        2,
        eagle::server::Admission { max_connections: 2, ..Default::default() },
    );
    let c1 = std::net::TcpStream::connect(&addr).unwrap();
    let c2 = std::net::TcpStream::connect(&addr).unwrap();
    // accepts are FIFO, so the third connection hits the cap
    let c3 = std::net::TcpStream::connect(&addr).unwrap();
    c3.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(c3);
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    match parse_response(&line).unwrap() {
        Response::Error(msg) => assert!(msg.contains("load shed"), "{msg}"),
        other => panic!("expected a load-shed error line, got {other:?}"),
    }
    // ... and the refused socket closes
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0);
    assert!(server.state.shed.shed_conn_limit.get() >= 1);
    drop((c1, c2));
    server.shutdown();
}

#[test]
fn idle_timeout_reaps_quiet_connections() {
    use std::io::Read;

    let (server, _service, addr) = start_hash_server_admission(
        32,
        2,
        eagle::server::Admission { idle_timeout_ms: 100, ..Default::default() },
    );
    let mut conn = std::net::TcpStream::connect(&addr).unwrap();
    conn.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 16];
    // the sweep closes the quiet socket: the blocked read sees EOF
    let n = conn.read(&mut buf).unwrap();
    assert_eq!(n, 0, "expected an idle close, got {n} bytes");
    assert!(server.state.shed.closed_idle.get() >= 1);
    server.shutdown();
}

// ---------------------------------------------------------------- protocol v2

#[test]
fn hello_negotiates_v2_capabilities() {
    use eagle::server::protocol::{MAX_ROUTE_BATCH, OPS, POLICIES, PROTOCOL_VERSION};

    let (server, _service, addr) = start_hash_server(32, 1, 2, None);
    let mut client = EagleClient::connect(&addr).unwrap();
    let hello = client.hello().unwrap();
    assert_eq!(hello.version, PROTOCOL_VERSION);
    assert_eq!(hello.ops, OPS.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    assert_eq!(hello.policies, POLICIES.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    assert_eq!(hello.max_route_batch, MAX_ROUTE_BATCH);
    server.shutdown();
}

#[test]
fn v2_per_query_policy_specs_route() {
    use eagle::coordinator::policy::PolicySpec;

    let (server, _service, addr) = start_hash_server(32, 1, 2, None);
    let registry = ModelRegistry::routerbench();
    let mut client = EagleClient::connect(&addr).unwrap();

    // cost-aware under a tiny budget still answers with a registry model
    let d = client
        .route_with("what is 2 + 2", Some(PolicySpec::CostAware { budget: 1e-6 }))
        .unwrap();
    assert!(registry.index_of(&d.model).is_some(), "unknown model {}", d.model);

    // threshold 1.0 can never clear the logistic win-prob: weak arm (cheapest)
    let weak = client
        .route_with("routine lookup", Some(PolicySpec::Threshold { threshold: 1.0 }))
        .unwrap();
    assert_eq!(weak.model_index, registry.cheapest_available().unwrap());

    // threshold 0.0 always clears it: strong arm, a strictly pricier model
    let strong = client
        .route_with("prove the lemma", Some(PolicySpec::Threshold { threshold: 0.0 }))
        .unwrap();
    assert!(
        strong.expected_cost > weak.expected_cost,
        "strong arm {} ({}) should out-price weak arm {} ({})",
        strong.model,
        strong.expected_cost,
        weak.model,
        weak.expected_cost,
    );

    // spec: None defers to the server default (unbounded here): still routes
    let d = client.route_with("open-ended essay", None).unwrap();
    assert!(registry.index_of(&d.model).is_some());

    // batch variant carries the spec across every text in the batch
    let batch = client
        .route_batch_with(
            &["q one", "q two", "q three"],
            Some(PolicySpec::Threshold { threshold: 1.0 }),
        )
        .unwrap();
    assert_eq!(batch.len(), 3);
    for b in &batch {
        assert_eq!(b.model_index, registry.cheapest_available().unwrap());
    }
    server.shutdown();
}

/// v1 lines must keep working bit-identically next to their v2
/// equivalents, while v2 is strict about fields and versions.
#[test]
fn v2_strict_fields_and_v1_compat_on_the_wire() {
    use std::io::{BufRead, BufReader, Write};

    let (server, _service, addr) = start_hash_server(32, 1, 2, None);
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut ask = |line: &str| {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply
    };

    // the same route, spelled v1 and v2: byte-identical replies
    let v1 = ask(r#"{"op":"route","text":"compare the two sorts","budget":0.5}"#);
    let v2 = ask(r#"{"v":2,"op":"route","text":"compare the two sorts","budget":0.5}"#);
    assert!(v1.contains("\"ok\":true"), "v1 route failed: {v1}");
    assert_eq!(v1, v2, "v2 budget route must match the v1 wire reply");

    // v1 stays lenient about stray fields (old clients keep working)...
    let lenient = ask(r#"{"op":"route","text":"legacy line","budget":0.5,"stray":1}"#);
    assert!(lenient.contains("\"ok\":true"), "v1 must ignore stray fields: {lenient}");

    // ...v2 rejects them loudly
    let strict = ask(r#"{"v":2,"op":"route","text":"x","budget":0.5,"stray":1}"#);
    assert!(strict.contains("\"ok\":false"), "v2 must reject stray fields: {strict}");
    assert!(strict.contains("unknown field"), "{strict}");

    // future versions are refused, not half-parsed
    let future = ask(r#"{"v":3,"op":"ping"}"#);
    assert!(future.contains("\"ok\":false") && future.contains("unsupported"), "{future}");

    // the threshold policy demands its knob
    let incomplete = ask(r#"{"v":2,"op":"route","text":"x","policy":"threshold"}"#);
    assert!(incomplete.contains("\"ok\":false"), "{incomplete}");

    // and the connection survives it all
    let pong = ask(r#"{"v":2,"op":"ping"}"#);
    assert!(pong.contains("\"pong\":true"), "{pong}");
    server.shutdown();
}
