//! Full-stack serving tests: TCP server + PJRT embedder + Eagle router.
//! Skipped when artifacts are missing (run `make artifacts`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use eagle::config::{EagleParams, EpochParams};
use eagle::coordinator::registry::ModelRegistry;
use eagle::coordinator::router::EagleRouter;
use eagle::embedding::{BatcherOptions, EmbedService};
use eagle::metrics::Metrics;
use eagle::runtime::Runtime;
use eagle::server::client::EagleClient;
use eagle::server::{Server, ServerState};
use eagle::vectordb::flat::FlatStore;

fn artifacts_dir() -> Option<PathBuf> {
    if !Runtime::available() {
        eprintln!(
            "skipping: PJRT runtime not compiled in (build with `--features pjrt` \
             in an environment that provides the xla crate)"
        );
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Feedback records visible to the writer (ingested, published or not).
fn ingested(server: &Server) -> usize {
    server.state.writer.lock().unwrap().history_len()
}

fn start_server(dir: &Path) -> (Server, EmbedService, String) {
    start_server_with_snapshot(dir, None)
}

fn start_server_with_snapshot(
    dir: &Path,
    snapshot: Option<std::path::PathBuf>,
) -> (Server, EmbedService, String) {
    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start(
        dir,
        BatcherOptions { batch_window_us: 100, max_batch: 16 },
        metrics.clone(),
    )
    .unwrap();
    let registry = ModelRegistry::routerbench();
    let router = EagleRouter::new(EagleParams::default(), registry.len(), FlatStore::new(256));
    // tight cadence so feedback becomes routable quickly in tests
    let epoch = EpochParams { publish_every: 8, publish_interval_ms: 10 };
    let mut state = ServerState::with_epoch(router, registry, service.handle(), metrics, epoch);
    if let Some(p) = snapshot {
        state = state.with_snapshot_path(p);
    }
    let state = Arc::new(state);
    let server = Server::start(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr.to_string();
    (server, service, addr)
}

#[test]
fn snapshot_op_persists_live_state() {
    let Some(dir) = artifacts_dir() else { return };
    let snap_path = std::env::temp_dir()
        .join(format!("eagle_server_snap_{}.json", std::process::id()));
    let (server, _service, addr) = start_server_with_snapshot(&dir, Some(snap_path.clone()));
    let mut client = EagleClient::connect(&addr).unwrap();

    for i in 0..5 {
        client
            .feedback(&format!("snapshot test prompt {i}"), "gpt-4", "mistral-7b-chat", 1.0)
            .unwrap();
    }
    // wait for applier
    for _ in 0..50 {
        if ingested(&server) == 5 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    let (path, entries) = client.snapshot().unwrap();
    assert_eq!(path, snap_path.display().to_string());
    assert_eq!(entries, 5);

    // the snapshot restores to an equivalent router
    let restored = eagle::coordinator::state::load_from(&snap_path).unwrap();
    assert_eq!(restored.feedback_len(), 5);
    let g = ModelRegistry::routerbench().index_of("gpt-4").unwrap();
    let m = ModelRegistry::routerbench().index_of("mistral-7b-chat").unwrap();
    assert!(restored.global().ratings()[g] > restored.global().ratings()[m]);

    std::fs::remove_file(&snap_path).ok();
    server.shutdown();
}

#[test]
fn snapshot_op_disabled_without_path() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);
    let mut client = EagleClient::connect(&addr).unwrap();
    let err = client.snapshot();
    assert!(err.is_err());
    assert!(format!("{:#}", err.err().unwrap()).contains("disabled"));
    server.shutdown();
}

#[test]
fn route_feedback_stats_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    let mut client = EagleClient::connect(&addr).unwrap();
    client.ping().unwrap();

    // generous budget -> strongest global model initially arbitrary, but a
    // decision must come back with a known model name
    let d = client.route("solve the equation 3x + 5 = 20", 1.0).unwrap();
    let registry = ModelRegistry::routerbench();
    assert!(registry.index_of(&d.model).is_some(), "unknown model {}", d.model);
    assert_eq!(registry.index_of(&d.model), Some(d.model_index));

    // tiny budget -> cheapest model
    let cheap = client.route("cheap question", 1e-9).unwrap();
    let cheapest = registry.cheapest_available().unwrap();
    assert_eq!(cheap.model_index, cheapest);

    // feedback: gpt-4 beat llama-2-13b-chat on a math prompt
    client
        .feedback("solve the equation 3x + 5 = 20", "gpt-4", "llama-2-13b-chat", 1.0)
        .unwrap();

    // give the applier a moment, then check state moved
    std::thread::sleep(std::time::Duration::from_millis(300));
    {
        let writer = server.state.writer.lock().unwrap();
        assert_eq!(writer.router().feedback_len(), 1);
        let g = registry.index_of("gpt-4").unwrap();
        let l = registry.index_of("llama-2-13b-chat").unwrap();
        let ratings = writer.router().global().ratings();
        assert!(ratings[g] > ratings[l]);
    }
    // the stale-publish beat must make the record visible to readers
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert_eq!(server.state.snapshots.load().history_len(), 1);

    let (report, requests, feedback) = client.stats().unwrap();
    assert!(requests >= 2, "requests = {requests}");
    assert_eq!(feedback, 1);
    assert!(report.contains("route_latency"));

    server.shutdown();
}

#[test]
fn feedback_moves_routing_decisions() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);
    let mut client = EagleClient::connect(&addr).unwrap();

    // hammer feedback: mistral-7b-chat (cheap) beats everything on "poetry"
    for i in 0..40 {
        let text = format!("write a short poem about the sea {i}");
        client.feedback(&text, "mistral-7b-chat", "gpt-4", 1.0).unwrap();
        client.feedback(&text, "mistral-7b-chat", "claude-v2", 1.0).unwrap();
    }
    // wait for the applier to drain
    for _ in 0..50 {
        if ingested(&server) == 80 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert_eq!(ingested(&server), 80);
    // make everything ingested visible to the route path immediately
    server.state.force_publish();
    assert_eq!(server.state.snapshots.load().history_len(), 80);

    // now route a poetry query with a huge budget: trained preference wins
    let d = client.route("write a short poem about the sea", 10.0).unwrap();
    assert_eq!(d.model, "mistral-7b-chat", "routing ignored feedback");

    server.shutdown();
}

#[test]
fn route_batch_matches_singles() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);
    let mut client = EagleClient::connect(&addr).unwrap();

    // seed some feedback so scores are non-uniform, then publish
    for i in 0..10 {
        client
            .feedback(&format!("math problem {i}"), "gpt-4", "claude-v2", 1.0)
            .unwrap();
    }
    for _ in 0..50 {
        if ingested(&server) == 10 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    server.state.force_publish();

    let texts = [
        "solve the equation 3x + 5 = 20",
        "write a poem about the sea",
        "translate hello to french",
        "what is the capital of peru",
    ];
    let batch = client.route_batch(&texts, 0.5).unwrap();
    assert_eq!(batch.len(), texts.len());
    for (text, b) in texts.iter().zip(&batch) {
        let single = client.route(text, 0.5).unwrap();
        assert_eq!(single.model, b.model, "batch/single diverge for {text:?}");
        assert_eq!(single.model_index, b.model_index);
        assert_eq!(single.expected_cost, b.expected_cost);
    }

    // batch of one works, and oversized batches are rejected cleanly
    let one = client.route_batch(&["just one"], 0.5).unwrap();
    assert_eq!(one.len(), 1);
    let too_many: Vec<String> = (0..300).map(|i| format!("q{i}")).collect();
    let refs: Vec<&str> = too_many.iter().map(|s| s.as_str()).collect();
    assert!(client.route_batch(&refs, 0.5).is_err());

    server.shutdown();
}

#[test]
fn pipelined_routes_are_cobatched() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // write 8 pipelined route requests in one burst; the worker should
    // answer all of them, in order
    let mut burst = String::new();
    for i in 0..8 {
        burst.push_str(&format!(
            "{{\"op\":\"route\",\"text\":\"pipelined query {i}\",\"budget\":0.5}}\n"
        ));
    }
    writer.write_all(burst.as_bytes()).unwrap();
    for _ in 0..8 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "bad response: {line}");
        assert!(line.contains("\"model\""), "bad response: {line}");
    }
    assert!(server.state.metrics.requests.get() >= 8);
    server.shutdown();
}

#[test]
fn malformed_requests_get_errors_not_disconnects() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    for bad in [
        "this is not json\n",
        "{\"op\":\"bogus\"}\n",
        "{\"op\":\"route\",\"text\":\"x\"}\n",
        "{\"op\":\"feedback\",\"text\":\"x\",\"model_a\":\"gpt-4\",\"model_b\":\"gpt-4\",\"score_a\":1}\n",
        "{\"op\":\"feedback\",\"text\":\"x\",\"model_a\":\"gpt-4\",\"model_b\":\"nope\",\"score_a\":1}\n",
        "{\"op\":\"feedback\",\"text\":\"x\",\"model_a\":\"gpt-4\",\"model_b\":\"claude-v2\",\"score_a\":0.3}\n",
    ] {
        writer.write_all(bad.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "expected error for {bad:?}, got {line}");
    }

    // connection still usable
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"pong\":true"));

    server.shutdown();
}

#[test]
fn concurrent_clients_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let (server, _service, addr) = start_server(&dir);

    let handles: Vec<_> = (0..6)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = EagleClient::connect(&addr).unwrap();
                let mut models = Vec::new();
                for i in 0..10 {
                    let d = c.route(&format!("query {t}-{i} about topic {}", t % 3), 0.5).unwrap();
                    models.push(d.model);
                }
                models
            })
        })
        .collect();
    for h in handles {
        let models = h.join().unwrap();
        assert_eq!(models.len(), 10);
    }
    assert!(server.state.metrics.requests.get() >= 60);
    server.shutdown();
}
