//! Compaction + GC under live followers and crashes: property tests that
//! the background compactor (binary-counter merges, v1 → v2 upgrades)
//! and manifest-generation GC never lose records, never break follower
//! convergence, and never leave an inconsistent store behind a crash —
//! the tail protocol's "restart from manifest" signal is a typed event,
//! not a panic.

use std::path::PathBuf;
use std::time::Duration;

use eagle::config::{EagleParams, EpochParams, ShardParams};
use eagle::coordinator::durable::{DurableLaneWriter, DurableOptions, DurableStore, StoreMeta};
use eagle::coordinator::replica::Follower;
use eagle::coordinator::router::Observation;
use eagle::coordinator::sharded::ShardedRouter;
use eagle::elo::{Comparison, Outcome};
use eagle::util::{l2_normalize, Rng};

const DIM: usize = 16;
const N_MODELS: usize = 5;
const HASH_SEED: u64 = 0xEA61E;

fn unit(rng: &mut Rng) -> Vec<f32> {
    let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn rand_obs(rng: &mut Rng) -> Observation {
    let a = rng.below(N_MODELS);
    let mut b = rng.below(N_MODELS - 1);
    if b >= a {
        b += 1;
    }
    let outcome = match rng.below(3) {
        0 => Outcome::WinA,
        1 => Outcome::WinB,
        _ => Outcome::Draw,
    };
    Observation::single(unit(rng), Comparison { a, b, outcome })
}

fn cadence() -> EpochParams {
    EpochParams { publish_every: 16, publish_interval_ms: 10_000 }
}

fn tail_cadence() -> EpochParams {
    EpochParams { publish_every: 1, publish_interval_ms: 10_000 }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("eagle_compaction_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn meta(k: usize) -> StoreMeta {
    StoreMeta {
        params: EagleParams::default(),
        n_models: N_MODELS,
        dim: DIM,
        shards: ShardParams { count: k, hash_seed: HASH_SEED },
    }
}

/// One leader-side ingest step against a live store (append + seal on a
/// byte cadence driven by tiny `seal_bytes`).
fn leader_step(leader: &mut ShardedRouter, writers: &mut [DurableLaneWriter], obs: Observation) {
    let shard = leader.shard_for(&obs.embedding);
    let gid = leader.next_global_id();
    leader.observe(obs.clone());
    writers[shard].append(gid, &obs).unwrap();
}

fn sync_all(writers: &mut [DurableLaneWriter]) {
    for w in writers.iter_mut() {
        w.sync().unwrap();
    }
}

fn quiesce(f: &mut Follower) {
    for _ in 0..200 {
        let s = f.poll().expect("tail poll");
        if s.applied == 0 && s.lag_bytes == 0 && s.pending_folds == 0 && !s.restarted {
            return;
        }
    }
    panic!("follower failed to drain a quiescent store");
}

fn assert_follower_matches(leader: &mut ShardedRouter, f: &Follower, rng: &mut Rng, what: &str) {
    leader.publish_all();
    let a = leader.handle().load();
    let b = f.handle().load();
    assert_eq!(a.store_len(), b.store_len(), "{what}: store length");
    assert_eq!(a.global_ratings(), b.global_ratings(), "{what}: global ratings");
    let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(rng)).collect();
    assert_eq!(a.score_batch(&queries), b.score_batch(&queries), "{what}: score_batch");
}

#[test]
fn followers_survive_compaction_and_zero_grace_gc() {
    // the GC-vs-follower race property: K followers attached at
    // adversarial offsets (one from the very start, one mid-storm, one
    // post-compaction) tail a leader that seals aggressively, compacts
    // repeatedly, and GCs with ZERO grace — the most hostile schedule the
    // public API can produce. Every follower must converge bit-identical
    // and no poll may ever crash on a vanished file.
    for &k in &[1usize, 3] {
        let mut rng = Rng::new(0xC0117AC7 + k as u64 * 13);
        let dir = tmp_dir(&format!("race_k{k}"));
        let opts = DurableOptions { seal_bytes: 700, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(k), opts).unwrap();
        let mut writers: Vec<DurableLaneWriter> =
            (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
        let mut leader =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);

        let mut followers: Vec<Follower> = vec![Follower::open(&dir, tail_cadence()).unwrap()];
        for i in 0..480usize {
            leader_step(&mut leader, &mut writers, rand_obs(&mut rng));
            if i == 160 {
                sync_all(&mut writers);
                followers.push(Follower::open(&dir, tail_cadence()).unwrap());
            }
            if i % 90 == 89 {
                // compact + delete superseded files immediately: any
                // follower whose cursor still names them must take the
                // typed restart, never an error
                sync_all(&mut writers);
                store.compact_once();
                store.gc_retired(Duration::ZERO);
            }
            if i == 300 {
                sync_all(&mut writers);
                followers.push(Follower::open(&dir, tail_cadence()).unwrap());
            }
            // adversarial offsets: each follower polls on its own phase
            for (j, f) in followers.iter_mut().enumerate() {
                if i % (11 + 7 * j) == j {
                    f.poll().expect("mid-storm poll must not crash");
                }
            }
        }
        sync_all(&mut writers);
        // one more full cycle with everything quiescent
        store.compact_once();
        store.gc_retired(Duration::ZERO);
        for (j, f) in followers.iter_mut().enumerate() {
            quiesce(f);
            assert_follower_matches(&mut leader, f, &mut rng, &format!("k={k} follower {j}"));
        }
        // compaction must actually have happened for this to test anything
        assert!(store.compaction_stats().merges.get() > 0, "no merges at k={k}");
        assert!(store.compaction_stats().gc_files.get() > 0, "no GC at k={k}");
        // binary-counter fixpoint: per-shard file count stays logarithmic
        // in the corpus (~480 records / 700-byte seals would be dozens of
        // files unmerged)
        for (shard, n) in store.segment_counts().iter().enumerate() {
            assert!(*n <= 12, "k={k} shard {shard}: {n} segment files after compaction");
        }
        drop(followers);
        drop(writers);
        drop(store);
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn gc_mid_poll_is_a_typed_restart_not_a_crash() {
    // deterministic reproduction of the race the property test can only
    // make likely: a follower holds a manifest cut that names a segment
    // file, and the file vanishes before the follower opens it. The poll
    // must report `restarted`, count a manifest restart, and converge on
    // a later poll once the current manifest is visible — exactly what a
    // racing GC produces.
    let k = 2usize;
    let mut rng = Rng::new(0x6C1DF11);
    let dir = tmp_dir("typed_restart");
    let opts = DurableOptions { seal_bytes: 600, fsync: false, mmap: true };
    let store = DurableStore::create(&dir, meta(k), opts).unwrap();
    let mut writers: Vec<DurableLaneWriter> =
        (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
    let mut leader =
        ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);

    for _ in 0..80 {
        leader_step(&mut leader, &mut writers, rand_obs(&mut rng));
    }
    sync_all(&mut writers);
    let mut f = Follower::open(&dir, tail_cadence()).unwrap();
    quiesce(&mut f);

    // second wave seals fresh segments the follower has not applied yet
    for _ in 0..120 {
        leader_step(&mut leader, &mut writers, rand_obs(&mut rng));
    }
    for w in writers.iter_mut() {
        w.seal().unwrap();
    }

    // hide every not-yet-applied segment file: the follower's next poll
    // reads the manifest naming them, then finds them gone mid-pass
    let hidden: Vec<(PathBuf, PathBuf)> = (0..k)
        .flat_map(|shard| {
            let shard_dir = dir.join(format!("shard-{shard}"));
            std::fs::read_dir(&shard_dir)
                .unwrap()
                .flatten()
                .map(|e| e.path())
                .filter(|p| p.extension().is_some_and(|x| x == "seg"))
                .map(|p| {
                    let away = p.with_extension("seg.hidden");
                    (p, away)
                })
                .collect::<Vec<_>>()
        })
        .collect();
    // only hide segments past the follower's frontier (the applied ones
    // are skipped by gid range and never opened)
    for (p, away) in &hidden {
        std::fs::rename(p, away).unwrap();
    }
    let restarts_before = f.metrics().manifest_restarts.get();
    let stats = f.poll().expect("poll over vanished segments must not error");
    assert!(stats.restarted, "vanished segment must surface as a restart");
    assert!(f.metrics().manifest_restarts.get() > restarts_before, "restart must be counted");

    // the files come back (equivalently: a newer manifest re-covers the
    // range) and the follower converges with nothing lost
    for (p, away) in &hidden {
        std::fs::rename(away, p).unwrap();
    }
    quiesce(&mut f);
    assert_follower_matches(&mut leader, &f, &mut rng, "after typed restart");

    drop(writers);
    drop(store);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_compaction_crash_sweeps_orphans_and_recovers() {
    // crash window: the compactor dies after writing (part of) a merged
    // segment file but before the manifest swap publishes it. On the
    // next open the unpublished file is an orphan — it must be swept,
    // the manifest must still parse, and recovery must rebuild exactly
    // the pre-crash corpus.
    let k = 2usize;
    let mut rng = Rng::new(0x70C4A54);
    let dir = tmp_dir("torn_merge");
    let opts = DurableOptions { seal_bytes: 500, fsync: false, mmap: true };
    let expect: usize = 140;
    {
        let store = DurableStore::create(&dir, meta(k), opts.clone()).unwrap();
        let mut writers: Vec<DurableLaneWriter> =
            (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
        let mut leader =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);
        for _ in 0..expect {
            leader_step(&mut leader, &mut writers, rand_obs(&mut rng));
        }
        sync_all(&mut writers);
    }

    // simulate the torn merge: an unreferenced segment file at a
    // reserved-but-unpublished id, in several crash shapes (partial
    // write, garbage, empty)
    for (i, bytes) in
        [&b"EAGS\x02\x00\x00\x00 torn"[..], &b"garbage"[..], &b""[..]].iter().enumerate()
    {
        let orphan = dir.join(format!("shard-0/seg-{:08}.seg", 90 + i));
        std::fs::write(&orphan, bytes).unwrap();
        let tmp_orphan = dir.join(format!("shard-1/.seg-{:08}.seg.tmp", 91 + i));
        std::fs::write(&tmp_orphan, b"half-written merge").unwrap();

        let (store, recovery) = DurableStore::open(&dir, opts.clone()).unwrap();
        assert_eq!(recovery.total_records(), expect, "crash shape {i} lost records");
        assert!(!orphan.exists(), "crash shape {i}: orphan survived the sweep");
        assert!(!tmp_orphan.exists(), "crash shape {i}: tmp orphan survived the sweep");
        // the swept store is fully operational: compaction + GC still run
        store.compact_once();
        store.gc_retired(Duration::ZERO);
        drop(store);
    }

    // final reopen: post-crash, post-compaction state replays cleanly
    let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
    assert_eq!(recovery.total_records(), expect);
    let router = recovery.into_router(cadence()).unwrap();
    assert_eq!(router.store_len(), expect);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mixed_format_store_tails_and_recovers_identically() {
    // a store that grew up under v1 (mmap off), then kept growing under
    // v2, then compacted (upgrading stragglers) must be one seamless
    // corpus to both recovery and a tailing follower.
    let k = 2usize;
    let mut rng = Rng::new(0x313D);
    let dir = tmp_dir("mixed");
    let v1_opts = DurableOptions { seal_bytes: 600, fsync: false, mmap: false };
    let v2_opts = DurableOptions { seal_bytes: 600, fsync: false, mmap: true };

    let mut leader =
        ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);
    {
        let store = DurableStore::create(&dir, meta(k), v1_opts).unwrap();
        let mut writers: Vec<DurableLaneWriter> =
            (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
        for _ in 0..100 {
            leader_step(&mut leader, &mut writers, rand_obs(&mut rng));
        }
        sync_all(&mut writers);
    }
    let (store, recovery) = DurableStore::open(&dir, v2_opts).unwrap();
    assert_eq!(recovery.total_records(), 100);
    drop(recovery);
    let mut writers: Vec<DurableLaneWriter> =
        (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
    // NOTE: the recovery above replayed into a throwaway; `leader` is the
    // live reference and the lane writers continue the same gid space.
    for _ in 0..120 {
        leader_step(&mut leader, &mut writers, rand_obs(&mut rng));
    }
    sync_all(&mut writers);

    // follower over the mixed store, with compaction upgrading mid-tail
    let mut f = Follower::open(&dir, tail_cadence()).unwrap();
    f.poll().unwrap();
    while store.compact_once() > 0 {}
    store.gc_retired(Duration::ZERO);
    quiesce(&mut f);
    assert_follower_matches(&mut leader, &f, &mut rng, "mixed-format follower");
    assert!(
        store.compaction_stats().merges.get() + store.compaction_stats().upgrades.get() > 0,
        "mixed store must have compacted or upgraded something"
    );

    drop(writers);
    drop(store);
    std::fs::remove_dir_all(&dir).ok();
}
