//! Robustness & extension coverage: failure injection on the artifact
//! loading path, IVF-backed routing, config files on disk, snapshot
//! corruption. No built artifacts required.

use std::path::PathBuf;
use std::sync::Arc;

use eagle::config::{Config, EagleParams};
use eagle::coordinator::router::{EagleRouter, Observation};
use eagle::coordinator::Router;
use eagle::elo::{Comparison, Outcome};
use eagle::embedding::{BatcherOptions, EmbedService};
use eagle::metrics::Metrics;
use eagle::runtime::{Manifest, Runtime};
use eagle::util::{l2_normalize, Rng};
use eagle::vectordb::flat::FlatStore;
use eagle::vectordb::ivf::{IvfIndex, IvfParams};
use eagle::vectordb::ReadIndex;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("eagle_robust_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn unit(rng: &mut Rng, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn rand_obs(rng: &mut Rng, dim: usize, n: usize) -> Vec<Observation> {
    (0..n)
        .map(|_| {
            let a = rng.below(5);
            let mut b = rng.below(4);
            if b >= a {
                b += 1;
            }
            let outcome = match rng.below(3) {
                0 => Outcome::WinA,
                1 => Outcome::WinB,
                _ => Outcome::Draw,
            };
            Observation {
                embedding: unit(rng, dim),
                comparisons: vec![Comparison { a, b, outcome }],
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// failure injection: artifact loading

#[test]
fn embed_service_fails_cleanly_without_manifest() {
    let dir = tmpdir("nomanifest");
    let err = EmbedService::start(&dir, BatcherOptions::default(), Arc::new(Metrics::new()));
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("manifest.json"), "unhelpful error: {msg}");
}

#[test]
fn runtime_rejects_corrupt_manifest_json() {
    let dir = tmpdir("badjson");
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn runtime_rejects_missing_hlo_file() {
    let dir = tmpdir("missinghlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format_version":1,
            "model":{"vocab_size":64,"seq_len":8,"d_model":16,"n_heads":2,
                     "n_layers":1,"d_ff":32,"seed":1},
            "embed_batch_sizes":[1],"scorer_shapes":[],
            "artifacts":[{"name":"embed_b1","kind":"embed",
                          "file":"embed_b1.hlo.txt","batch":1,
                          "seq_len":8,"out_dim":16}],
            "weights":{"file":"weights.bin","dtype":"f32_le","total_elems":4,
                       "sha256":"x","tensors":[
                       {"name":"a","shape":[4],"offset_elems":0}]}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("weights.bin"), vec![0u8; 16]).unwrap();
    let err = Runtime::load(&dir);
    assert!(err.is_err());
}

#[test]
fn runtime_rejects_garbage_hlo_text() {
    let dir = tmpdir("garbagehlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format_version":1,
            "model":{"vocab_size":64,"seq_len":8,"d_model":16,"n_heads":2,
                     "n_layers":1,"d_ff":32,"seed":1},
            "embed_batch_sizes":[1],"scorer_shapes":[],
            "artifacts":[{"name":"embed_b1","kind":"embed",
                          "file":"embed_b1.hlo.txt","batch":1,
                          "seq_len":8,"out_dim":16}],
            "weights":{"file":"weights.bin","dtype":"f32_le","total_elems":4,
                       "sha256":"x","tensors":[
                       {"name":"a","shape":[4],"offset_elems":0}]}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("weights.bin"), vec![0u8; 16]).unwrap();
    std::fs::write(dir.join("embed_b1.hlo.txt"), "this is not hlo").unwrap();
    assert!(Runtime::load(&dir).is_err());
}

// ---------------------------------------------------------------------------
// IVF-backed router (scaling path)

#[test]
fn ivf_router_agrees_with_flat_router() {
    let mut rng = Rng::new(41);
    let dim = 32;
    let obs = rand_obs(&mut rng, dim, 600);

    let flat = EagleRouter::fit(
        EagleParams::default(),
        5,
        FlatStore::with_capacity(dim, obs.len()),
        &obs,
    );
    let vectors: Vec<Vec<f32>> = obs.iter().map(|o| o.embedding.clone()).collect();
    let payloads = obs
        .iter()
        .map(|o| eagle::vectordb::Feedback { comparisons: o.comparisons.clone() })
        .collect();
    let params = IvfParams { n_cells: 16, nprobe: 16, kmeans_iters: 6, seed: 2 };
    let ivf_store = IvfIndex::build(dim, &vectors, payloads, params);
    let mut ivf = EagleRouter::new(EagleParams::default(), 5, ivf_store);
    // align the global tables (store contents already match)
    ivf.restore_global(flat.global().ratings().as_slice(), flat.feedback_len());

    // exhaustive probe => identical neighbor sets => identical scores
    let mut agreements = 0;
    for i in 0..50 {
        let q = unit(&mut Rng::new(1000 + i), dim);
        let sf = flat.scores(&q);
        let si = ivf.scores(&q);
        let top_f = sf.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        let top_i = si.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if top_f == top_i {
            agreements += 1;
        }
    }
    assert!(agreements >= 45, "flat/ivf top-choice agreement {agreements}/50");
}

#[test]
fn ivf_router_online_insert() {
    let mut rng = Rng::new(43);
    let dim = 16;
    let store = IvfIndex::new(dim, IvfParams::default());
    let mut router = EagleRouter::new(EagleParams::default(), 5, store);
    for obs in rand_obs(&mut rng, dim, 100) {
        router.observe(obs);
    }
    assert_eq!(router.store().len(), 100);
    let q = unit(&mut rng, dim);
    assert_eq!(router.scores(&q).len(), 5);
}

// ---------------------------------------------------------------------------
// config file on disk

#[test]
fn config_file_roundtrip() {
    let dir = tmpdir("config");
    let path = dir.join("eagle.toml");
    std::fs::write(
        &path,
        "# test config\n[eagle]\np = 0.25\nn_neighbors = 10\n\n[server]\nworkers = 2\n",
    )
    .unwrap();
    let cfg = Config::load(Some(&path), &[]).unwrap();
    assert_eq!(cfg.eagle.p, 0.25);
    assert_eq!(cfg.eagle.n_neighbors, 10);
    assert_eq!(cfg.server.workers, 2);
    // CLI override beats file
    let cfg2 = Config::load(Some(&path), &[("eagle.p".into(), "0.75".into())]).unwrap();
    assert_eq!(cfg2.eagle.p, 0.75);
}

#[test]
fn config_file_invalid_values_rejected() {
    let dir = tmpdir("badconfig");
    let path = dir.join("eagle.toml");
    std::fs::write(&path, "[eagle]\np = 1.5\n").unwrap();
    assert!(Config::load(Some(&path), &[]).is_err());
}

// ---------------------------------------------------------------------------
// snapshot corruption

#[test]
fn snapshot_corruption_detected() {
    let mut rng = Rng::new(47);
    let obs = rand_obs(&mut rng, 8, 40);
    let router = EagleRouter::fit(EagleParams::default(), 5, FlatStore::new(8), &obs);
    let snap = eagle::coordinator::state::snapshot(&router);

    // truncation
    assert!(eagle::coordinator::state::restore(&snap[..snap.len() / 2]).is_err());
    // rating arity mismatch
    let bad = snap.replace("\"n_models\":5", "\"n_models\":7");
    assert!(eagle::coordinator::state::restore(&bad).is_err());
}

#[test]
fn snapshot_restore_after_many_updates() {
    let mut rng = Rng::new(53);
    let mut router = EagleRouter::fit(
        EagleParams::default(),
        5,
        FlatStore::new(8),
        &rand_obs(&mut rng, 8, 50),
    );
    for chunk in rand_obs(&mut rng, 8, 200).chunks(10) {
        router.update(chunk);
    }
    let restored =
        eagle::coordinator::state::restore(&eagle::coordinator::state::snapshot(&router))
            .unwrap();
    assert_eq!(restored.feedback_len(), router.feedback_len());
    let q = unit(&mut rng, 8);
    let a = router.scores(&q);
    let b = restored.scores(&q);
    for m in 0..5 {
        assert!((a[m] - b[m]).abs() < 1e-6);
    }
}
