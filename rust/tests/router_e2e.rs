//! End-to-end router pipeline tests over the synthetic benchmark
//! (HashEmbedder rig: artifact-free, fast, deterministic). The paper's
//! qualitative claims are asserted here at small scale; full-scale numbers
//! live in the bench targets + EXPERIMENTS.md.

use eagle::baselines::knn::KnnPredictor;
use eagle::baselines::mlp::{MlpOptions, MlpPredictor};
use eagle::baselines::svm::{SvmOptions, SvmPredictor};
use eagle::baselines::QualityPredictor;
use eagle::config::EagleParams;
use eagle::coordinator::{PredictorRouter, Router};
use eagle::eval::harness::{bench_data_params, EmbedderRig, Experiment};
use eagle::eval::{oracle_curve, summed_auc};
use eagle::routerbench::DATASETS;

fn experiment(seed: u64, per_dataset: usize) -> Experiment {
    let rig = EmbedderRig::hash();
    Experiment::build(&bench_data_params(seed, per_dataset), &rig)
}

#[test]
fn eagle_beats_every_baseline_on_summed_auc() {
    let exp = experiment(11, 400);
    let cfg = eagle::config::Config::default();

    let mut sums = std::collections::BTreeMap::new();
    for si in 0..DATASETS.len() {
        // eagle
        let router = exp.fit_eagle(si, EagleParams::default(), 1.0);
        *sums.entry("eagle").or_insert(0.0) += exp.eval(&router, si).auc();
        // knn
        let mut knn = KnnPredictor::new(cfg.baselines.knn_neighbors);
        knn.fit(&exp.train_set_feedback(si, 1.0));
        *sums.entry("knn").or_insert(0.0) += exp.eval(&PredictorRouter::new(knn), si).auc();
        // svm
        let mut svm = SvmPredictor::new(SvmOptions::default());
        svm.fit(&exp.train_set_feedback(si, 1.0));
        *sums.entry("svm").or_insert(0.0) += exp.eval(&PredictorRouter::new(svm), si).auc();
        // mlp (reduced epochs for test speed)
        let mut mlp = MlpPredictor::new(MlpOptions { epochs: 25, ..Default::default() });
        mlp.fit(&exp.train_set_feedback(si, 1.0));
        *sums.entry("mlp").or_insert(0.0) += exp.eval(&PredictorRouter::new(mlp), si).auc();
    }
    let eagle = sums["eagle"];
    println!("summed AUC: {sums:?}");
    for (name, auc) in &sums {
        if *name != "eagle" {
            assert!(
                eagle > auc - 1e-9,
                "eagle ({eagle:.4}) must match-or-beat {name} ({auc:.4})"
            );
        }
    }
    // and strictly beat at least two of the three baselines
    let strictly = sums.iter().filter(|(n, a)| **n != "eagle" && eagle > **a).count();
    assert!(strictly >= 2, "eagle strictly beats only {strictly} baselines: {sums:?}");
}

#[test]
fn oracle_dominates_all_routers() {
    let exp = experiment(13, 250);
    for si in [0, 3, 5] {
        let router = exp.fit_eagle(si, EagleParams::default(), 1.0);
        let r_auc = exp.eval(&router, si).auc();
        let o_auc = oracle_curve(&exp.split(si).test, &exp.policy, DATASETS[si]).auc();
        assert!(o_auc >= r_auc - 1e-9, "oracle {o_auc} vs eagle {r_auc} on {si}");
    }
}

#[test]
fn combined_beats_both_ablations_in_aggregate() {
    // Fig 4a's shape: Eagle >= max(Eagle-Global, Eagle-Local) summed.
    let exp = experiment(17, 400);
    let mut full = Vec::new();
    let mut global = Vec::new();
    let mut local = Vec::new();
    for si in 0..DATASETS.len() {
        let mk = |p: f64| exp.fit_eagle(si, EagleParams { p, ..Default::default() }, 1.0);
        full.push(exp.eval(&mk(0.5), si));
        global.push(exp.eval(&mk(1.0), si));
        local.push(exp.eval(&mk(0.0), si));
    }
    let (f, g, l) = (summed_auc(&full), summed_auc(&global), summed_auc(&local));
    println!("full={f:.4} global={g:.4} local={l:.4}");
    assert!(f >= g - 0.02, "combined ({f}) much worse than global ({g})");
    assert!(f >= l - 0.02, "combined ({f}) much worse than local ({l})");
    // and strictly better than at least one ablation
    assert!(f > g || f > l, "combined adds nothing: f={f} g={g} l={l}");
}

#[test]
fn more_data_does_not_hurt_eagle() {
    // Fig 3b's shape: AUC at 100% >= AUC at 70% (up to noise), summed.
    let exp = experiment(19, 400);
    let mut auc70 = 0.0;
    let mut auc100 = 0.0;
    for si in 0..DATASETS.len() {
        let r70 = exp.fit_eagle(si, EagleParams::default(), 0.7);
        let r100 = exp.fit_eagle(si, EagleParams::default(), 1.0);
        auc70 += exp.eval(&r70, si).auc();
        auc100 += exp.eval(&r100, si).auc();
    }
    println!("sum AUC 70%={auc70:.4} 100%={auc100:.4}");
    assert!(auc100 >= auc70 - 0.05, "quality collapsed with more data");
}

#[test]
fn incremental_update_is_much_faster_than_baseline_retrain() {
    // Table 3a's shape at test scale: Eagle's +15% update beats MLP
    // retraining by a wide margin.
    use std::time::Instant;
    let exp = experiment(23, 400);
    let si = 0;

    // Eagle: fit on 70%, time the +15% increment.
    let mut router = exp.fit_eagle(si, EagleParams::default(), 0.7);
    let obs85 = exp.observations(si, 0.85);
    let new: Vec<_> = obs85[exp.observations(si, 0.7).len()..].to_vec();
    let t0 = Instant::now();
    router.update(&new);
    let eagle_update = t0.elapsed().as_secs_f64();

    // MLP: fit on 70%, time the retrain at 85%.
    let mut mlp = MlpPredictor::new(MlpOptions { epochs: 20, ..Default::default() });
    mlp.fit(&exp.train_set_feedback(si, 0.7));
    let t1 = Instant::now();
    let inc = exp.train_set_feedback(si, 0.85);
    let delta = inc.suffix(exp.train_set_feedback(si, 0.7).len());
    mlp.update(&delta);
    let mlp_update = t1.elapsed().as_secs_f64();

    println!("eagle update {eagle_update:.6}s vs mlp retrain {mlp_update:.6}s");
    assert!(
        mlp_update > eagle_update * 20.0,
        "expected >=20x gap, got eagle={eagle_update} mlp={mlp_update}"
    );
}

#[test]
fn router_scores_are_deterministic() {
    let exp = experiment(29, 150);
    let r1 = exp.fit_eagle(0, EagleParams::default(), 1.0);
    let r2 = exp.fit_eagle(0, EagleParams::default(), 1.0);
    for emb in exp.test_emb[0].iter().take(20) {
        assert_eq!(r1.scores(emb), r2.scores(emb));
    }
}

#[test]
fn neighbor_size_sweep_runs_and_n1_is_weakest() {
    // Fig 4b's endpoints: a starved neighborhood (N=1) shouldn't beat the
    // paper's N=20 on aggregate (local-only emphasis).
    let exp = experiment(31, 400);
    let mut auc_n1 = 0.0;
    let mut auc_n20 = 0.0;
    for si in 0..DATASETS.len() {
        let mk = |n: usize| {
            exp.fit_eagle(
                si,
                EagleParams { p: 0.0, n_neighbors: n, ..Default::default() },
                1.0,
            )
        };
        auc_n1 += exp.eval(&mk(1), si).auc();
        auc_n20 += exp.eval(&mk(20), si).auc();
    }
    println!("local-only sum AUC N=1 {auc_n1:.4} vs N=20 {auc_n20:.4}");
    // Our trajectory-averaged local estimator degrades gracefully at small
    // N (it stays near the global seed), so the paper's sharp N=10 dropoff
    // softens; assert the weak form (see EXPERIMENTS.md Fig 4b notes).
    assert!(auc_n20 >= auc_n1 - 0.05);
}

#[test]
fn snapshot_roundtrip_preserves_eval() {
    let exp = experiment(37, 200);
    let router = exp.fit_eagle(2, EagleParams::default(), 1.0);
    let snap = eagle::coordinator::state::snapshot(&router);
    let restored = eagle::coordinator::state::restore(&snap).unwrap();
    let a = exp.eval(&router, 2).auc();
    let b = exp.eval(&restored, 2).auc();
    assert!((a - b).abs() < 1e-9, "{a} vs {b}");
}
