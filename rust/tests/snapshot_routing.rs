//! Concurrency stress + equivalence suite for RCU snapshot routing
//! (`eagle::coordinator::snapshot`).
//!
//! The contract under test:
//! - **No torn reads**: every `(epoch, history_len, ratings)` triple a
//!   reader observes matches exactly what the writer published for that
//!   epoch — never a mix of two epochs.
//! - **Readers never block**: route-side snapshot acquisition is one
//!   uncontended slot read; even under a full-rate feedback storm the
//!   readers keep making progress and no single acquisition stalls.
//! - **Snapshot ≡ locked router**: scores from a published snapshot are
//!   bit-identical to a flat-store `EagleRouter` rebuilt over the same
//!   feedback prefix (the acceptance criterion for the RCU refactor).
//! - **K-shard ≡ 1-shard**: scatter-gather scoring through a
//!   `ShardedRouter` (serial, batched, and parallel-scatter paths) is
//!   bit-identical to the single-shard scorer at every K, and readers
//!   keep making progress while every shard lane publishes at full rate
//!   from its own thread (multi-writer ingest).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use eagle::config::{EagleParams, EpochParams, IvfPublishParams, ShardParams};
use eagle::coordinator::router::{EagleRouter, Observation};
use eagle::coordinator::sharded::{shard_of, ShardedRouter};
use eagle::coordinator::snapshot::{RouterSnapshot, RouterWriter, SnapshotView};
use eagle::elo::{Comparison, Outcome};
use eagle::util::{l2_normalize, Rng};
use eagle::vectordb::flat::FlatStore;

const DIM: usize = 16;
const N_MODELS: usize = 6;

/// Serializes the thread-heavy tests in this binary: cargo's parallel
/// test runner would otherwise pile ~10 busy threads onto a small CI
/// runner and turn scheduling gaps into spurious stall reports.
static STORM_GATE: Mutex<()> = Mutex::new(());

fn storm_slot() -> std::sync::MutexGuard<'static, ()> {
    STORM_GATE.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn unit(rng: &mut Rng) -> Vec<f32> {
    let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

/// Deterministic feedback stream; the prefix of length h is exactly what
/// a snapshot with `history_len == h` has folded in.
fn obs_stream(seed: u64, n: usize) -> Vec<Observation> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let a = rng.below(N_MODELS);
            let mut b = rng.below(N_MODELS - 1);
            if b >= a {
                b += 1;
            }
            let outcome = match rng.below(3) {
                0 => Outcome::WinA,
                1 => Outcome::WinB,
                _ => Outcome::Draw,
            };
            Observation::single(unit(&mut rng), Comparison { a, b, outcome })
        })
        .collect()
}

/// One observed routing state: (epoch, history_len, ratings).
type Observed = (u64, usize, Vec<f64>);

/// What the writer records at each publish, keyed by epoch.
type PublishLog = Mutex<HashMap<u64, (usize, Vec<f64>)>>;

struct StormResult {
    /// Per-reader observations.
    observed: Vec<Vec<Observed>>,
    /// A few snapshots pinned by readers mid-storm, for score replay.
    pinned: Vec<Arc<RouterSnapshot>>,
    /// Worst single snapshot acquisition per reader.
    max_load: Vec<Duration>,
    log: HashMap<u64, (usize, Vec<f64>)>,
}

/// Run `n_readers` scoring threads against a writer ingesting `stream`
/// at full rate with the given cadence.
fn run_storm(stream: Vec<Observation>, cadence: EpochParams, n_readers: usize) -> StormResult {
    let mut writer = RouterWriter::new(EagleParams::default(), N_MODELS, DIM, cadence);
    let ring = writer.ring();
    let log: Arc<PublishLog> = Arc::new(Mutex::new(HashMap::new()));
    {
        let snap = ring.load();
        log.lock().unwrap().insert(
            snap.epoch(),
            (snap.history_len(), snap.global_ratings().to_vec()),
        );
    }
    let done = Arc::new(AtomicBool::new(false));

    let writer_log = log.clone();
    let writer_done = done.clone();
    let writer_thread = std::thread::spawn(move || {
        let record = |w: &RouterWriter, epoch: u64| {
            writer_log.lock().unwrap().insert(
                epoch,
                (w.router().feedback_len(), w.router().global().ratings()),
            );
        };
        for obs in stream {
            if let Some(epoch) = writer.observe(obs) {
                record(&writer, epoch);
            }
        }
        // flush the tail so the final state is published too
        if writer.unpublished() > 0 {
            let epoch = writer.publish();
            record(&writer, epoch);
        }
        writer_done.store(true, Ordering::SeqCst);
    });

    let readers: Vec<_> = (0..n_readers)
        .map(|r| {
            let ring = ring.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + r as u64);
                let mut observed = Vec::new();
                let mut pinned: Vec<Arc<RouterSnapshot>> = Vec::new();
                let mut max_load = Duration::ZERO;
                let mut last_epoch = 0u64;
                let mut iters = 0u64;
                // run through the storm, and keep going for a minimum
                // number of iterations in case the writer outpaced thread
                // startup on a fast machine
                while !done.load(Ordering::SeqCst) || iters < 200 {
                    let t0 = Instant::now();
                    let snap = ring.load();
                    max_load = max_load.max(t0.elapsed());
                    // epochs move forward only
                    assert!(
                        snap.epoch() >= last_epoch,
                        "epoch went backwards: {} -> {}",
                        last_epoch,
                        snap.epoch()
                    );
                    last_epoch = snap.epoch();
                    // actually score against it (exercises the view)
                    let q = unit(&mut rng);
                    let scores = snap.scores(&q);
                    assert_eq!(scores.len(), N_MODELS);
                    assert!(scores.iter().all(|s| s.is_finite()), "non-finite score");
                    observed.push((
                        snap.epoch(),
                        snap.history_len(),
                        snap.global_ratings().to_vec(),
                    ));
                    if iters % 64 == 0 && pinned.len() < 8 {
                        pinned.push(snap);
                    }
                    iters += 1;
                }
                (observed, pinned, max_load, iters)
            })
        })
        .collect();

    writer_thread.join().unwrap();
    let mut result = StormResult {
        observed: Vec::new(),
        pinned: Vec::new(),
        max_load: Vec::new(),
        log: HashMap::new(),
    };
    for r in readers {
        let (observed, pinned, max_load, iters) = r.join().unwrap();
        assert!(iters >= 20, "reader starved: only {iters} iterations");
        result.observed.push(observed);
        result.pinned.extend(pinned);
        result.max_load.push(max_load);
    }
    result.log = Arc::try_unwrap(log)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_else(|arc| arc.lock().unwrap().clone());
    result
}

/// Rebuild the locked-router baseline over the stream prefix.
fn reference_router(stream: &[Observation], prefix: usize) -> EagleRouter<FlatStore> {
    let mut r = EagleRouter::new(EagleParams::default(), N_MODELS, FlatStore::new(DIM));
    for obs in &stream[..prefix] {
        r.observe(obs.clone());
    }
    r
}

#[test]
fn feedback_storm_no_torn_reads_and_readers_progress() {
    let _slot = storm_slot();
    let stream = obs_stream(0xA11CE, 20_000);
    let cadence = EpochParams { publish_every: 32, publish_interval_ms: 5 };
    let result = run_storm(stream, cadence, 4);

    // every reader observation corresponds exactly to a published epoch
    let mut checked = 0usize;
    for per_reader in &result.observed {
        for (epoch, history_len, ratings) in per_reader {
            let (pub_len, pub_ratings) = result
                .log
                .get(epoch)
                .unwrap_or_else(|| panic!("reader saw unpublished epoch {epoch}"));
            assert_eq!(history_len, pub_len, "torn read at epoch {epoch}");
            assert_eq!(ratings, pub_ratings, "torn ratings at epoch {epoch}");
            checked += 1;
        }
    }
    assert!(checked >= 80, "too few observations checked: {checked}");

    // readers never block: a snapshot acquisition is a slot read; even on
    // a loaded CI box a full second means something held the reader
    // (scheduling noise is why this is not tighter)
    for (r, max_load) in result.max_load.iter().enumerate() {
        assert!(
            *max_load < Duration::from_secs(1),
            "reader {r} stalled {max_load:?} acquiring a snapshot"
        );
    }
}

#[test]
fn snapshot_scores_equal_locked_router_for_same_epoch() {
    let _slot = storm_slot();
    let stream = obs_stream(0xB0B, 6_000);
    let cadence = EpochParams { publish_every: 64, publish_interval_ms: 1_000 };
    let result = run_storm(stream.clone(), cadence, 2);

    // dedupe pinned snapshots by epoch, keep a handful
    let mut by_epoch: HashMap<u64, Arc<RouterSnapshot>> = HashMap::new();
    for snap in result.pinned {
        by_epoch.entry(snap.epoch()).or_insert(snap);
    }
    assert!(!by_epoch.is_empty(), "no snapshots pinned during the storm");

    let mut rng = Rng::new(0xCAFE);
    let probes: Vec<Vec<f32>> = (0..3).map(|_| unit(&mut rng)).collect();
    for (epoch, snap) in by_epoch.iter().take(6) {
        let reference = reference_router(&stream, snap.history_len());
        assert_eq!(
            snap.global_ratings(),
            &reference.global().ratings()[..],
            "global table diverged at epoch {epoch}"
        );
        for q in &probes {
            assert_eq!(
                snap.scores(q),
                reference.combined_scores(q),
                "snapshot scores != locked-router scores at epoch {epoch}"
            );
        }
        // batched path agrees with singles on the same snapshot
        let batch = snap.score_batch(&probes);
        for (q, b) in probes.iter().zip(&batch) {
            assert_eq!(&snap.scores(q), b);
        }
    }
}

#[test]
fn ring_wraps_safely_under_concurrent_load() {
    let _slot = storm_slot();
    // publish on every record: thousands of publishes force many full
    // revolutions of the publication ring while readers hammer it
    let stream = obs_stream(0xD00D, 4_000);
    let cadence = EpochParams { publish_every: 1, publish_interval_ms: 1_000 };
    let result = run_storm(stream, cadence, 4);

    let max_epoch = result.log.keys().copied().max().unwrap();
    assert_eq!(max_epoch, 4_000, "every record published its own epoch");
    for per_reader in &result.observed {
        for (epoch, history_len, _) in per_reader {
            // with publish_every=1, epoch == history_len exactly
            assert_eq!(*epoch as usize, *history_len, "epoch/history skew");
        }
    }
}

/// The sharding acceptance criterion: K-shard scatter-gather
/// `score_batch` is bit-identical to the single-shard scorer on the same
/// feedback stream, for K in {1, 2, 3, 8}, over interleaved inserts
/// (checkpoints land mid-stream, between lane publishes, after segment
/// and id-block merges).
#[test]
fn sharded_scatter_gather_matches_single_shard_scores() {
    for &k in &[1usize, 2, 3, 8] {
        let mut rng = Rng::new(0x5EEDED + k as u64);
        let stream = obs_stream(0xC0DE + k as u64, 700);
        let mut sharded = ShardedRouter::new(
            EagleParams::default(),
            N_MODELS,
            DIM,
            EpochParams { publish_every: 7, publish_interval_ms: 10_000 },
            ShardParams { count: k, hash_seed: 0xEA61E },
        );
        let handle = sharded.handle();
        for (step, obs) in stream.iter().enumerate() {
            sharded.observe(obs.clone());
            if (step + 1) % 167 == 0 || step + 1 == stream.len() {
                sharded.publish_all();
                let snap = handle.load();
                let reference = reference_router(&stream, step + 1);
                assert_eq!(snap.history_len(), reference.feedback_len(), "K={k}");
                assert_eq!(snap.store_len(), step + 1, "K={k}");
                assert_eq!(
                    snap.global_ratings(),
                    &reference.global().ratings()[..],
                    "shared global table diverged at K={k}, step {step}"
                );
                let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(&mut rng)).collect();
                let batch = snap.score_batch(&queries);
                let scatter = snap.score_batch_scatter(&queries);
                for (qi, q) in queries.iter().enumerate() {
                    let want = reference.combined_scores(q);
                    assert_eq!(
                        snap.scores(q),
                        want,
                        "serial sharded scores != single-shard at K={k}, step {step}"
                    );
                    assert_eq!(batch[qi], want, "score_batch diverged at K={k}, step {step}");
                    assert_eq!(
                        scatter[qi], want,
                        "parallel scatter diverged at K={k}, step {step}"
                    );
                }
            }
        }
    }
}

/// Multi-writer shard storm: one thread per shard lane applies its hash
/// partition and publishes at full rate while a stream-order thread
/// drives the shared global lane and reader threads score continuously.
/// Readers must make progress throughout (per-shard epochs only move
/// forward), and the quiescent final state must equal a single-shard
/// replay of the whole stream.
#[test]
fn shard_storm_readers_progress_while_all_writers_publish() {
    let _slot = storm_slot();
    const K: usize = 4;
    const HASH_SEED: u64 = 0xEA61E;
    let stream = obs_stream(0x5A4D, 8_000);
    let sharded = ShardedRouter::new(
        EagleParams::default(),
        N_MODELS,
        DIM,
        EpochParams { publish_every: 16, publish_interval_ms: 5 },
        ShardParams { count: K, hash_seed: HASH_SEED },
    );
    let handle = sharded.handle();
    let (mut global_lane, lanes) = sharded.into_lanes();

    // pre-partition deterministically, preserving arrival order per lane
    let mut per_lane: Vec<Vec<(u32, Observation)>> = (0..K).map(|_| Vec::new()).collect();
    for (gid, obs) in stream.iter().enumerate() {
        let s = shard_of(&obs.embedding, HASH_SEED, K);
        per_lane[s].push((gid as u32, obs.clone()));
    }

    let done = Arc::new(AtomicBool::new(false));
    let global_stream = stream.clone();
    let global_thread = std::thread::spawn(move || {
        for obs in &global_stream {
            global_lane.apply(&obs.comparisons);
            global_lane.maybe_publish();
        }
        global_lane.publish();
    });
    let lane_threads: Vec<_> = lanes
        .into_iter()
        .zip(per_lane)
        .map(|(mut lane, work)| {
            std::thread::spawn(move || {
                for (gid, obs) in work {
                    lane.apply(gid, obs);
                    lane.maybe_publish();
                }
                lane.publish();
            })
        })
        .collect();

    let readers: Vec<_> = (0..3)
        .map(|r| {
            let handle = handle.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(2000 + r as u64);
                let mut last_epochs = vec![0u64; K];
                let mut iters = 0u64;
                while !done.load(Ordering::SeqCst) || iters < 200 {
                    let snap = handle.load();
                    let epochs = snap.shard_epochs();
                    for (s, (&prev, &cur)) in last_epochs.iter().zip(&epochs).enumerate() {
                        assert!(cur >= prev, "shard {s} epoch went backwards: {prev} -> {cur}");
                    }
                    last_epochs = epochs;
                    let scores = snap.scores(&unit(&mut rng));
                    assert_eq!(scores.len(), N_MODELS);
                    assert!(scores.iter().all(|s| s.is_finite()), "non-finite score");
                    iters += 1;
                }
                iters
            })
        })
        .collect();

    global_thread.join().unwrap();
    for t in lane_threads {
        t.join().unwrap();
    }
    done.store(true, Ordering::SeqCst);
    for r in readers {
        let iters = r.join().unwrap();
        assert!(iters >= 20, "reader starved: only {iters} iterations");
    }

    // quiescent equivalence: the sharded state == single-shard replay
    let snap = handle.load();
    assert_eq!(snap.store_len(), stream.len());
    assert_eq!(snap.history_len(), stream.len());
    let reference = reference_router(&stream, stream.len());
    let mut rng = Rng::new(0xFACE);
    for _ in 0..4 {
        let q = unit(&mut rng);
        assert_eq!(
            snap.scores(&q),
            reference.combined_scores(&q),
            "post-storm sharded scores diverged from single-shard replay"
        );
    }
}

/// The IVF acceptance property: with `nprobe == n_cells` (exhaustive
/// probe) an IVF-published snapshot scores **bit-identically** to the
/// flat view — across random thresholds, cell counts, stream lengths,
/// mid-stream checkpoints, and core rebuilds, single-shard and K-shard.
#[test]
fn ivf_published_snapshots_score_identically_to_flat_property() {
    let mut cfg_rng = Rng::new(0x1FF);
    for trial in 0..6 {
        let n_cells = 2 + cfg_rng.below(14);
        let threshold = 50 + cfg_rng.below(200);
        let n = threshold + 150 + cfg_rng.below(300);
        let publish_every = 5 + cfg_rng.below(40);
        let k = [1usize, 3][trial % 2];
        let stream = obs_stream(0x1F5 + trial as u64, n);
        let mut sharded = ShardedRouter::new(
            EagleParams::default(),
            N_MODELS,
            DIM,
            EpochParams { publish_every, publish_interval_ms: 10_000 },
            ShardParams { count: k, hash_seed: 0xEA61E },
        );
        sharded.set_ivf(IvfPublishParams {
            publish_threshold: threshold,
            n_cells,
            nprobe: n_cells,
        });
        let handle = sharded.handle();
        let mut rng = Rng::new(0xAB + trial as u64);
        for (step, obs) in stream.iter().enumerate() {
            sharded.observe(obs.clone());
            let at_checkpoint = (step + 1) % 157 == 0 || step + 1 == n;
            if !at_checkpoint {
                continue;
            }
            sharded.publish_all();
            let snap = handle.load();
            let reference = reference_router(&stream, step + 1);
            for _ in 0..3 {
                let q = unit(&mut rng);
                assert_eq!(
                    snap.scores(&q),
                    reference.combined_scores(&q),
                    "ivf snapshot diverged: trial {trial} K={k} n_cells={n_cells} \
                     threshold={threshold} step {step}"
                );
            }
        }
    }

    // and the view kind actually flips past the threshold (single shard,
    // where the lane corpus size is the stream length)
    let stream = obs_stream(0x1F6, 200);
    let mut writer = RouterWriter::new(
        EagleParams::default(),
        N_MODELS,
        DIM,
        EpochParams { publish_every: 1_000_000, publish_interval_ms: 1_000_000 },
    );
    writer.set_ivf(IvfPublishParams { publish_threshold: 100, n_cells: 8, nprobe: 8 });
    for obs in &stream[..99] {
        writer.apply(obs.clone());
    }
    writer.publish();
    assert!(matches!(writer.ring().load().view(), SnapshotView::Flat(_)));
    for obs in &stream[99..] {
        writer.apply(obs.clone());
    }
    writer.publish();
    assert!(matches!(writer.ring().load().view(), SnapshotView::Ivf(_)));
}

/// The compaction stress criterion: IVF core rebuilds happen on the
/// ingest thread at full feedback rate while readers score continuously —
/// readers must keep progressing, never observe a stalled acquisition,
/// and the final state must equal an in-order flat replay.
#[test]
fn ivf_compaction_never_blocks_route_scoring() {
    let _slot = storm_slot();
    let stream = obs_stream(0x1F7, 12_000);
    let mut writer = RouterWriter::new(
        EagleParams::default(),
        N_MODELS,
        DIM,
        EpochParams { publish_every: 64, publish_interval_ms: 5 },
    );
    // low threshold + small cells: many core rebuilds over the storm
    writer.set_ivf(IvfPublishParams { publish_threshold: 500, n_cells: 16, nprobe: 16 });
    let ring = writer.ring();
    let done = Arc::new(AtomicBool::new(false));

    let done_w = done.clone();
    let reference_stream = stream.clone();
    let writer_thread = std::thread::spawn(move || {
        for obs in stream {
            writer.observe(obs);
        }
        if writer.unpublished() > 0 {
            writer.publish();
        }
        let (core, tail) = writer.ivf_core_tail_len();
        done_w.store(true, Ordering::SeqCst);
        (core, tail)
    });

    let readers: Vec<_> = (0..3)
        .map(|r| {
            let ring = ring.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(3000 + r as u64);
                let mut iters = 0u64;
                let mut max_load = Duration::ZERO;
                let mut saw_ivf = false;
                while !done.load(Ordering::SeqCst) || iters < 200 {
                    let t0 = Instant::now();
                    let snap = ring.load();
                    max_load = max_load.max(t0.elapsed());
                    saw_ivf |= matches!(snap.view(), SnapshotView::Ivf(_));
                    let scores = snap.scores(&unit(&mut rng));
                    assert!(scores.iter().all(|s| s.is_finite()));
                    iters += 1;
                }
                (iters, max_load, saw_ivf)
            })
        })
        .collect();

    let (core, tail) = writer_thread.join().unwrap();
    assert!(core >= 500, "core never rebuilt under storm (len {core})");
    assert!(core + tail == 12_000, "core/tail skew: {core} + {tail}");
    for r in readers {
        let (iters, max_load, saw_ivf) = r.join().unwrap();
        assert!(iters >= 20, "reader starved: only {iters} iterations");
        assert!(saw_ivf, "reader never observed an IVF-published snapshot");
        // a snapshot acquisition is a slot read; a full second means a
        // core rebuild blocked the reader (the bug this test guards)
        assert!(max_load < Duration::from_secs(1), "reader stalled {max_load:?}");
    }

    // quiescent equivalence after all the rebuilds
    let snap = ring.load();
    assert_eq!(snap.store_len(), 12_000);
    let reference = reference_router(&reference_stream, 12_000);
    let mut rng = Rng::new(0x1CE);
    for _ in 0..4 {
        let q = unit(&mut rng);
        assert_eq!(snap.scores(&q), reference.combined_scores(&q));
    }
}

#[test]
fn queue_backpressure_never_reaches_readers() {
    let _slot = storm_slot();
    // a writer that also sleeps (simulating embed work) while readers
    // score: reader progress must not depend on writer progress
    let stream = obs_stream(0x5EED, 200);
    let mut writer = RouterWriter::new(
        EagleParams::default(),
        N_MODELS,
        DIM,
        EpochParams { publish_every: 10, publish_interval_ms: 1_000 },
    );
    let ring = writer.ring();
    let done = Arc::new(AtomicBool::new(false));
    let done_w = done.clone();
    let writer_thread = std::thread::spawn(move || {
        for obs in stream {
            writer.observe(obs);
            std::thread::sleep(Duration::from_micros(200));
        }
        done_w.store(true, Ordering::SeqCst);
    });
    let mut rng = Rng::new(1);
    let mut iters = 0u64;
    while !done.load(Ordering::SeqCst) {
        let snap = ring.load();
        let _ = snap.scores(&unit(&mut rng));
        iters += 1;
    }
    writer_thread.join().unwrap();
    // 200 records * 200us of writer-side work = at least ~40ms of storm;
    // an unblocked reader fits thousands of iterations in that window
    assert!(iters > 500, "reader made only {iters} iterations");
}
