//! Durable-store crash recovery: property tests that
//! `recover(persist(state)) ≡ state` — bit-identical `score_batch`
//! results and global-ELO table — for K ∈ {1, 4} across interleaved
//! seal/delta/ELO-fold histories, a torn-tail-write test, and a full
//! SIGKILL-the-server crash/restart e2e (hash embedder, no artifacts).

use std::path::{Path, PathBuf};

use eagle::config::{EagleParams, EpochParams, ShardParams};
use eagle::coordinator::durable::{DurableLaneWriter, DurableOptions, DurableStore, StoreMeta};
use eagle::coordinator::router::Observation;
use eagle::coordinator::sharded::{shard_of, ShardedRouter};
use eagle::elo::{Comparison, Outcome};
use eagle::util::{l2_normalize, Rng};

const DIM: usize = 16;
const N_MODELS: usize = 5;
const HASH_SEED: u64 = 0xEA61E;

fn unit(rng: &mut Rng) -> Vec<f32> {
    let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn rand_obs(rng: &mut Rng) -> Observation {
    let a = rng.below(N_MODELS);
    let mut b = rng.below(N_MODELS - 1);
    if b >= a {
        b += 1;
    }
    let outcome = match rng.below(3) {
        0 => Outcome::WinA,
        1 => Outcome::WinB,
        _ => Outcome::Draw,
    };
    Observation::single(unit(rng), Comparison { a, b, outcome })
}

fn cadence() -> EpochParams {
    EpochParams { publish_every: 16, publish_interval_ms: 10_000 }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("eagle_recovery_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn meta(k: usize) -> StoreMeta {
    StoreMeta {
        params: EagleParams::default(),
        n_models: N_MODELS,
        dim: DIM,
        shards: ShardParams { count: k, hash_seed: HASH_SEED },
    }
}

/// Drive a [`ShardedRouter`] and its durable lane writers through one
/// interleaved history: every record is observed in memory and appended
/// to its shard's delta log (exactly what the ingest appliers do), with
/// seals forced by the tiny seal threshold, explicit mid-stream seals,
/// periodic syncs, and mid-stream global-ELO checkpoints.
fn drive_history(
    dir: &Path,
    k: usize,
    n: usize,
    opts: &DurableOptions,
    rng: &mut Rng,
) -> (ShardedRouter, Vec<Observation>) {
    let store = DurableStore::create(dir, meta(k), opts.clone()).unwrap();
    let mut writers: Vec<DurableLaneWriter> =
        (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
    let mut router =
        ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);
    let mut stream = Vec::with_capacity(n);
    for i in 0..n {
        let obs = rand_obs(rng);
        let shard = router.shard_for(&obs.embedding);
        let gid = router.next_global_id();
        router.observe(obs.clone());
        writers[shard].append(gid, &obs).unwrap();
        stream.push(obs);
        // interleave seals, syncs, and checkpoints through the history
        if i % 37 == 36 {
            writers[rng.below(k)].seal().unwrap();
        }
        if i % 23 == 22 {
            writers[rng.below(k)].sync().unwrap();
        }
        if i % 61 == 60 {
            for w in &mut writers {
                w.sync().unwrap();
            }
            store
                .checkpoint_global(router.next_global_id(), router.global_elo().export_state())
                .unwrap();
        }
    }
    for w in &mut writers {
        w.sync().unwrap();
    }
    (router, stream)
}

fn assert_equivalent(a: &mut ShardedRouter, b: &mut ShardedRouter, rng: &mut Rng, what: &str) {
    a.publish_all();
    b.publish_all();
    assert_eq!(a.store_len(), b.store_len(), "{what}: store length");
    assert_eq!(a.history_len(), b.history_len(), "{what}: history length");
    assert_eq!(
        a.global_elo().export_state(),
        b.global_elo().export_state(),
        "{what}: global-ELO state"
    );
    let snap_a = a.handle().load();
    let snap_b = b.handle().load();
    assert_eq!(snap_a.global_ratings(), snap_b.global_ratings(), "{what}: ratings");
    let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(rng)).collect();
    assert_eq!(
        snap_a.score_batch(&queries),
        snap_b.score_batch(&queries),
        "{what}: score_batch"
    );
}

#[test]
fn recover_equals_state_across_interleaved_histories() {
    // the acceptance property: recover(persist(state)) ≡ state for
    // K ∈ {1, 4}, across random interleavings of seals, delta appends,
    // ELO folds, and checkpoints — and the equivalence survives further
    // ingest (the averaging trajectory resumed, not just the ratings)
    for &k in &[1usize, 4] {
        for seed in 0..4u64 {
            let mut rng = Rng::new(0xD0_0D + seed * 101 + k as u64);
            let dir = tmp_dir(&format!("prop_k{k}_s{seed}"));
            let n = 150 + rng.below(150);
            let opts =
                DurableOptions { seal_bytes: 500 + rng.below(1500), fsync: false, mmap: true };
            let (mut original, _stream) = drive_history(&dir, k, n, &opts, &mut rng);

            let (store, recovery) = DurableStore::open(&dir, opts.clone()).unwrap();
            assert_eq!(recovery.torn_bytes, 0, "clean history must not lose bytes");
            assert_eq!(recovery.total_records(), n);
            let mut recovered = recovery.into_router(cadence()).unwrap();
            assert_eq!(recovered.next_global_id(), original.next_global_id());
            assert_equivalent(&mut original, &mut recovered, &mut rng, "post-recovery");

            // both routers ingest the same continuation; the recovered
            // one also keeps appending durably (writers survive reopen)
            let mut writers: Vec<DurableLaneWriter> =
                (0..k).map(|s| store.lane_writer(s).unwrap()).collect();
            for _ in 0..60 {
                let obs = rand_obs(&mut rng);
                let shard = recovered.shard_for(&obs.embedding);
                let gid = recovered.next_global_id();
                original.observe(obs.clone());
                recovered.observe(obs.clone());
                writers[shard].append(gid, &obs).unwrap();
            }
            for w in &mut writers {
                w.sync().unwrap();
            }
            assert_equivalent(&mut original, &mut recovered, &mut rng, "post-continuation");

            // ...and a second recovery sees the continuation too
            drop(writers);
            drop(store);
            let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
            let mut twice = recovery.into_router(cadence()).unwrap();
            assert_equivalent(&mut original, &mut twice, &mut rng, "second recovery");
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

#[test]
fn torn_tail_write_recovers_to_last_full_record() {
    let mut rng = Rng::new(0x7EA2);
    let k = 4;
    let dir = tmp_dir("torn_tail");
    // nothing seals: every record stays in its delta log, so truncating
    // one log mid-frame tears exactly its last record
    let opts = DurableOptions { seal_bytes: usize::MAX, fsync: false, mmap: true };
    let (_original, stream) = drive_history(&dir, k, 200, &opts, &mut rng);

    // tear the final record of the last observation's shard
    let torn_shard = shard_of(&stream[199].embedding, HASH_SEED, k);
    let log = std::fs::read_dir(dir.join(format!("shard-{torn_shard}")))
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "log"))
        .unwrap();
    let len = std::fs::metadata(&log).unwrap().len();
    std::fs::OpenOptions::new()
        .write(true)
        .open(&log)
        .unwrap()
        .set_len(len - 3)
        .unwrap();

    let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
    assert!(recovery.torn_bytes > 0, "the torn tail must be detected");
    assert_eq!(recovery.total_records(), 199, "recovery keeps every full record");
    let mut recovered = recovery.into_router(cadence()).unwrap();

    // reference: replay exactly the surviving records, preserving their
    // original global arrival ids (the torn shard has a gap at its tail)
    let torn_gid = stream[..200]
        .iter()
        .enumerate()
        .filter(|(_, o)| shard_of(&o.embedding, HASH_SEED, k) == torn_shard)
        .map(|(gid, _)| gid as u32)
        .next_back()
        .unwrap();
    let reference_shell =
        ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(), meta(k).shards);
    let handle = reference_shell.handle();
    let (mut global, mut lanes) = reference_shell.into_lanes();
    for (gid, obs) in stream.iter().enumerate() {
        let gid = gid as u32;
        if gid == torn_gid {
            continue;
        }
        global.apply(&obs.comparisons);
        lanes[shard_of(&obs.embedding, HASH_SEED, k)].apply(gid, obs.clone());
    }
    global.publish();
    for lane in &mut lanes {
        lane.publish();
    }
    recovered.publish_all();
    let snap_ref = handle.load();
    let snap_rec = recovered.handle().load();
    assert_eq!(snap_rec.store_len(), 199);
    assert_eq!(snap_rec.global_ratings(), snap_ref.global_ratings());
    for _ in 0..6 {
        let q = unit(&mut rng);
        assert_eq!(snap_rec.scores(&q), snap_ref.scores(&q), "torn recovery diverged");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---- SIGKILL crash/restart e2e ----------------------------------------

/// Spawn `eagle serve` on a free port with a durable dir and hash
/// embedder (no artifacts needed), returning the child + bound address.
fn spawn_server(durable_dir: &std::path::Path) -> (std::process::Child, String) {
    use std::io::{BufRead, BufReader};
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_eagle"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--set",
            &format!("persist.dir={}", durable_dir.display()),
            "--set",
            "persist.interval_ms=20",
            "--set",
            "persist.seal_bytes=16384",
            "--set",
            "persist.fsync=false",
            "--set",
            "shards.count=2",
            "--set",
            "epoch.publish_every=8",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn eagle serve");
    let stdout = child.stdout.take().expect("child stdout");
    let mut reader = BufReader::new(stdout);
    let mut addr = None;
    let mut line = String::new();
    // the banner line is printed once serving starts
    for _ in 0..64 {
        line.clear();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(rest) = line.strip_prefix("eagle serving on ") {
            addr = rest.split_whitespace().next().map(str::to_string);
            break;
        }
    }
    // keep draining the pipe so the server never blocks on a full pipe
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).unwrap_or(0) > 0 {
            sink.clear();
        }
    });
    let addr = addr.expect("server banner with bound address");
    (child, addr)
}

#[test]
fn sigkill_mid_ingest_recovers_and_serves() {
    use eagle::server::client::EagleClient;

    let root = tmp_dir("sigkill");
    let durable = root.join("durable");
    std::fs::create_dir_all(&root).unwrap();

    // phase 1: serve, storm feedback, checkpoint, storm more, SIGKILL
    let (mut child, addr) = spawn_server(&durable);
    let mut client = EagleClient::connect(&addr).expect("connect");
    for i in 0..300 {
        client
            .feedback(&format!("crash recovery prompt {i}"), "gpt-4", "mistral-7b-chat", 1.0)
            .expect("feedback accepted");
    }
    // the admin snapshot op = flush + fsync + checkpoint on the durable
    // store: everything accepted so far is durable after this returns
    let (snap_path, entries) = client.snapshot().expect("durable snapshot op");
    assert_eq!(entries, 300, "checkpoint must cover every accepted record");
    assert_eq!(snap_path, durable.display().to_string());
    // keep ingesting so the kill lands mid-stream, then SIGKILL
    for i in 300..400 {
        let _ =
            client.feedback(&format!("crash recovery prompt {i}"), "gpt-4", "gpt-3.5-turbo", 0.0);
    }
    child.kill().expect("SIGKILL server");
    let _ = child.wait();
    drop(client);

    // phase 2: recover in-process — the checkpointed prefix survives
    let opts = DurableOptions { seal_bytes: 16384, fsync: false, mmap: true };
    let (_store, recovery) = DurableStore::open(&durable, opts).unwrap();
    assert!(
        recovery.total_records() >= 300,
        "recovered {} records, checkpoint covered 300",
        recovery.total_records()
    );
    let recovered = recovery
        .into_router(EpochParams::default())
        .expect("recovered router");
    assert!(recovered.store_len() >= 300);
    assert_eq!(recovered.store_len(), recovered.history_len());
    drop(_store);

    // phase 3: restart the server from the durable dir and route
    let (mut child, addr) = spawn_server(&durable);
    let mut client = EagleClient::connect(&addr).expect("reconnect");
    let decision = client.route("which model should answer this?", 0.02).expect("route");
    assert!(!decision.model.is_empty());
    let (_, entries) = client.snapshot().expect("snapshot after restart");
    assert!(entries >= 300, "restarted server lost the corpus ({entries} records)");
    child.kill().ok();
    let _ = child.wait();
    std::fs::remove_dir_all(&root).ok();
}
