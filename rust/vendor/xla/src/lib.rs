//! API-surface **stub** of the `xla` crate (PJRT bindings).
//!
//! The real crate is not on crates.io, so CI could never type-check the
//! `pjrt`-gated runtime code and it would bit-rot silently. This stub
//! mirrors exactly the API surface `eagle::runtime` uses — same type
//! names, same signatures — but every entry point fails at runtime with
//! a clear message. `cargo check --all-targets --features pjrt` compiles
//! against it; executing PJRT artifacts requires replacing this path
//! dependency with the real vendored crate (see `rust/README.md`).
//!
//! All handle types wrap an uninhabited `Void`, so post-construction
//! methods are statically unreachable (`match self.0 {}`) and can never
//! mask a real-crate behavior difference.

use std::fmt;
use std::path::Path;

const STUB_MSG: &str = "stub xla crate: this build only type-checks the `pjrt` feature; \
                        vendor the real xla crate (see rust/README.md) to run PJRT artifacts";

/// Error type matching the real crate's `Display`-able error surface.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Uninhabited: stub handles can never actually be constructed.
#[derive(Debug)]
enum Void {}

#[derive(Debug)]
pub struct PjRtClient(Void);

#[derive(Debug)]
pub struct PjRtLoadedExecutable(Void);

#[derive(Debug)]
pub struct PjRtBuffer(Void);

#[derive(Debug)]
pub struct HloModuleProto(Void);

#[derive(Debug)]
pub struct XlaComputation(Void);

#[derive(Debug)]
pub struct Literal(Void);

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error(STUB_MSG.to_string()))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match self.0 {}
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error(STUB_MSG.to_string()))
    }
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match proto.0 {}
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        match self.0 {}
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        match self.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_with_stub_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub xla crate"));
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
