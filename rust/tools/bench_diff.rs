//! `bench-diff`: compare two `BENCH_*.json` artifacts and report
//! regressions — the CI bench trend tool.
//!
//! ```bash
//! cargo run --release --bin bench-diff -- baseline.json current.json \
//!     [--threshold 0.15] [--md-out summary.md]
//! ```
//!
//! Direction is inferred from the metric name (`*_us`/`*latency*` are
//! lower-is-better; `*qps`/`*rps`/`*ratio*`/`*speedup*` higher-is-better;
//! anything else is reported as neutral). The exit code is 1 when at
//! least one regression beyond the threshold was found — the CI step
//! wraps the call in `continue-on-error: true`, so the signal is visible
//! (red step + summary table) without blocking the job. `--md-out FILE`
//! appends a GitHub-flavored markdown rendering of the comparison (the
//! CI step points it at `$GITHUB_STEP_SUMMARY`).

use std::collections::BTreeMap;
use std::io::Write as _;
use std::process::ExitCode;

use eagle::bench::{fmt, print_table};
use eagle::json;

#[derive(Clone, Copy, PartialEq)]
enum Direction {
    HigherIsBetter,
    LowerIsBetter,
    Neutral,
}

fn direction_of(name: &str) -> Direction {
    let lower = name.to_ascii_lowercase();
    // latency-ish suffixes first: "route_latency.p99_us" must not match
    // a higher-is-better token by accident
    for token in ["_us", "_ms", "_ns", "latency", "secs", "_s."] {
        if lower.contains(token) {
            return Direction::LowerIsBetter;
        }
    }
    for token in ["qps", "rps", "per_s", "ratio", "speedup", "recall", "auc", "throughput"] {
        if lower.contains(token) {
            return Direction::HigherIsBetter;
        }
    }
    Direction::Neutral
}

/// metric name -> value, from one BENCH_*.json document.
fn load_metrics(path: &str) -> Result<BTreeMap<String, f64>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
    let arr = doc
        .get("metrics")
        .as_arr()
        .ok_or_else(|| format!("{path}: no metrics array"))?;
    let mut out = BTreeMap::new();
    for m in arr {
        let name = m.get("name").as_str().ok_or_else(|| format!("{path}: metric without name"))?;
        let value = m.get("value").as_f64().ok_or_else(|| format!("{path}: metric without value"))?;
        out.insert(name.to_string(), value);
    }
    Ok(out)
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut threshold = 0.15f64;
    let mut md_out: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--threshold" => {
                let Some(v) = argv.get(i + 1).and_then(|v| v.parse().ok()) else {
                    eprintln!("--threshold needs a number");
                    return ExitCode::from(2);
                };
                threshold = v;
                i += 2;
            }
            "--md-out" => {
                let Some(p) = argv.get(i + 1) else {
                    eprintln!("--md-out needs a file path");
                    return ExitCode::from(2);
                };
                md_out = Some(p.clone());
                i += 2;
            }
            // kept for compatibility: regressions now always exit 1
            "--strict" => {
                i += 1;
            }
            other => {
                paths.push(other.to_string());
                i += 1;
            }
        }
    }
    if paths.len() != 2 {
        eprintln!(
            "usage: bench-diff BASELINE.json CURRENT.json [--threshold 0.15] [--md-out FILE]"
        );
        return ExitCode::from(2);
    }

    let (base, current) = match (load_metrics(&paths[0]), load_metrics(&paths[1])) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench-diff: {e}");
            return ExitCode::from(2);
        }
    };

    let mut regressions = Vec::new();
    let mut improvements = Vec::new();
    let mut neutral_changes = Vec::new();
    for (name, &base_v) in &base {
        let Some(&cur_v) = current.get(name) else { continue };
        if base_v == 0.0 {
            continue;
        }
        let rel = (cur_v - base_v) / base_v.abs();
        let row = vec![
            name.clone(),
            fmt(base_v, 2),
            fmt(cur_v, 2),
            format!("{:+.1}%", rel * 100.0),
        ];
        let worse = match direction_of(name) {
            Direction::HigherIsBetter => -rel,
            Direction::LowerIsBetter => rel,
            Direction::Neutral => {
                if rel.abs() > threshold {
                    neutral_changes.push(row);
                }
                continue;
            }
        };
        if worse > threshold {
            regressions.push(row);
        } else if worse < -threshold {
            improvements.push(row);
        }
    }
    let missing: Vec<&String> = base.keys().filter(|k| !current.contains_key(*k)).collect();
    let added: Vec<&String> = current.keys().filter(|k| !base.contains_key(*k)).collect();

    println!(
        "bench-diff: {} vs {} ({} shared metrics, threshold {:.0}%)",
        paths[0],
        paths[1],
        base.keys().filter(|k| current.contains_key(*k)).count(),
        threshold * 100.0
    );
    let header = || {
        vec!["metric".to_string(), "baseline".to_string(), "current".to_string(), "delta".to_string()]
    };
    if regressions.is_empty() {
        println!("no regressions beyond the threshold");
    } else {
        let mut rows = vec![header()];
        rows.extend(regressions.iter().cloned());
        print_table(&format!("REGRESSIONS (> {:.0}% worse)", threshold * 100.0), &rows);
    }
    if !improvements.is_empty() {
        let mut rows = vec![header()];
        rows.extend(improvements.iter().cloned());
        print_table("improvements", &rows);
    }
    if !neutral_changes.is_empty() {
        let mut rows = vec![header()];
        rows.extend(neutral_changes.iter().cloned());
        print_table("changed (no known direction)", &rows);
    }
    if !missing.is_empty() {
        println!("\nmetrics missing from current: {missing:?}");
    }
    if !added.is_empty() {
        println!("new metrics (no baseline): {added:?}");
    }

    if let Some(out) = &md_out {
        if let Err(e) = append_markdown(
            out,
            &paths,
            threshold,
            &regressions,
            &improvements,
            &neutral_changes,
            &missing,
            &added,
        ) {
            eprintln!("bench-diff: writing {out}: {e}");
        }
    }

    if regressions.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

/// One markdown table per change class (rows are the same
/// `[metric, baseline, current, delta]` vectors the console tables use).
fn md_table(out: &mut String, title: &str, rows: &[Vec<String>]) {
    if rows.is_empty() {
        return;
    }
    out.push_str(&format!("\n#### {title}\n\n"));
    out.push_str("| metric | baseline | current | delta |\n");
    out.push_str("| --- | ---: | ---: | ---: |\n");
    for row in rows {
        out.push_str(&format!("| `{}` | {} | {} | {} |\n", row[0], row[1], row[2], row[3]));
    }
}

#[allow(clippy::too_many_arguments)]
fn append_markdown(
    path: &str,
    paths: &[String],
    threshold: f64,
    regressions: &[Vec<String>],
    improvements: &[Vec<String>],
    neutral_changes: &[Vec<String>],
    missing: &[&String],
    added: &[&String],
) -> std::io::Result<()> {
    let mut md = String::new();
    md.push_str(&format!(
        "\n### Bench trend: `{}` vs `{}` (threshold {:.0}%)\n",
        paths[1],
        paths[0],
        threshold * 100.0
    ));
    if regressions.is_empty() {
        md.push_str("\nNo regressions beyond the threshold. :white_check_mark:\n");
    } else {
        md_table(
            &mut md,
            &format!(":red_circle: Regressions (> {:.0}% worse)", threshold * 100.0),
            regressions,
        );
    }
    md_table(&mut md, "Improvements", improvements);
    md_table(&mut md, "Changed (no known direction)", neutral_changes);
    if !missing.is_empty() {
        md.push_str(&format!("\nMetrics missing from current: {missing:?}\n"));
    }
    if !added.is_empty() {
        md.push_str(&format!("\nNew metrics (no baseline): {added:?}\n"));
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(md.as_bytes())
}
