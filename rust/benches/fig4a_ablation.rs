//! Fig 4a reproduction: Eagle vs its components (Eagle-Global only,
//! Eagle-Local only).
//!
//! Paper shape: neither component alone reaches the combined router;
//! Global lacks specialization, Local is biased by small samples.
//!
//! Run: `cargo bench --bench fig4a_ablation`

mod common;

use eagle::bench::{fmt, print_table};
use eagle::config::EagleParams;
use eagle::routerbench::DATASETS;

fn main() {
    let (_rig, exp, cfg) = common::setup("fig4a");
    let variants = [("eagle-global", 1.0), ("eagle-local", 0.0), ("eagle", cfg.eagle.p)];

    let mut rows = vec![{
        let mut h = vec!["variant".to_string()];
        h.extend(DATASETS.iter().map(|d| d.to_string()));
        h.push("sum".into());
        h
    }];
    let mut sums = Vec::new();
    for (name, p) in variants {
        let mut row = vec![name.to_string()];
        let mut sum = 0.0;
        for si in 0..DATASETS.len() {
            let r = exp.fit_eagle(si, EagleParams { p, ..cfg.eagle.clone() }, 1.0);
            let auc = exp.eval(&r, si).auc();
            row.push(fmt(auc, 4));
            sum += auc;
        }
        row.push(fmt(sum, 4));
        rows.push(row);
        sums.push((name, sum));
    }
    print_table("Fig 4a — component ablation (AUC)", &rows);

    let combined = sums.iter().find(|(n, _)| *n == "eagle").unwrap().1;
    let global = sums.iter().find(|(n, _)| *n == "eagle-global").unwrap().1;
    let local = sums.iter().find(|(n, _)| *n == "eagle-local").unwrap().1;
    println!(
        "\npaper shape check: combined ({:.4}) vs global ({:.4}) vs local ({:.4}) — \
         combined should be highest",
        combined, global, local
    );

    // extension ablation: trajectory averaging on/off for the global table
    // is covered in perf_hotpath (it is an estimator property, not a
    // routing-policy one); here we add the replay-order ablation instead.
    println!(
        "(local replay order: neighbors are replayed far-to-near so the most \
         similar prompts carry the most ELO weight; see router.rs)"
    );
}
