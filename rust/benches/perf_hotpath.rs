//! Hot-path microbenchmarks (§Perf): every stage of the request path plus
//! the estimator ablations. Criterion-equivalent harness from
//! `eagle::bench` (adaptive iteration counts, p50/p99).
//!
//! Run: `cargo bench --bench perf_hotpath`

use eagle::config::EagleParams;
use eagle::coordinator::router::{EagleRouter, Observation};
use eagle::coordinator::Router;
use eagle::elo::{Comparison, EloEngine, GlobalElo, Outcome};
use eagle::embedding::{BatcherOptions, EmbedService, Embedder, HashEmbedder};
use eagle::metrics::Metrics;
use eagle::tokenizer;
use eagle::util::{l2_normalize, Rng};
use eagle::vectordb::flat::FlatStore;
use eagle::vectordb::ivf::{IvfIndex, IvfParams};
use eagle::vectordb::{Feedback, VectorIndex};

const DIM: usize = 256;

fn unit(rng: &mut Rng) -> Vec<f32> {
    let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn rand_cmp(rng: &mut Rng) -> Comparison {
    let a = rng.below(11);
    let mut b = rng.below(10);
    if b >= a {
        b += 1;
    }
    let outcome = match rng.below(3) {
        0 => Outcome::WinA,
        1 => Outcome::WinB,
        _ => Outcome::Draw,
    };
    Comparison { a, b, outcome }
}

fn main() {
    let mut rng = Rng::new(0xBE);
    let mut results = Vec::new();

    // --- tokenizer ---
    let text = "Solve this word problem about train speed distance hours \
                please carefully show your reasoning with all details";
    results.push(eagle::bench::bench("tokenizer/tokenize_64", 200, || {
        std::hint::black_box(tokenizer::tokenize_default(text));
    }));

    // --- ELO ---
    let cmps: Vec<Comparison> = (0..1000).map(|_| rand_cmp(&mut rng)).collect();
    let mut engine = EloEngine::new(11, 32.0);
    results.push(eagle::bench::bench("elo/update_x1000", 200, || {
        engine.replay(&cmps);
    }));
    results.push(eagle::bench::bench("elo/global_init_10k_records", 300, || {
        let mut g = GlobalElo::new(11, 32.0);
        for chunk in cmps.chunks(100) {
            for _ in 0..1 {
                g.apply_new(chunk);
            }
        }
        std::hint::black_box(g.ratings());
    }));

    // --- vector stores ---
    for &n in &[1_000usize, 10_000] {
        let mut flat = FlatStore::with_capacity(DIM, n);
        for _ in 0..n {
            let v = unit(&mut rng);
            flat.add(&v, Feedback { comparisons: vec![rand_cmp(&mut rng)] });
        }
        let q = unit(&mut rng);
        results.push(eagle::bench::bench(
            &format!("vectordb/flat_scan_top20_n{n}"),
            300,
            || {
                std::hint::black_box(flat.search(&q, 20));
            },
        ));

        let vectors: Vec<Vec<f32>> = (0..n).map(|_| unit(&mut rng)).collect();
        let payloads = (0..n)
            .map(|_| Feedback { comparisons: vec![rand_cmp(&mut rng)] })
            .collect();
        let ivf = IvfIndex::build(DIM, &vectors, payloads, IvfParams::default());
        results.push(eagle::bench::bench(
            &format!("vectordb/ivf_top20_n{n}_probe8of64"),
            300,
            || {
                std::hint::black_box(ivf.search(&q, 20));
            },
        ));
    }

    // --- router scoring path (local elo replay included) ---
    let obs: Vec<Observation> = (0..5_000)
        .map(|_| Observation {
            embedding: unit(&mut rng),
            comparisons: (0..3).map(|_| rand_cmp(&mut rng)).collect(),
        })
        .collect();
    let router = EagleRouter::fit(
        EagleParams::default(),
        11,
        FlatStore::with_capacity(DIM, obs.len()),
        &obs,
    );
    let q = unit(&mut rng);
    results.push(eagle::bench::bench("router/combined_scores_store5k", 400, || {
        std::hint::black_box(router.scores(&q));
    }));
    let global_router = EagleRouter::fit(
        EagleParams { p: 1.0, ..Default::default() },
        11,
        FlatStore::with_capacity(DIM, obs.len()),
        &obs,
    );
    results.push(eagle::bench::bench("router/global_only_store5k", 200, || {
        std::hint::black_box(global_router.scores(&q));
    }));

    // --- hash embedder (fallback path) ---
    let hash = HashEmbedder::new(DIM);
    results.push(eagle::bench::bench("embed/hash_fallback_1", 200, || {
        std::hint::black_box(hash.embed(&[text]));
    }));

    // --- PJRT embedder (serving path; skipped without artifacts) ---
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let metrics = std::sync::Arc::new(Metrics::new());
        let svc = EmbedService::start(
            artifacts,
            BatcherOptions { batch_window_us: 0, max_batch: 32 },
            metrics,
        )
        .expect("embed service");
        let handle = svc.handle();
        results.push(eagle::bench::bench("embed/pjrt_single", 2_000, || {
            std::hint::black_box(handle.embed_one(text).unwrap());
        }));
        let texts: Vec<&str> = (0..32).map(|_| text).collect();
        results.push(eagle::bench::bench("embed/pjrt_batch32", 4_000, || {
            std::hint::black_box(handle.embed_many(&texts).unwrap());
        }));
    } else {
        println!("(skipping PJRT embed benches: artifacts not built)");
    }

    println!("\n== perf_hotpath ==");
    for r in &results {
        println!("{}", r.line());
    }
}
