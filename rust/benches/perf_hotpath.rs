//! Hot-path microbenchmarks (§Perf): every stage of the request path plus
//! the estimator ablations. Criterion-equivalent harness from
//! `eagle::bench` (adaptive iteration counts, p50/p99).
//!
//! Run: `cargo bench --bench perf_hotpath`
//!
//! `EAGLE_BENCH_SMOKE=1` shrinks every measurement window for CI;
//! `EAGLE_BENCH_JSON=1` (implied by smoke) writes `BENCH_perf_hotpath.json`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use eagle::bench::JsonReport;
use eagle::config::{EagleParams, EpochParams, ShardParams};
use eagle::coordinator::feedback::Verdict;
use eagle::coordinator::ingest::{IngestOptions, IngestPipeline};
use eagle::coordinator::router::{EagleRouter, Observation};
use eagle::coordinator::sharded::ShardedRouter;
use eagle::coordinator::snapshot::RouterWriter;
use eagle::coordinator::Router;
use eagle::elo::{Comparison, EloEngine, GlobalElo, Outcome};
use eagle::embedding::{BatcherOptions, EmbedService, Embedder, HashEmbedder};
use eagle::metrics::Metrics;
use eagle::tokenizer;
use eagle::util::{l2_normalize, percentile, Rng};
use eagle::vectordb::flat::FlatStore;
use eagle::vectordb::ivf::{IvfIndex, IvfParams};
use eagle::vectordb::kernel;
use eagle::vectordb::topk::TopK;
use eagle::vectordb::view::SegmentStore;
use eagle::vectordb::{Feedback, ReadIndex, VectorIndex};

const DIM: usize = 256;

/// Per-bench time target, capped hard in smoke mode.
fn target_ms(full: u64) -> u64 {
    if eagle::bench::smoke() {
        full.min(10)
    } else {
        full
    }
}

fn unit(rng: &mut Rng) -> Vec<f32> {
    let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
    l2_normalize(&mut v);
    v
}

fn rand_cmp(rng: &mut Rng) -> Comparison {
    let a = rng.below(11);
    let mut b = rng.below(10);
    if b >= a {
        b += 1;
    }
    let outcome = match rng.below(3) {
        0 => Outcome::WinA,
        1 => Outcome::WinB,
        _ => Outcome::Draw,
    };
    Comparison { a, b, outcome }
}

fn main() {
    let mut rng = Rng::new(0xBE);
    let mut results = Vec::new();

    // --- tokenizer ---
    let text = "Solve this word problem about train speed distance hours \
                please carefully show your reasoning with all details";
    results.push(eagle::bench::bench("tokenizer/tokenize_64", target_ms(200), || {
        std::hint::black_box(tokenizer::tokenize_default(text));
    }));

    // --- ELO ---
    let cmps: Vec<Comparison> = (0..1000).map(|_| rand_cmp(&mut rng)).collect();
    let mut engine = EloEngine::new(11, 32.0);
    results.push(eagle::bench::bench("elo/update_x1000", target_ms(200), || {
        engine.replay(&cmps);
    }));
    results.push(eagle::bench::bench("elo/global_init_10k_records", target_ms(300), || {
        let mut g = GlobalElo::new(11, 32.0);
        for chunk in cmps.chunks(100) {
            for _ in 0..1 {
                g.apply_new(chunk);
            }
        }
        std::hint::black_box(g.ratings());
    }));

    // --- vector stores ---
    for &n in &[1_000usize, 10_000] {
        let mut flat = FlatStore::with_capacity(DIM, n);
        for _ in 0..n {
            let v = unit(&mut rng);
            flat.add(&v, Feedback { comparisons: vec![rand_cmp(&mut rng)] });
        }
        let q = unit(&mut rng);
        results.push(eagle::bench::bench(
            &format!("vectordb/flat_scan_top20_n{n}"),
            target_ms(300),
            || {
                std::hint::black_box(flat.search(&q, 20));
            },
        ));

        let vectors: Vec<Vec<f32>> = (0..n).map(|_| unit(&mut rng)).collect();
        let payloads = (0..n)
            .map(|_| Feedback { comparisons: vec![rand_cmp(&mut rng)] })
            .collect();
        let ivf = IvfIndex::build(DIM, &vectors, payloads, IvfParams::default());
        results.push(eagle::bench::bench(
            &format!("vectordb/ivf_top20_n{n}_probe8of64"),
            target_ms(300),
            || {
                std::hint::black_box(ivf.search(&q, 20));
            },
        ));
    }

    // --- router scoring path (local elo replay included) ---
    let obs: Vec<Observation> = (0..5_000)
        .map(|_| Observation {
            embedding: unit(&mut rng),
            comparisons: (0..3).map(|_| rand_cmp(&mut rng)).collect(),
        })
        .collect();
    let router = EagleRouter::fit(
        EagleParams::default(),
        11,
        FlatStore::with_capacity(DIM, obs.len()),
        &obs,
    );
    let q = unit(&mut rng);
    results.push(eagle::bench::bench("router/combined_scores_store5k", target_ms(400), || {
        std::hint::black_box(router.scores(&q));
    }));
    let batch_queries: Vec<Vec<f32>> = (0..32).map(|_| unit(&mut rng)).collect();
    results.push(eagle::bench::bench("router/score_batch32_store5k", target_ms(400), || {
        std::hint::black_box(router.score_batch(&batch_queries));
    }));
    let global_router = EagleRouter::fit(
        EagleParams { p: 1.0, ..Default::default() },
        11,
        FlatStore::with_capacity(DIM, obs.len()),
        &obs,
    );
    results.push(eagle::bench::bench("router/global_only_store5k", target_ms(200), || {
        std::hint::black_box(global_router.scores(&q));
    }));

    // --- hash embedder (fallback path) ---
    let hash = HashEmbedder::new(DIM);
    results.push(eagle::bench::bench("embed/hash_fallback_1", target_ms(200), || {
        std::hint::black_box(hash.embed(&[text]));
    }));

    // --- PJRT embedder (serving path; skipped without artifacts) ---
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let metrics = std::sync::Arc::new(Metrics::new());
        let svc = EmbedService::start(
            artifacts,
            BatcherOptions { batch_window_us: 0, max_batch: 32 },
            metrics,
        )
        .expect("embed service");
        let handle = svc.handle();
        results.push(eagle::bench::bench("embed/pjrt_single", target_ms(2_000), || {
            std::hint::black_box(handle.embed_one(text).unwrap());
        }));
        let texts: Vec<&str> = (0..32).map(|_| text).collect();
        results.push(eagle::bench::bench("embed/pjrt_batch32", target_ms(4_000), || {
            std::hint::black_box(handle.embed_many(&texts).unwrap());
        }));
    } else {
        println!("(skipping PJRT embed benches: artifacts not built)");
    }

    // --- snapshot routing: ring load + scoring through a snapshot ---
    let snap_writer = {
        let mut w = RouterWriter::new(
            EagleParams::default(),
            11,
            DIM,
            EpochParams { publish_every: 64, publish_interval_ms: 5 },
        );
        for obs in &obs {
            w.observe(obs.clone());
        }
        w.publish();
        w
    };
    let ring = snap_writer.ring();
    results.push(eagle::bench::bench("snapshot/ring_load", target_ms(100), || {
        std::hint::black_box(ring.load());
    }));
    results.push(eagle::bench::bench("snapshot/scores_store5k", target_ms(400), || {
        let snap = ring.load();
        std::hint::black_box(snap.scores(&q));
    }));

    println!("\n== perf_hotpath ==");
    for r in &results {
        println!("{}", r.line());
    }

    let mut report = JsonReport::new("perf_hotpath");
    for r in &results {
        report.push_result(r);
    }
    kernel_scan_sweep(&mut report);
    quant_scan_sweep(&mut report);
    ivf_nprobe_sweep(&mut report);
    contention_scenario(snap_writer, &mut report);
    sharded_storm_sweep(&obs, &mut report);
    ingest_pipeline_sweep(&mut report);
    persist_beat_sweep(&mut report);
    recovery_open_sweep(&mut report);
    replica_tail_sweep(&mut report);
    connection_scale_sweep(&mut report);
    if eagle::bench::json_enabled() {
        let path = report.write().expect("write bench json");
        println!("\nwrote {}", path.display());
    }
}

/// The seed's scan hot loop (4-way unrolled scalar dot), re-implemented
/// here verbatim so the kernel sweep's speedup is measured against the
/// pre-kernel baseline in-artifact, whatever backend dispatch picked.
fn seed_scalar_dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// The ISSUE 5 acceptance sweep: scan throughput over batch size × dim,
/// three ways — the seed scalar path, the kernel single-query path, and
/// the query-blocked multi-query path. Emits `kernel.b{B}.*` at the
/// serving dim (256) and `kernel.d{D}.b{B}.*` otherwise; the acceptance
/// gate is `kernel.b{B}.speedup_vs_scalar >= 2` at B >= 8.
fn kernel_scan_sweep(report: &mut JsonReport) {
    const K: usize = 20;
    let n: usize = if eagle::bench::smoke() { 4_096 } else { 16_384 };
    let dims: &[usize] = &[64, 256];
    let batches: &[usize] = &[1, 8, 32];

    println!(
        "\n== scan kernels (backend {}, {n}-row corpus, top-{K}) ==",
        kernel::active().name()
    );
    for &dim in dims {
        let mut rng = Rng::new(0x5EED ^ dim as u64);
        let mut store = SegmentStore::new(dim);
        let mut slab: Vec<f32> = Vec::with_capacity(n * dim);
        for _ in 0..n {
            let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            l2_normalize(&mut v);
            slab.extend_from_slice(&v);
            store.add(&v, Feedback { comparisons: vec![rand_cmp(&mut rng)] });
        }
        let view = store.freeze();
        for &b in batches {
            let queries: Vec<Vec<f32>> = (0..b)
                .map(|_| {
                    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                    l2_normalize(&mut v);
                    v
                })
                .collect();
            let qrefs: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();

            // sanity: the blocked path must retain exactly the per-query hits
            let blocked_hits = view.search_batch(&qrefs, K);
            for (q, hits) in qrefs.iter().zip(&blocked_hits) {
                assert_eq!(hits, &view.search(q, K), "blocked scan diverged from singles");
            }

            let r_scalar = eagle::bench::bench(
                &format!("kernel/scalar_d{dim}_b{b}"),
                target_ms(150),
                || {
                    for q in &queries {
                        let mut topk = TopK::new(K);
                        for r in 0..n {
                            topk.push(r as u32, seed_scalar_dot(&slab[r * dim..(r + 1) * dim], q));
                        }
                        std::hint::black_box(topk.into_sorted());
                    }
                },
            );
            let r_single = eagle::bench::bench(
                &format!("kernel/single_d{dim}_b{b}"),
                target_ms(150),
                || {
                    for q in &qrefs {
                        std::hint::black_box(view.search(q, K));
                    }
                },
            );
            let r_blocked = eagle::bench::bench(
                &format!("kernel/blocked_d{dim}_b{b}"),
                target_ms(150),
                || {
                    std::hint::black_box(view.search_batch(&qrefs, K));
                },
            );
            let qps = |r: &eagle::bench::BenchResult| b as f64 * 1e9 / r.mean_ns.max(1.0);
            let (scalar_qps, single_qps, blocked_qps) =
                (qps(&r_scalar), qps(&r_single), qps(&r_blocked));
            let speedup = blocked_qps / scalar_qps.max(1e-9);
            println!(
                "  d={dim:<3} B={b:<2}: scalar {scalar_qps:>9.0} q/s | kernel single \
                 {single_qps:>9.0} q/s | blocked {blocked_qps:>9.0} q/s  ({speedup:.2}x vs seed)"
            );
            let prefix = if dim == DIM {
                format!("kernel.b{b}")
            } else {
                format!("kernel.d{dim}.b{b}")
            };
            report.push(&format!("{prefix}.scalar_qps"), scalar_qps);
            report.push(&format!("{prefix}.single_qps"), single_qps);
            report.push(&format!("{prefix}.qps"), blocked_qps);
            report.push(&format!("{prefix}.speedup_vs_scalar"), speedup);
        }
    }
}

/// The ISSUE 8 acceptance sweep: SQ8-quantized scan + exact rerank vs
/// the exact f32 blocked scan over dim × batch. Emits
/// `quant.d{D}.b{B}.qps` / `.recall_ratio` / `.bytes_per_query` (plus
/// the f32 baseline qps and the speedup); the acceptance gate is
/// `speedup_vs_f32 >= 2` with `recall_ratio >= 0.99` at dim 256, B >= 8,
/// default rerank factor. The win is bandwidth: the quantized scan
/// streams 1 byte/element instead of 4, and the rerank touches only
/// `rerank_factor * K` exact rows.
fn quant_scan_sweep(report: &mut JsonReport) {
    use eagle::vectordb::quant::{QuantCache, QuantView, DEFAULT_RERANK_FACTOR};

    const K: usize = 20;
    let n: usize = if eagle::bench::smoke() { 4_096 } else { 16_384 };
    let dims: &[usize] = &[64, 256];
    let batches: &[usize] = &[1, 8, 32];

    println!(
        "\n== sq8 quantized scan (backend {}, {n}-row corpus, top-{K}, rerank x{}) ==",
        kernel::active().name(),
        DEFAULT_RERANK_FACTOR
    );
    for &dim in dims {
        let mut rng = Rng::new(0x5_08 ^ dim as u64);
        let mut store = SegmentStore::new(dim);
        for _ in 0..n {
            let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            l2_normalize(&mut v);
            store.add(&v, Feedback { comparisons: vec![rand_cmp(&mut rng)] });
        }
        let view = store.freeze();
        let mut cache = QuantCache::new();
        // min_rows = 1: quantize every sealed segment so the sweep
        // measures the quantized path, not the exact-tail fallback
        let qview = QuantView::build(view.clone(), &mut cache, 1, DEFAULT_RERANK_FACTOR);
        assert_eq!(qview.quantized_rows(), n, "corpus not fully quantized");

        for &b in batches {
            let queries: Vec<Vec<f32>> = (0..b.max(32))
                .map(|_| {
                    let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
                    l2_normalize(&mut v);
                    v
                })
                .collect();
            let qrefs: Vec<&[f32]> = queries[..b].iter().map(|q| q.as_slice()).collect();

            // quality: recall@K of the quantized+rerank hits vs exact,
            // over a fixed 32-query panel (batch path == singles by
            // construction, asserted below)
            let panel: Vec<&[f32]> = queries.iter().map(|q| q.as_slice()).collect();
            let quant_hits = qview.search_batch(&panel, K);
            let mut recall_sum = 0.0f64;
            for (q, got) in panel.iter().zip(&quant_hits) {
                assert_eq!(got, &qview.search(q, K), "quant batch diverged from singles");
                let want: Vec<u32> = view.search(q, K).into_iter().map(|h| h.id).collect();
                let inter = got.iter().filter(|h| want.contains(&h.id)).count();
                recall_sum += inter as f64 / K as f64;
            }
            let recall = recall_sum / panel.len() as f64;

            let r_f32 = eagle::bench::bench(
                &format!("quant/f32_d{dim}_b{b}"),
                target_ms(150),
                || {
                    std::hint::black_box(view.search_batch(&qrefs, K));
                },
            );
            let r_quant = eagle::bench::bench(
                &format!("quant/sq8_d{dim}_b{b}"),
                target_ms(150),
                || {
                    std::hint::black_box(qview.search_batch(&qrefs, K));
                },
            );
            let qps = |r: &eagle::bench::BenchResult| b as f64 * 1e9 / r.mean_ns.max(1.0);
            let (f32_qps, quant_qps) = (qps(&r_f32), qps(&r_quant));
            let speedup = quant_qps / f32_qps.max(1e-9);
            let bytes = qview.scan_bytes_per_query(K);
            println!(
                "  d={dim:<3} B={b:<2}: f32 {f32_qps:>9.0} q/s | sq8+rerank \
                 {quant_qps:>9.0} q/s ({speedup:.2}x) | recall@{K} {recall:.3} | \
                 {bytes} B/query"
            );
            let prefix = format!("quant.d{dim}.b{b}");
            report.push(&format!("{prefix}.f32_qps"), f32_qps);
            report.push(&format!("{prefix}.qps"), quant_qps);
            report.push(&format!("{prefix}.speedup_vs_f32"), speedup);
            report.push(&format!("{prefix}.recall_ratio"), recall);
            report.push(&format!("{prefix}.bytes_per_query"), bytes as f64);
        }
    }
}

/// The ROADMAP-open IVF quality surface: recall@20 vs exact and probe
/// throughput swept over `nprobe`, so the quality/cost trade-off of
/// partial probes is tracked per PR (`ivf.p{P}.recall_ratio` /
/// `ivf.p{P}.qps`).
fn ivf_nprobe_sweep(report: &mut JsonReport) {
    const K: usize = 20;
    const DIM_IVF: usize = 64;
    const N_CELLS: usize = 64;
    let n: usize = if eagle::bench::smoke() { 4_000 } else { 20_000 };
    let n_centers = 32;

    // clustered corpus: partial probes have structure to exploit
    let mut rng = Rng::new(0x1F5);
    let centers: Vec<Vec<f32>> = (0..n_centers)
        .map(|_| {
            let mut v: Vec<f32> = (0..DIM_IVF).map(|_| rng.normal() as f32).collect();
            l2_normalize(&mut v);
            v
        })
        .collect();
    let mut vectors = Vec::with_capacity(n);
    let mut flat = FlatStore::with_capacity(DIM_IVF, n);
    for i in 0..n {
        let c = &centers[i % n_centers];
        let mut v: Vec<f32> = c.iter().map(|&x| x + 0.2 * rng.normal() as f32).collect();
        l2_normalize(&mut v);
        flat.add(&v, Feedback { comparisons: vec![rand_cmp(&mut rng)] });
        vectors.push(v);
    }
    let payloads = (0..n).map(|_| Feedback { comparisons: vec![rand_cmp(&mut rng)] }).collect();
    let params = IvfParams { n_cells: N_CELLS, nprobe: N_CELLS, kmeans_iters: 5, seed: 0x1F5 };
    let base = IvfIndex::build(DIM_IVF, &vectors, payloads, params);

    let queries: Vec<Vec<f32>> = (0..32)
        .map(|i| {
            let c = &centers[(i * 7) % n_centers];
            let mut v: Vec<f32> = c.iter().map(|&x| x + 0.2 * rng.normal() as f32).collect();
            l2_normalize(&mut v);
            v
        })
        .collect();
    let exact: Vec<Vec<u32>> = queries
        .iter()
        .map(|q| flat.search(q, K).into_iter().map(|h| h.id).collect())
        .collect();

    println!("\n== ivf nprobe sweep (n={n}, {N_CELLS} cells, recall@{K} vs exact) ==");
    for &p in &[1usize, 2, 4, 8, 16, 32, 64] {
        let mut idx = base.clone();
        idx.set_nprobe(p);
        let mut recall_sum = 0.0f64;
        for (q, want) in queries.iter().zip(&exact) {
            let got: Vec<u32> = idx.search(q, K).into_iter().map(|h| h.id).collect();
            let inter = got.iter().filter(|id| want.contains(id)).count();
            recall_sum += inter as f64 / K as f64;
        }
        let recall = recall_sum / queries.len() as f64;
        let r = eagle::bench::bench(&format!("ivf/probe{p}of{N_CELLS}"), target_ms(100), || {
            for q in &queries {
                std::hint::black_box(idx.search(q, K));
            }
        });
        let qps = queries.len() as f64 * 1e9 / r.mean_ns.max(1.0);
        println!("  nprobe={p:<2}: recall@{K} {recall:.3}  {qps:>9.0} q/s");
        report.push(&format!("ivf.p{p}.recall_ratio"), recall);
        report.push(&format!("ivf.p{p}.qps"), qps);
    }
}

/// The acceptance scenario for RCU snapshot routing: batched route
/// throughput while the applier ingests >= 10k records/s must stay within
/// 10% of the zero-feedback baseline. Quiet and stormy measurement
/// windows alternate so the growing store affects both modes equally.
fn contention_scenario(mut writer: RouterWriter, report: &mut JsonReport) {
    const BATCH: usize = 32;
    const WINDOW: Duration = Duration::from_millis(30);
    const TARGET_INGEST_PER_S: u64 = 20_000;
    let windows_per_mode: usize = if eagle::bench::smoke() { 3 } else { 12 };

    let ring = writer.ring();
    let stop = Arc::new(AtomicBool::new(false));
    let storm_on = Arc::new(AtomicBool::new(false));
    let ingested = Arc::new(AtomicU64::new(0));
    let storm_ns = Arc::new(AtomicU64::new(0));

    let stop_w = stop.clone();
    let storm_on_w = storm_on.clone();
    let ingested_w = ingested.clone();
    let storm_ns_w = storm_ns.clone();
    let feeder = std::thread::spawn(move || {
        let mut rng = Rng::new(0x570F);
        // throttle to ~TARGET_INGEST_PER_S: ingest small bursts, then nap
        let burst = 32u64;
        let nap = Duration::from_nanos(1_000_000_000 * burst / TARGET_INGEST_PER_S);
        while !stop_w.load(Ordering::Relaxed) {
            if !storm_on_w.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_micros(200));
                continue;
            }
            let t0 = Instant::now();
            for _ in 0..burst {
                let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
                l2_normalize(&mut v);
                let a = rng.below(11);
                let mut b = rng.below(10);
                if b >= a {
                    b += 1;
                }
                writer.observe(Observation::single(
                    v,
                    Comparison { a, b, outcome: Outcome::WinA },
                ));
            }
            ingested_w.fetch_add(burst, Ordering::Relaxed);
            let spent = t0.elapsed();
            storm_ns_w.fetch_add(spent.as_nanos() as u64, Ordering::Relaxed);
            if spent < nap {
                std::thread::sleep(nap - spent);
                storm_ns_w.fetch_add((nap - spent).as_nanos() as u64, Ordering::Relaxed);
            }
        }
    });

    let mut rng = Rng::new(0xBEEF);
    let queries: Vec<Vec<f32>> = (0..BATCH)
        .map(|_| {
            let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
            l2_normalize(&mut v);
            v
        })
        .collect();

    // (queries served, busy seconds, per-batch latencies in us)
    let mut measure = |lat: &mut Vec<f64>| -> (u64, f64) {
        let until = Instant::now() + WINDOW;
        let mut served = 0u64;
        let t0 = Instant::now();
        while Instant::now() < until {
            let tb = Instant::now();
            let snap = ring.load();
            std::hint::black_box(snap.score_batch(&queries));
            lat.push(tb.elapsed().as_nanos() as f64 / 1e3);
            served += BATCH as u64;
        }
        (served, t0.elapsed().as_secs_f64())
    };

    let (mut quiet_lat, mut storm_lat) = (Vec::new(), Vec::new());
    let (mut quiet_served, mut quiet_secs) = (0u64, 0f64);
    let (mut storm_served, mut storm_secs) = (0u64, 0f64);
    for _ in 0..windows_per_mode {
        storm_on.store(false, Ordering::Relaxed);
        let (s, t) = measure(&mut quiet_lat);
        quiet_served += s;
        quiet_secs += t;

        storm_on.store(true, Ordering::Relaxed);
        let (s, t) = measure(&mut storm_lat);
        storm_served += s;
        storm_secs += t;
    }
    storm_on.store(false, Ordering::Relaxed);
    stop.store(true, Ordering::Relaxed);
    feeder.join().unwrap();

    let quiet_tput = quiet_served as f64 / quiet_secs;
    let storm_tput = storm_served as f64 / storm_secs;
    let ingest_rate =
        ingested.load(Ordering::Relaxed) as f64 / (storm_ns.load(Ordering::Relaxed) as f64 / 1e9);
    let ratio = storm_tput / quiet_tput;

    println!("\n== snapshot contention (batched route, {BATCH} q/batch) ==");
    println!(
        "  quiet: {:>9.0} q/s  p50 {:>8.1} us/batch  p99 {:>8.1} us/batch",
        quiet_tput,
        percentile(&quiet_lat, 50.0),
        percentile(&quiet_lat, 99.0),
    );
    println!(
        "  storm: {:>9.0} q/s  p50 {:>8.1} us/batch  p99 {:>8.1} us/batch  \
         (applier ingesting {:.0} rec/s)",
        storm_tput,
        percentile(&storm_lat, 50.0),
        percentile(&storm_lat, 99.0),
        ingest_rate,
    );
    let verdict = if ratio >= 0.90 { "PASS" } else { "WARN" };
    println!(
        "  storm/quiet throughput ratio = {ratio:.3}  (target >= 0.900: {verdict})"
    );
    if ingest_rate < 10_000.0 {
        println!("  WARN: ingest rate below the 10k rec/s storm target");
    }
    report.push("contention.quiet_qps", quiet_tput);
    report.push("contention.storm_qps", storm_tput);
    report.push("contention.storm_quiet_ratio", ratio);
    report.push("contention.ingest_rps", ingest_rate);
}

/// The sharded ingest-pipeline arm (ISSUE 3 acceptance): end-to-end
/// feedback ingest throughput through the dispatcher + per-shard applier
/// threads, swept over the applier count K. Producers push pre-embedded
/// verdicts (the embed stage is the engine thread's own bench above);
/// the clock stops at the flush barrier, so every record is applied AND
/// published when the window closes. Target: K=4 >= 2x K=1.
fn ingest_pipeline_sweep(report: &mut JsonReport) {
    const N_MODELS: usize = 11;
    let shard_counts: &[usize] = if eagle::bench::smoke() { &[1, 4] } else { &[1, 2, 4, 8] };
    let records: usize = if eagle::bench::smoke() { 8_000 } else { 60_000 };
    const PRODUCERS: usize = 2;

    println!("\n== sharded ingest pipeline ({records} records, {PRODUCERS} producers, flush-to-publish) ==");
    let mut k1_rps = 0.0f64;
    for &k in shard_counts {
        // pre-generate the stream so producer-side RNG cost stays out of
        // the measurement window
        let mut rng = Rng::new(0x1A6E57 + k as u64);
        let per_producer = records / PRODUCERS;
        let slabs: Vec<Vec<Verdict>> = (0..PRODUCERS)
            .map(|_| {
                (0..per_producer)
                    .map(|_| {
                        let a = rng.below(N_MODELS);
                        let mut b = rng.below(N_MODELS - 1);
                        if b >= a {
                            b += 1;
                        }
                        Verdict {
                            embedding: unit(&mut rng),
                            model_a: a,
                            model_b: b,
                            score_a: [0.0, 0.5, 1.0][rng.below(3)],
                        }
                    })
                    .collect()
            })
            .collect();

        let router = ShardedRouter::new(
            EagleParams::default(),
            N_MODELS,
            DIM,
            EpochParams { publish_every: 64, publish_interval_ms: 5 },
            ShardParams { count: k, hash_seed: 0xEA61E },
        );
        let pipeline = Arc::new(IngestPipeline::start(
            router,
            None,
            IngestOptions {
                epoch: EpochParams { publish_every: 64, publish_interval_ms: 5 },
                // lane queues sized so backpressure throttles producers at
                // the raw queue only — the applied-count assert below
                // demands zero drops
                lane_queue_capacity: records,
                ..Default::default()
            },
        ));

        let t0 = Instant::now();
        let producers: Vec<_> = slabs
            .into_iter()
            .map(|slab| {
                let p = pipeline.clone();
                std::thread::spawn(move || {
                    for mut v in slab {
                        // bounded queues throttle the producer instead of
                        // dropping: retry until accepted
                        loop {
                            v = match p.try_push_verdict(v) {
                                Ok(()) => break,
                                Err(back) => {
                                    std::thread::yield_now();
                                    back
                                }
                            };
                        }
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        pipeline.flush();
        let secs = t0.elapsed().as_secs_f64();
        pipeline.shutdown();

        let m = pipeline.metrics();
        assert_eq!(m.applied.get() as usize, records, "pipeline lost records");
        let rps = records as f64 / secs;
        if k == 1 {
            k1_rps = rps;
        }
        let speedup = rps / k1_rps.max(1e-9);
        println!(
            "  K={k}: {rps:>9.0} rec/s applied+published  ({secs:.3} s, {speedup:.2}x vs K=1)"
        );
        report.push(&format!("ingest.k{k}.rps"), rps);
        report.push(&format!("ingest.k{k}.speedup_vs_k1"), speedup);
    }
}

/// Bytes on disk under `dir`, recursively.
fn dir_bytes(dir: &std::path::Path) -> u64 {
    let mut total = 0;
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            total += dir_bytes(&path);
        } else if let Ok(md) = std::fs::metadata(&path) {
            total += md.len();
        }
    }
    total
}

/// The ISSUE 4 acceptance sweep: persist-beat cost at growing corpus
/// sizes. The legacy path rewrites the whole corpus as one JSON blob per
/// beat (O(corpus)); the durable segment store appends + fsyncs the
/// delta and swaps a small manifest (O(delta)). Both are measured on the
/// same router state with the same fixed-size delta, so the emitted
/// `persist.n{N}.*` metrics show the legacy bytes growing with N while
/// the delta-beat bytes stay flat.
fn persist_beat_sweep(report: &mut JsonReport) {
    use eagle::coordinator::durable::{DurableOptions, DurableStore};
    const N_MODELS: usize = 11;
    const DELTA: usize = 256;
    let sizes: &[usize] = if eagle::bench::smoke() { &[2_000, 8_000] } else { &[10_000, 40_000] };
    let shards = ShardParams { count: 4, hash_seed: 0xEA61E };
    let root = std::env::temp_dir().join(format!("eagle_persist_sweep_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).expect("bench tmp dir");

    println!("\n== persist beat cost (full JSON vs segment delta, {DELTA}-record beats) ==");
    for &n in sizes {
        let mut rng = Rng::new(0x9E57 + n as u64);
        let mut router = ShardedRouter::new(
            EagleParams::default(),
            N_MODELS,
            DIM,
            EpochParams { publish_every: 64, publish_interval_ms: 5 },
            shards.clone(),
        );
        for _ in 0..n {
            let v = unit(&mut rng);
            router.observe(Observation::single(v, rand_cmp(&mut rng)));
        }
        router.publish_all();

        // (a) the legacy beat: serialize the world
        let json_path = root.join(format!("full_{n}.json"));
        let t0 = Instant::now();
        router.handle().load().persist(&json_path).expect("full JSON persist");
        let json_ms = t0.elapsed().as_secs_f64() * 1e3;
        let json_bytes = std::fs::metadata(&json_path).unwrap().len();

        // (b) the durable beat: bootstrap the store from the same
        // corpus, ingest a fixed delta, then fsync + checkpoint
        let dir = root.join(format!("durable_{n}"));
        let store = DurableStore::create_from_router(
            &dir,
            &router,
            DurableOptions { seal_bytes: 16 << 20, fsync: true, mmap: true },
        )
        .expect("bootstrap durable store");
        let mut writers: Vec<_> =
            (0..shards.count).map(|s| store.lane_writer(s).expect("lane writer")).collect();
        let deltas: Vec<(usize, u32, Observation)> = (0..DELTA)
            .map(|_| {
                let obs = Observation::single(unit(&mut rng), rand_cmp(&mut rng));
                let shard = router.shard_for(&obs.embedding);
                let gid = router.next_global_id();
                router.observe(obs.clone());
                (shard, gid, obs)
            })
            .collect();
        let before = dir_bytes(&dir);
        let t0 = Instant::now();
        for (shard, gid, obs) in &deltas {
            writers[*shard].append(*gid, obs).expect("delta append");
        }
        for w in &mut writers {
            w.sync().expect("delta fsync");
        }
        store
            .checkpoint_global(router.next_global_id(), router.global_elo().export_state())
            .expect("checkpoint");
        let delta_ms = t0.elapsed().as_secs_f64() * 1e3;
        let delta_bytes = dir_bytes(&dir).saturating_sub(before);

        let ratio = json_bytes as f64 / delta_bytes.max(1) as f64;
        println!(
            "  n={n}: full-JSON {json_bytes} B / {json_ms:.1} ms per beat  |  \
             segment delta {delta_bytes} B / {delta_ms:.2} ms per beat  \
             (full/delta bytes = {ratio:.0}x)"
        );
        report.push(&format!("persist.n{n}.full_json_bytes"), json_bytes as f64);
        report.push(&format!("persist.n{n}.full_json_ms"), json_ms);
        report.push(&format!("persist.n{n}.delta_beat_bytes"), delta_bytes as f64);
        report.push(&format!("persist.n{n}.delta_beat_ms"), delta_ms);
        report.push(&format!("persist.n{n}.full_over_delta_bytes_ratio"), ratio);
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// The ISSUE 10 acceptance sweep: restart cost. Open an n-record durable
/// store and rebuild a serving router two ways — a v1 store (every frame
/// re-decoded, every embedding byte re-verified) and the same corpus
/// compacted to mmap v2 (the aligned f32 slab is mapped and adopted
/// zero-copy; per-record work shrinks to the gid/comparison side arrays
/// and the ELO folds). Emits `persist.recover.*`; the acceptance gate is
/// `persist.recover.speedup_x >= 10` on a sealed, compacted store.
fn recovery_open_sweep(report: &mut JsonReport) {
    use eagle::coordinator::durable::{DurableOptions, DurableStore, StoreMeta};

    const N_MODELS: usize = 11;
    let n: usize = if eagle::bench::smoke() { 4_000 } else { 30_000 };
    let shards = ShardParams { count: 4, hash_seed: 0xEA61E };
    let cadence = EpochParams { publish_every: 64, publish_interval_ms: 5 };
    let root = std::env::temp_dir().join(format!("eagle_recover_sweep_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).expect("bench tmp dir");

    let mut open_ms = [0f64; 2];
    for (slot, (tag, mmap)) in [("decode", false), ("mmap", true)].into_iter().enumerate() {
        let dir = root.join(tag);
        let opts = DurableOptions { seal_bytes: 1 << 20, fsync: false, mmap };
        let meta = StoreMeta {
            params: EagleParams::default(),
            n_models: N_MODELS,
            dim: DIM,
            shards: shards.clone(),
        };
        {
            // identical corpus both ways (same seed), fully sealed so the
            // open replays segments, not delta-log tails
            let store = DurableStore::create(&dir, meta, opts.clone()).expect("create store");
            let mut writers: Vec<_> =
                (0..shards.count).map(|s| store.lane_writer(s).expect("lane writer")).collect();
            let mut router = ShardedRouter::new(
                EagleParams::default(),
                N_MODELS,
                DIM,
                cadence.clone(),
                shards.clone(),
            );
            let mut rng = Rng::new(0x8EC0);
            for _ in 0..n {
                let obs = Observation::single(unit(&mut rng), rand_cmp(&mut rng));
                let shard = router.shard_for(&obs.embedding);
                let gid = router.next_global_id();
                router.observe(obs.clone());
                writers[shard].append(gid, &obs).expect("append");
            }
            for w in &mut writers {
                w.sync().expect("sync");
                w.seal().expect("seal");
            }
            if mmap {
                // the steady-state restart shape: binary-counter fixpoint,
                // superseded files gone
                store.compact_once();
                store.gc_retired(Duration::ZERO);
            }
        }
        let t0 = Instant::now();
        let (_store, recovery) = DurableStore::open(&dir, opts).expect("reopen store");
        let router = recovery.into_router(cadence.clone()).expect("recover router");
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(router.store_len(), n, "{tag} recovery lost records");
        open_ms[slot] = ms;
        report.push(&format!("persist.recover.{tag}_open_ms"), ms);
    }
    let speedup = open_ms[0] / open_ms[1].max(1e-9);
    println!("\n== restart cost ({n}-record store, open -> serving router) ==");
    println!(
        "  v1 frame-decode {:.1} ms | compacted-v2 mmap {:.1} ms | speedup {speedup:.1}x",
        open_ms[0], open_ms[1]
    );
    report.push("persist.recover.speedup_x", speedup);
    let _ = std::fs::remove_dir_all(&root);
}

/// The follower-replication cost surface (PR 9): cold catch-up rate over
/// an n-record store (`replica.catchup_rps`), steady-state tail rate
/// while the leader keeps appending (`replica.tail_rps`, with the peak
/// unread log backlog in `replica.tail_lag_bytes_peak`), and the
/// promotion latency once the leader stops (`replica.promote_ms`). The
/// tail consumes the same bytes crash recovery replays, so these numbers
/// bound both failover lag and read-replica staleness.
fn replica_tail_sweep(report: &mut JsonReport) {
    use eagle::coordinator::durable::{DurableOptions, DurableStore, StoreMeta};
    use eagle::coordinator::replica::Follower;

    const N_MODELS: usize = 11;
    let n: usize = if eagle::bench::smoke() { 4_000 } else { 30_000 };
    let bursts: usize = if eagle::bench::smoke() { 20 } else { 200 };
    const BURST: usize = 64;
    let shards = ShardParams { count: 4, hash_seed: 0xEA61E };
    let cadence = EpochParams { publish_every: 64, publish_interval_ms: 5 };
    let dir = std::env::temp_dir().join(format!("eagle_replica_sweep_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let meta = StoreMeta {
        params: EagleParams::default(),
        n_models: N_MODELS,
        dim: DIM,
        shards: shards.clone(),
    };
    let opts = DurableOptions { seal_bytes: 256 << 10, fsync: false, mmap: true };
    let store = DurableStore::create(&dir, meta, opts.clone()).expect("create durable store");
    let mut writers: Vec<_> =
        (0..shards.count).map(|s| store.lane_writer(s).expect("lane writer")).collect();
    let mut router =
        ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence.clone(), shards.clone());
    let mut rng = Rng::new(0x8E81);
    let append_one = |router: &mut ShardedRouter, writers: &mut Vec<_>, rng: &mut Rng| {
        let obs = Observation::single(unit(rng), rand_cmp(rng));
        let shard = router.shard_for(&obs.embedding);
        let gid = router.next_global_id();
        router.observe(obs.clone());
        writers[shard].append(gid, &obs).expect("delta append");
    };
    for i in 0..n {
        append_one(&mut router, &mut writers, &mut rng);
        if i % 1024 == 1023 {
            store
                .checkpoint_global(router.next_global_id(), router.global_elo().export_state())
                .expect("checkpoint");
        }
    }
    for w in &mut writers {
        w.sync().expect("sync");
    }

    // (a) cold catch-up: open + drain, the warm-standby bootstrap cost
    let t0 = Instant::now();
    let mut follower = Follower::open(&dir, cadence).expect("follower open");
    while follower.poll().expect("catch-up poll").applied > 0 {}
    let catchup_secs = t0.elapsed().as_secs_f64();
    let catchup_rps = follower.applied_records() as f64 / catchup_secs.max(1e-9);

    // (b) steady-state tail: the leader keeps appending in bursts (some
    // left unsynced, so the follower sees buffered/torn tails), one poll
    // per burst
    let before = follower.applied_records();
    let mut lag_peak = 0u64;
    let t0 = Instant::now();
    for i in 0..bursts {
        for _ in 0..BURST {
            append_one(&mut router, &mut writers, &mut rng);
        }
        if i % 2 == 0 {
            for w in &mut writers {
                w.sync().expect("sync");
            }
        }
        if i % 8 == 7 {
            writers[i % shards.count].seal().expect("seal");
        }
        let s = follower.poll().expect("tail poll");
        lag_peak = lag_peak.max(s.lag_bytes);
    }
    for w in &mut writers {
        w.sync().expect("sync");
    }
    while follower.poll().expect("drain poll").applied > 0 {}
    let tail_secs = t0.elapsed().as_secs_f64();
    let tailed = follower.applied_records() - before;
    let tail_rps = tailed as f64 / tail_secs.max(1e-9);

    // (c) promotion: leader stops (writers + store drop, lock released),
    // the standby takes over
    drop(writers);
    drop(store);
    let t0 = Instant::now();
    let promotion = follower.promote(opts).unwrap_or_else(|e| panic!("promote: {:#}", e.error));
    let promote_ms = t0.elapsed().as_secs_f64() * 1e3;
    drop(promotion);

    println!("\n== follower replication (K={}, {n}-record store) ==", shards.count);
    println!(
        "  catch-up {catchup_rps:>9.0} rec/s | tail {tail_rps:>9.0} rec/s \
         (peak lag {lag_peak} B) | promote {promote_ms:.1} ms"
    );
    report.push("replica.catchup_rps", catchup_rps);
    report.push("replica.tail_rps", tail_rps);
    report.push("replica.tail_lag_bytes_peak", lag_peak as f64);
    report.push("replica.promote_ms", promote_ms);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE 6 acceptance sweep: route latency for one active client
/// while N idle keep-alive connections are parked on the serving event
/// loop. Under the old worker-pool server a handful of idle clients
/// pinned every worker inside its read-timeout poll, so this curve
/// exploded; with readiness polling an idle connection costs zero
/// wakeups and `conn.c{N}.p99_us` / `conn.c{N}.qps` should stay flat
/// from 100 to 10k connections (fd limits permitting — the sweep stops
/// scaling, with a note, at the first connect failure).
fn connection_scale_sweep(report: &mut JsonReport) {
    use eagle::coordinator::registry::ModelRegistry;
    use eagle::server::client::EagleClient;
    use eagle::server::{Admission, Server, ServerOptions, ServerState};

    const DIM_SRV: usize = 32;
    let levels: &[usize] = if eagle::bench::smoke() { &[16, 64] } else { &[100, 1_000, 10_000] };
    let window = if eagle::bench::smoke() {
        Duration::from_millis(80)
    } else {
        Duration::from_millis(500)
    };

    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start_hash(
        DIM_SRV,
        BatcherOptions { batch_window_us: 100, max_batch: 16 },
        metrics.clone(),
    );
    let registry = ModelRegistry::routerbench();
    let router = EagleRouter::new(EagleParams::default(), registry.len(), FlatStore::new(DIM_SRV));
    let state = Arc::new(
        ServerState::builder(router, registry, service.handle(), metrics)
            .options(ServerOptions {
                epoch: EpochParams { publish_every: 64, publish_interval_ms: 5 },
                admission: Admission {
                    max_connections: 16_384,
                    max_inflight: 256,
                    // parked connections must survive the measurement window
                    idle_timeout_ms: 0,
                },
                ..Default::default()
            })
            .build(),
    );
    let server = Server::start(state, "127.0.0.1:0", 2).expect("bench server");
    let addr = server.addr.to_string();

    let mut client = EagleClient::connect(&addr).expect("bench client");
    let mut idle: Vec<std::net::TcpStream> = Vec::new();

    println!("\n== connection scale (1 active client vs N idle keep-alive conns) ==");
    for &n in levels {
        while idle.len() < n {
            match std::net::TcpStream::connect(&addr) {
                Ok(s) => idle.push(s),
                Err(e) => {
                    println!("  (stopped scaling at {} idle conns: {e})", idle.len());
                    break;
                }
            }
        }
        if idle.len() < n {
            break;
        }
        // let the event loop drain its accept backlog before measuring
        std::thread::sleep(Duration::from_millis(20));

        for i in 0..16 {
            client.route(&format!("warmup probe {i}"), 1.0).expect("warmup route");
        }
        let mut lat = Vec::new();
        let mut seq = 0u64;
        let until = Instant::now() + window;
        let t0 = Instant::now();
        while Instant::now() < until {
            let tb = Instant::now();
            client.route(&format!("scale probe {seq}"), 1.0).expect("route under idle load");
            lat.push(tb.elapsed().as_nanos() as f64 / 1e3);
            seq += 1;
        }
        let secs = t0.elapsed().as_secs_f64();
        let qps = lat.len() as f64 / secs.max(1e-9);
        let p99 = percentile(&lat, 99.0);
        println!(
            "  c={n:<5}: {qps:>8.0} q/s  p50 {:>7.1} us  p99 {p99:>7.1} us",
            percentile(&lat, 50.0),
        );
        report.push(&format!("conn.c{n}.p99_us"), p99);
        report.push(&format!("conn.c{n}.qps"), qps);
    }
    drop(idle);
    drop(client);
    server.shutdown();
}

/// The sharded scatter-gather arm: batched route throughput through a
/// `ShardedRouter` handle while a feeder ingests a >= 10k records/s storm
/// through the same router, swept over shard counts. Scatter parallelism
/// should scale throughput with K (up to the core count); every K scores
/// bit-identically, so this sweep is purely a performance surface.
fn sharded_storm_sweep(obs: &[Observation], report: &mut JsonReport) {
    const BATCH: usize = 32;
    const TARGET_INGEST_PER_S: u64 = 20_000;
    let shard_counts: &[usize] = if eagle::bench::smoke() { &[1, 4] } else { &[1, 2, 4, 8] };
    let window = if eagle::bench::smoke() {
        Duration::from_millis(150)
    } else {
        Duration::from_millis(600)
    };

    println!("\n== sharded scatter-gather (batched route, {BATCH} q/batch, ingest storm) ==");
    for &k in shard_counts {
        let mut router = ShardedRouter::new(
            EagleParams::default(),
            11,
            DIM,
            EpochParams { publish_every: 64, publish_interval_ms: 5 },
            ShardParams { count: k, hash_seed: 0xEA61E },
        );
        for o in obs {
            router.observe(o.clone());
        }
        router.publish_all();
        let handle = router.handle();

        let stop = Arc::new(AtomicBool::new(false));
        let ingested = Arc::new(AtomicU64::new(0));
        let stop_w = stop.clone();
        let ingested_w = ingested.clone();
        let feeder = std::thread::spawn(move || {
            let mut rng = Rng::new(0x570F + k as u64);
            let burst = 32u64;
            let nap = Duration::from_nanos(1_000_000_000 * burst / TARGET_INGEST_PER_S);
            let t0 = Instant::now();
            while !stop_w.load(Ordering::Relaxed) {
                let tb = Instant::now();
                for _ in 0..burst {
                    let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
                    l2_normalize(&mut v);
                    let a = rng.below(11);
                    let mut b = rng.below(10);
                    if b >= a {
                        b += 1;
                    }
                    router.observe(Observation::single(
                        v,
                        Comparison { a, b, outcome: Outcome::WinA },
                    ));
                }
                ingested_w.fetch_add(burst, Ordering::Relaxed);
                let spent = tb.elapsed();
                if spent < nap {
                    std::thread::sleep(nap - spent);
                }
            }
            t0.elapsed().as_secs_f64()
        });

        let mut rng = Rng::new(0xBEEF);
        let queries: Vec<Vec<f32>> = (0..BATCH).map(|_| unit(&mut rng)).collect();
        let mut lat = Vec::new();
        let mut served = 0u64;
        let until = Instant::now() + window;
        let t0 = Instant::now();
        while Instant::now() < until {
            let tb = Instant::now();
            let snap = handle.load();
            std::hint::black_box(snap.score_batch(&queries));
            lat.push(tb.elapsed().as_nanos() as f64 / 1e3);
            served += BATCH as u64;
        }
        let secs = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::Relaxed);
        let storm_secs = feeder.join().unwrap();

        let tput = served as f64 / secs;
        let ingest_rate = ingested.load(Ordering::Relaxed) as f64 / storm_secs.max(1e-9);
        println!(
            "  K={k}: {tput:>9.0} q/s  p50 {:>8.1} us/batch  p99 {:>8.1} us/batch  \
             (ingest {ingest_rate:.0} rec/s)",
            percentile(&lat, 50.0),
            percentile(&lat, 99.0),
        );
        if ingest_rate < 10_000.0 {
            println!("       WARN: ingest rate below the 10k rec/s storm target");
        }
        report.push(&format!("sharded.k{k}.route_qps"), tput);
        report.push(&format!("sharded.k{k}.p50_us"), percentile(&lat, 50.0));
        report.push(&format!("sharded.k{k}.p99_us"), percentile(&lat, 99.0));
        report.push(&format!("sharded.k{k}.ingest_rps"), ingest_rate);
    }
}
