//! Fig 3b reproduction: routing quality (summed AUC over the 7 datasets)
//! as the feedback corpus grows 70% -> 85% -> 100%.
//!
//! Paper shape: Eagle above all baselines at every stage, with an average
//! improvement of 8.65% (70%), 9.21% (85%), 9.92% (100%) over the three
//! baselines' mean.
//!
//! Plus the serving-side counterpart of the incremental-update claim:
//! route latency (p50/p99 through published snapshots) stays flat while
//! the writer ingests the 70%->100% feedback delta as a storm — swept
//! over shard counts, since the sharded scatter-gather core is how the
//! serving path absorbs the storm at scale.
//!
//! Run: `cargo bench --bench fig3b_incremental`
//!
//! `EAGLE_BENCH_SMOKE=1` shrinks the storm windows for CI;
//! `EAGLE_BENCH_JSON=1` (implied) writes `BENCH_fig3b_incremental.json`.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use eagle::bench::{fmt, print_table, JsonReport};
use eagle::config::{EpochParams, ShardParams};
use eagle::coordinator::router::EagleRouter;
use eagle::coordinator::sharded::ShardedRouter;
use eagle::routerbench::DATASETS;
use eagle::util::percentile;
use eagle::vectordb::flat::FlatStore;

const STAGES: [f64; 3] = [0.70, 0.85, 1.00];

fn main() {
    let (_rig, exp, cfg) = common::setup("fig3b");
    let routers = ["eagle", "knn", "mlp", "svm"];

    let mut sums = vec![[0.0f64; 3]; routers.len()];
    for (ri, r) in routers.iter().enumerate() {
        for (stage_i, frac) in STAGES.iter().enumerate() {
            for si in 0..DATASETS.len() {
                let router = common::fit_router(&exp, &cfg, r, si, *frac);
                sums[ri][stage_i] += exp.eval(router.as_ref(), si).auc();
            }
        }
    }

    let mut rows = vec![vec![
        "router".to_string(),
        "70%".to_string(),
        "85%".to_string(),
        "100%".to_string(),
    ]];
    for (ri, r) in routers.iter().enumerate() {
        rows.push(vec![
            r.to_string(),
            fmt(sums[ri][0], 4),
            fmt(sums[ri][1], 4),
            fmt(sums[ri][2], 4),
        ]);
    }
    print_table("Fig 3b — summed AUC by feedback stage", &rows);

    println!();
    let mut report = JsonReport::new("fig3b_incremental");
    for (stage_i, (label, paper)) in
        [("70%", 8.65), ("85%", 9.21), ("100%", 9.92)].iter().enumerate()
    {
        let baseline_mean: f64 =
            (1..routers.len()).map(|ri| sums[ri][stage_i]).sum::<f64>() / 3.0;
        let imp = (sums[0][stage_i] - baseline_mean) / baseline_mean * 100.0;
        println!(
            "stage {label}: eagle improvement over baseline mean = {imp:+.2}% \
             (paper: +{paper:.2}%)"
        );
        report.push(&format!("auc.eagle.stage{stage_i}"), sums[0][stage_i]);
        report.push(&format!("auc.improvement_pct.stage{stage_i}"), imp);
    }

    incremental_storm_arm(&exp, &cfg, &mut report);
    if eagle::bench::json_enabled() {
        let path = report.write().expect("write bench json");
        println!("\nwrote {}", path.display());
    }
}

/// Route p50/p99 through published snapshots while the 70%->100%
/// feedback delta streams in at full rate, vs. idle before and after —
/// swept over shard counts of the scatter-gather router.
fn incremental_storm_arm(
    exp: &eagle::eval::harness::Experiment,
    cfg: &eagle::config::Config,
    report: &mut JsonReport,
) {
    let split = 0;
    let warm = exp.observations(split, 0.70);
    let all = exp.observations(split, 1.0);
    if warm.is_empty() || warm.len() >= all.len() {
        println!("(skipping storm arm: no 70%->100% feedback delta at this scale)");
        return;
    }
    let delta: Vec<_> = all[warm.len()..].to_vec();
    let probes: Vec<Vec<f32>> =
        warm.iter().step_by(37).take(24).map(|o| o.embedding.clone()).collect();
    let idle_batches = if eagle::bench::smoke() { 120 } else { 400 };
    let min_storm_ms = if eagle::bench::smoke() { 150 } else { 400 };
    let shard_counts: &[usize] = if eagle::bench::smoke() { &[1, 2] } else { &[1, 4] };

    for &k in shard_counts {
        let base = EagleRouter::fit(
            cfg.eagle.clone(),
            exp.n_models(),
            FlatStore::new(probes[0].len()),
            &warm,
        );
        let mut sharded = ShardedRouter::from_router(
            base,
            EpochParams { publish_every: 64, publish_interval_ms: 5 },
            ShardParams { count: k, hash_seed: 0xEA61E },
        );
        let handle = sharded.handle();
        let delta_k = delta.clone();

        let sample = |keep: &dyn Fn(usize) -> bool| -> (f64, f64, usize) {
            let mut lat = Vec::new();
            let mut i = 0usize;
            while keep(i) {
                let t0 = Instant::now();
                let snap = handle.load();
                std::hint::black_box(snap.score_batch(&probes));
                lat.push(t0.elapsed().as_nanos() as f64 / 1e3);
                i += 1;
            }
            (percentile(&lat, 50.0), percentile(&lat, 99.0), lat.len())
        };

        // idle baseline at 70%
        let (idle_p50, idle_p99, _) = sample(&|i| i < idle_batches);

        // storm: stream the 70%->100% delta in, replaying it cyclically so
        // the storm lasts long enough to measure (>= one full pass)
        let storming = Arc::new(AtomicBool::new(true));
        let storming_w = storming.clone();
        let feeder = std::thread::spawn(move || {
            let t0 = Instant::now();
            let mut n = 0usize;
            'storm: loop {
                for obs in &delta_k {
                    sharded.observe(obs.clone());
                    n += 1;
                    if n >= delta_k.len() && t0.elapsed().as_millis() >= min_storm_ms {
                        break 'storm;
                    }
                }
            }
            sharded.publish_all();
            let secs = t0.elapsed().as_secs_f64();
            storming_w.store(false, Ordering::Relaxed);
            (n, secs)
        });
        let (storm_p50, storm_p99, storm_batches) =
            sample(&|_| storming.load(Ordering::Relaxed));
        let (n_delta, ingest_secs) = feeder.join().unwrap();

        // idle again at 100%
        let (after_p50, after_p99, _) = sample(&|i| i < idle_batches);

        println!(
            "\n== route latency under incremental update (batch {}, split {}, K={k}) ==",
            probes.len(),
            DATASETS[split]
        );
        println!("  idle @70%:  p50 {idle_p50:>8.1} us/batch  p99 {idle_p99:>8.1} us/batch");
        println!(
            "  storm:      p50 {storm_p50:>8.1} us/batch  p99 {storm_p99:>8.1} us/batch  \
             ({n_delta} records in {ingest_secs:.3}s = {:.0} rec/s, {storm_batches} batches \
             sampled)",
            n_delta as f64 / ingest_secs.max(1e-9)
        );
        println!("  idle @100%: p50 {after_p50:>8.1} us/batch  p99 {after_p99:>8.1} us/batch");
        let flat_p99 = storm_p99 / idle_p99.max(after_p99).max(1e-9);
        println!("  flat-p99 check: storm p99 / idle-span p99 = {flat_p99:.3}");
        report.push(&format!("storm.k{k}.idle_p99_us"), idle_p99);
        report.push(&format!("storm.k{k}.storm_p99_us"), storm_p99);
        report.push(&format!("storm.k{k}.flat_p99_ratio"), flat_p99);
        report.push(&format!("storm.k{k}.ingest_rps"), n_delta as f64 / ingest_secs.max(1e-9));
    }
}
