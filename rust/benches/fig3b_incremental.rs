//! Fig 3b reproduction: routing quality (summed AUC over the 7 datasets)
//! as the feedback corpus grows 70% -> 85% -> 100%.
//!
//! Paper shape: Eagle above all baselines at every stage, with an average
//! improvement of 8.65% (70%), 9.21% (85%), 9.92% (100%) over the three
//! baselines' mean.
//!
//! Run: `cargo bench --bench fig3b_incremental`

mod common;

use eagle::bench::{fmt, print_table};
use eagle::routerbench::DATASETS;

const STAGES: [f64; 3] = [0.70, 0.85, 1.00];

fn main() {
    let (_rig, exp, cfg) = common::setup("fig3b");
    let routers = ["eagle", "knn", "mlp", "svm"];

    let mut sums = vec![[0.0f64; 3]; routers.len()];
    for (ri, r) in routers.iter().enumerate() {
        for (stage_i, frac) in STAGES.iter().enumerate() {
            for si in 0..DATASETS.len() {
                let router = common::fit_router(&exp, &cfg, r, si, *frac);
                sums[ri][stage_i] += exp.eval(router.as_ref(), si).auc();
            }
        }
    }

    let mut rows = vec![vec![
        "router".to_string(),
        "70%".to_string(),
        "85%".to_string(),
        "100%".to_string(),
    ]];
    for (ri, r) in routers.iter().enumerate() {
        rows.push(vec![
            r.to_string(),
            fmt(sums[ri][0], 4),
            fmt(sums[ri][1], 4),
            fmt(sums[ri][2], 4),
        ]);
    }
    print_table("Fig 3b — summed AUC by feedback stage", &rows);

    println!();
    for (stage_i, (label, paper)) in
        [("70%", 8.65), ("85%", 9.21), ("100%", 9.92)].iter().enumerate()
    {
        let baseline_mean: f64 =
            (1..routers.len()).map(|ri| sums[ri][stage_i]).sum::<f64>() / 3.0;
        let imp = (sums[0][stage_i] - baseline_mean) / baseline_mean * 100.0;
        println!(
            "stage {label}: eagle improvement over baseline mean = {imp:+.2}% \
             (paper: +{paper:.2}%)"
        );
    }
}
