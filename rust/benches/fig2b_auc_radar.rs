//! Fig 2b reproduction: AUC across all seven RouterBench datasets (the
//! radar chart) plus the paper's headline summed-AUC improvements
//! (23.52% over SVM, 5.14% over KNN, 4.73% over MLP).
//!
//! Run: `cargo bench --bench fig2b_auc_radar`

mod common;

use eagle::bench::{fmt, print_table};
use eagle::eval::improvement_pct;
use eagle::routerbench::DATASETS;

fn main() {
    let (_rig, exp, cfg) = common::setup("fig2b");
    let routers = ["eagle", "knn", "mlp", "svm"];

    let mut aucs = vec![vec![0.0f64; DATASETS.len()]; routers.len()];
    for (ri, r) in routers.iter().enumerate() {
        for si in 0..DATASETS.len() {
            let router = common::fit_router(&exp, &cfg, r, si, 1.0);
            aucs[ri][si] = exp.eval(router.as_ref(), si).auc();
        }
    }

    let mut rows = vec![{
        let mut h = vec!["router".to_string()];
        h.extend(DATASETS.iter().map(|d| d.to_string()));
        h.push("sum".into());
        h
    }];
    for (ri, r) in routers.iter().enumerate() {
        let mut row = vec![r.to_string()];
        for si in 0..DATASETS.len() {
            row.push(fmt(aucs[ri][si], 4));
        }
        row.push(fmt(aucs[ri].iter().sum::<f64>(), 4));
        rows.push(row);
    }
    print_table("Fig 2b — AUC per dataset (radar series)", &rows);

    let sums: Vec<f64> = aucs.iter().map(|a| a.iter().sum()).collect();
    let mut imp_rows = vec![vec![
        "baseline".to_string(),
        "measured improvement".to_string(),
        "paper".to_string(),
    ]];
    for (name, paper) in [("svm", 23.52), ("knn", 5.14), ("mlp", 4.73)] {
        let bi = routers.iter().position(|r| *r == name).unwrap();
        imp_rows.push(vec![
            name.into(),
            format!("{:+.2}%", improvement_pct(sums[0], sums[bi])),
            format!("+{paper:.2}%"),
        ]);
    }
    print_table("summed-AUC improvement of eagle over baselines", &imp_rows);

    let wins = (0..DATASETS.len())
        .filter(|&si| (1..routers.len()).all(|ri| aucs[0][si] >= aucs[ri][si]))
        .count();
    println!(
        "\npaper shape check: eagle is best-or-tied on {wins}/{} datasets \
         (paper: superior across all datasets)",
        DATASETS.len()
    );
}
