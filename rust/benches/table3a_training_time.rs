//! Table 3a reproduction: training / update wall-clock at the 70% / 85% /
//! 100% data stages.
//!
//! Paper numbers (seconds): KNN 176.3/180.6/193.4, MLP 248.3/253.3/260.2,
//! SVM 114.7/143.0/150.5, Eagle 8.0/1.4/1.5 — i.e. Eagle's init is ~4.8%
//! of the mean baseline time and each incremental update is 0.5-1%.
//!
//! Protocol (per DESIGN.md): the baselines' pipelines re-featurize and
//! refit on the *full accumulated* feedback at every stage (sklearn-style
//! online behavior, embedding included: their featurization is part of the
//! training pipeline). Eagle re-uses the request-time embeddings already
//! cached in its vector DB and folds in only the *new* records.
//!
//! Run: `cargo bench --bench table3a_training_time`

mod common;

use eagle::baselines::knn::KnnPredictor;
use eagle::baselines::mlp::{MlpOptions, MlpPredictor};
use eagle::baselines::svm::{SvmOptions, SvmPredictor};
use eagle::baselines::QualityPredictor;
use eagle::bench::{print_table, time_once};
use eagle::config::EagleParams;
use eagle::routerbench::DATASETS;

const STAGES: [f64; 3] = [0.70, 0.85, 1.00];

fn main() {
    let (rig, exp, cfg) = common::setup("table3a");

    let mut rows = vec![vec![
        "router".to_string(),
        "70% (s)".to_string(),
        "85% (s)".to_string(),
        "100% (s)".to_string(),
    ]];

    // --- baselines: re-embed + full refit per stage ---
    let mut baseline_times: Vec<[f64; 3]> = Vec::new();
    for name in ["knn", "mlp", "svm"] {
        let mut ts = [0.0f64; 3];
        for (stage_i, frac) in STAGES.iter().enumerate() {
            let (_, t) = time_once(|| {
                for si in 0..DATASETS.len() {
                    // pipeline cost: featurize the accumulated train prefix...
                    let s = exp.split(si);
                    let n = ((s.train.len() as f64) * frac).round() as usize;
                    let texts: Vec<&str> =
                        s.train[..n].iter().map(|x| x.text.as_str()).collect();
                    let _emb = rig.embed_texts(&texts);
                    // ...and fit from scratch on it
                    let data = exp.train_set_feedback(si, *frac);
                    match name {
                        "knn" => {
                            let mut p = KnnPredictor::new(cfg.baselines.knn_neighbors);
                            p.fit(&data);
                        }
                        "mlp" => {
                            let mut p = MlpPredictor::new(MlpOptions {
                                hidden: cfg.baselines.mlp_hidden,
                                epochs: cfg.baselines.mlp_epochs,
                                lr: cfg.baselines.mlp_lr,
                                ..Default::default()
                            });
                            p.fit(&data);
                        }
                        _ => {
                            let mut p = SvmPredictor::new(SvmOptions {
                                epsilon: cfg.baselines.svm_epsilon,
                                epochs: cfg.baselines.svm_epochs,
                                lr: cfg.baselines.svm_lr,
                                ..Default::default()
                            });
                            p.fit(&data);
                        }
                    }
                }
            });
            ts[stage_i] = t;
        }
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", ts[0]),
            format!("{:.3}", ts[1]),
            format!("{:.3}", ts[2]),
        ]);
        baseline_times.push(ts);
    }

    // --- eagle: init once (ELO replay + vector inserts over cached
    //     request-time embeddings), then incremental updates ---
    let mut eagle_ts = [0.0f64; 3];
    let (mut routers, t_init) = time_once(|| {
        (0..DATASETS.len())
            .map(|si| exp.fit_eagle(si, EagleParams::default(), STAGES[0]))
            .collect::<Vec<_>>()
    });
    eagle_ts[0] = t_init;
    for (stage_i, w) in STAGES.windows(2).enumerate() {
        let (_, t) = time_once(|| {
            for (si, r) in routers.iter_mut().enumerate() {
                let old = exp.observations(si, w[0]).len();
                let newer = exp.observations(si, w[1]);
                r.update(&newer[old..]);
            }
        });
        eagle_ts[stage_i + 1] = t;
    }
    rows.push(vec![
        "eagle".to_string(),
        format!("{:.4}", eagle_ts[0]),
        format!("{:.4}", eagle_ts[1]),
        format!("{:.4}", eagle_ts[2]),
    ]);

    print_table("Table 3a — training/update wall-clock (7 datasets)", &rows);

    let mean_baseline_init: f64 =
        baseline_times.iter().map(|t| t[0]).sum::<f64>() / baseline_times.len() as f64;
    let mean_baseline_update: f64 = baseline_times
        .iter()
        .map(|t| (t[1] + t[2]) / 2.0)
        .sum::<f64>()
        / baseline_times.len() as f64;
    println!(
        "\neagle init     = {:.2}% of mean baseline training time (paper: ~4.8%)",
        eagle_ts[0] / mean_baseline_init * 100.0
    );
    println!(
        "eagle update   = {:.3}% of mean baseline update time (paper: 0.5-1%)",
        (eagle_ts[1] + eagle_ts[2]) / 2.0 / mean_baseline_update * 100.0
    );
    println!(
        "update speedup = {:.0}x (paper: 100-200x)",
        mean_baseline_update / ((eagle_ts[1] + eagle_ts[2]) / 2.0)
    );
}
