//! Fig 2a reproduction: router quality vs willingness-to-pay on MMLU.
//!
//! Paper shape: Eagle's curve dominates KNN/MLP/SVM across budget levels.
//! Run: `cargo bench --bench fig2a_budget_curve`

mod common;

use eagle::bench::{fmt, print_table};
use eagle::eval::oracle_curve;
use eagle::routerbench::DATASETS;

fn main() {
    let (_rig, exp, cfg) = common::setup("fig2a");
    let mmlu = DATASETS.iter().position(|d| *d == "mmlu").unwrap();
    let routers = ["eagle", "knn", "mlp", "svm"];

    let curves: Vec<_> = routers
        .iter()
        .map(|r| {
            let router = common::fit_router(&exp, &cfg, r, mmlu, 1.0);
            exp.eval(router.as_ref(), mmlu)
        })
        .collect();
    let oracle = oracle_curve(&exp.split(mmlu).test, &exp.policy, "mmlu");

    // the figure series: quality at each willingness-to-pay level
    let mut rows = vec![{
        let mut h = vec!["budget ($/query)".to_string()];
        h.extend(routers.iter().map(|r| r.to_string()));
        h.push("oracle".into());
        h
    }];
    for (i, p) in curves[0].points.iter().enumerate() {
        // thin the sweep for readability: keep every second level
        if i % 2 == 1 {
            continue;
        }
        let mut row = vec![format!("{:.5}", p.budget)];
        for c in &curves {
            row.push(fmt(c.points[i].mean_quality, 4));
        }
        row.push(fmt(oracle.points[i].mean_quality, 4));
        rows.push(row);
    }
    print_table("Fig 2a — MMLU quality vs willingness-to-pay", &rows);

    let mut auc_rows = vec![vec!["router".to_string(), "AUC".to_string()]];
    for c in &curves {
        auc_rows.push(vec![c.router.clone(), fmt(c.auc(), 4)]);
    }
    auc_rows.push(vec!["oracle".into(), fmt(oracle.auc(), 4)]);
    print_table("Fig 2a — MMLU AUC", &auc_rows);

    let eagle_auc = curves[0].auc();
    let dominated = curves[1..].iter().filter(|c| eagle_auc >= c.auc()).count();
    println!(
        "\npaper shape check: eagle beats {}/{} baselines on MMLU AUC \
         (paper: eagle dominates all)",
        dominated,
        curves.len() - 1
    );
}
