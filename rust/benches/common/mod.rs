//! Shared scaffolding for the figure/table bench targets.
//!
//! Scale defaults to 800 prompts/dataset (fast, stable shapes); override
//! with EAGLE_BENCH_SCALE=2800 to match the paper's full dataset size.
//! The embedder is the PJRT serving path when artifacts exist, otherwise
//! the hash fallback (noted in the output header).

use eagle::baselines::knn::KnnPredictor;
use eagle::baselines::mlp::{MlpOptions, MlpPredictor};
use eagle::baselines::svm::{SvmOptions, SvmPredictor};
use eagle::baselines::QualityPredictor;
use eagle::config::{Config, EagleParams};
use eagle::coordinator::{PredictorRouter, Router};
use eagle::eval::harness::{bench_data_params, EmbedderRig, Experiment};

pub const DEFAULT_SCALE: usize = 800;
pub const SEED: u64 = 0xEA61E;

pub fn scale() -> usize {
    std::env::var("EAGLE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_SCALE)
}

pub fn setup(name: &str) -> (EmbedderRig, Experiment, Config) {
    let rig = EmbedderRig::auto(std::path::Path::new("artifacts"));
    println!(
        "[{name}] scale={} prompts/dataset, embedder={}, seed={SEED:#x}",
        scale(),
        if rig.is_pjrt { "PJRT(MiniStella AOT)" } else { "hash-fallback" }
    );
    let exp = Experiment::build(&bench_data_params(SEED, scale()), &rig);
    (rig, exp, Config::default())
}

/// Fit a named router on one dataset split under the paper's online
/// (feedback-supervision) protocol. `frac` stages the train prefix.
pub fn fit_router(
    exp: &Experiment,
    cfg: &Config,
    name: &str,
    split: usize,
    frac: f64,
) -> Box<dyn Router> {
    match name {
        "eagle" | "eagle-global" | "eagle-local" => {
            let p = match name {
                "eagle-global" => 1.0,
                "eagle-local" => 0.0,
                _ => cfg.eagle.p,
            };
            Box::new(exp.fit_eagle(split, EagleParams { p, ..cfg.eagle.clone() }, frac))
        }
        "knn" => {
            let mut p = KnnPredictor::new(cfg.baselines.knn_neighbors);
            p.fit(&exp.train_set_feedback(split, frac));
            Box::new(PredictorRouter::new(p))
        }
        "mlp" => {
            let mut p = MlpPredictor::new(MlpOptions {
                hidden: cfg.baselines.mlp_hidden,
                epochs: cfg.baselines.mlp_epochs,
                lr: cfg.baselines.mlp_lr,
                ..Default::default()
            });
            p.fit(&exp.train_set_feedback(split, frac));
            Box::new(PredictorRouter::new(p))
        }
        "svm" => {
            let mut p = SvmPredictor::new(SvmOptions {
                epsilon: cfg.baselines.svm_epsilon,
                epochs: cfg.baselines.svm_epochs,
                lr: cfg.baselines.svm_lr,
                ..Default::default()
            });
            p.fit(&exp.train_set_feedback(split, frac));
            Box::new(PredictorRouter::new(p))
        }
        other => panic!("unknown router {other}"),
    }
}
