//! Scenario matrix: routing methods × serving scenarios, deterministic.
//!
//! Runs the seeded matrix of `eagle::eval::scenario` twice and asserts
//! the CSV/JSON artifacts are byte-identical (the determinism gate),
//! then writes `scenario_summary.csv`, `scenario_matrix.json`, and the
//! `BENCH_scenario_matrix.json` metric family CI feeds into the
//! `bench-diff` trend gate.
//!
//! Run: `cargo bench --bench scenario_matrix`
//! (smoke: `EAGLE_BENCH_SMOKE=1`, smaller matrix + JSON artifact)

use eagle::bench::{self, fmt, print_table, JsonReport};
use eagle::eval::scenario::{run_matrix, ScenarioConfig, METHODS, SCENARIOS};

fn main() {
    let cfg = if bench::smoke() { ScenarioConfig::smoke() } else { ScenarioConfig::full() };
    println!(
        "[scenario_matrix] seed={} per_dataset={} ({} mode)",
        cfg.seed,
        cfg.per_dataset,
        if bench::smoke() { "smoke" } else { "full" }
    );

    let (result, secs) = bench::time_once(|| run_matrix(&cfg));
    let rerun = run_matrix(&cfg);
    assert_eq!(result.to_csv(), rerun.to_csv(), "scenario CSV must be seed-stable");
    assert_eq!(result.to_json(), rerun.to_json(), "scenario JSON must be seed-stable");
    println!("matrix of {} cells in {secs:.1}s, re-run byte-identical", result.cells.len());

    // method × scenario AUC table
    let mut rows = vec![{
        let mut h = vec!["method".to_string()];
        h.extend(SCENARIOS.iter().filter(|s| **s != "adversarial").map(|s| s.to_string()));
        h
    }];
    for method in METHODS {
        let mut row = vec![method.to_string()];
        for scenario in SCENARIOS.iter().filter(|s| **s != "adversarial") {
            let v = result.get(scenario, method, "auc").unwrap_or(f64::NAN);
            row.push(fmt(v, 4));
        }
        rows.push(row);
    }
    print_table("Scenario matrix — AUC by method", &rows);

    let mut diag = vec![vec!["diagnostic".to_string(), "value".to_string()]];
    for (s, m, k) in [
        ("drift", "budget", "adaptation_gain"),
        ("cold_start", "budget", "recovery_gain"),
        ("burst_skew", "sharded", "score_divergence"),
        ("burst_skew", "sharded", "shard_imbalance"),
        ("adversarial", "wire", "error_reply_rate"),
        ("adversarial", "wire", "survived"),
        ("adversarial", "durable", "recovered_ratio"),
        ("adversarial", "durable", "survived"),
    ] {
        diag.push(vec![
            format!("{s}.{m}.{k}"),
            fmt(result.get(s, m, k).unwrap_or(f64::NAN), 4),
        ]);
    }
    print_table("Scenario matrix — diagnostics", &diag);

    let dir = std::env::var("EAGLE_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    match result.write_to(std::path::Path::new(&dir)) {
        Ok((csv, json)) => println!("wrote {} and {}", csv.display(), json.display()),
        Err(e) => eprintln!("artifact write failed: {e}"),
    }

    if bench::json_enabled() {
        let mut report = JsonReport::new("scenario_matrix");
        for (name, value) in result.metrics() {
            report.push(&name, value);
        }
        report.push("scenario.matrix_secs", secs);
        match report.write() {
            Ok(p) => println!("wrote {}", p.display()),
            Err(e) => eprintln!("bench json write failed: {e}"),
        }
    }
}
