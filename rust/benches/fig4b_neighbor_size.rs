//! Fig 4b reproduction: effect of the local neighbor count N on
//! Eagle-Local performance.
//!
//! Paper shape: N=10 lacks information, N=20 is optimal, larger N gives
//! diminishing returns. Note (EXPERIMENTS.md): our trajectory-averaged
//! local estimator degrades *gracefully* at small N (it stays close to
//! the global seed), so the small-N penalty is softer than the paper's.
//!
//! Run: `cargo bench --bench fig4b_neighbor_size`

mod common;

use eagle::bench::{fmt, print_table};
use eagle::config::EagleParams;
use eagle::routerbench::DATASETS;

fn main() {
    let (_rig, exp, cfg) = common::setup("fig4b");
    let n_values = [1usize, 5, 10, 20, 40, 80];

    let mut rows = vec![vec![
        "N".to_string(),
        "summed AUC (local-only)".to_string(),
        "summed AUC (combined)".to_string(),
    ]];
    let mut best = (0usize, f64::MIN);
    for &n in &n_values {
        let mut local_sum = 0.0;
        let mut combined_sum = 0.0;
        for si in 0..DATASETS.len() {
            let local = exp.fit_eagle(
                si,
                EagleParams { p: 0.0, n_neighbors: n, ..cfg.eagle.clone() },
                1.0,
            );
            local_sum += exp.eval(&local, si).auc();
            let combined = exp.fit_eagle(
                si,
                EagleParams { p: 0.5, n_neighbors: n, ..cfg.eagle.clone() },
                1.0,
            );
            combined_sum += exp.eval(&combined, si).auc();
        }
        if combined_sum > best.1 {
            best = (n, combined_sum);
        }
        rows.push(vec![n.to_string(), fmt(local_sum, 4), fmt(combined_sum, 4)]);
    }
    print_table("Fig 4b — neighbor size sweep", &rows);
    println!(
        "\npaper shape check: best combined N = {} (paper: N=20 optimal, \
         diminishing returns beyond)",
        best.0
    );
}
