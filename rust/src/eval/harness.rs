//! Experiment harness shared by the CLI, the examples, and every
//! figure/table bench: build the benchmark, embed all prompts through the
//! serving embedder, fit routers, evaluate curves.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::baselines::linalg::Matrix;
use crate::baselines::TrainSet;
use crate::config::{Config, DataParams, EagleParams};
use crate::coordinator::policy::RoutePolicy;
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::router::{EagleRouter, Observation};
use crate::embedding::{BatcherOptions, EmbedService, Embedder, HashEmbedder, ServiceEmbedder};
use crate::metrics::Metrics;
use crate::routerbench::models::MODELS;
use crate::routerbench::{gen, Benchmark, DatasetSplit};
use crate::vectordb::flat::FlatStore;

use super::CostQualityCurve;

/// An embedder plus whatever service it needs kept alive.
pub struct EmbedderRig {
    /// Kept alive for the lifetime of the rig (engine thread).
    _service: Option<EmbedService>,
    embedder: Box<dyn Embedder>,
    /// True when backed by the PJRT artifacts (serving path), false for
    /// the pure-rust fallback.
    pub is_pjrt: bool,
}

impl EmbedderRig {
    /// PJRT-backed if `artifacts_dir` holds a manifest, otherwise the
    /// HashEmbedder fallback (tests / artifact-less benches).
    pub fn auto(artifacts_dir: &Path) -> EmbedderRig {
        match EmbedService::start(
            artifacts_dir,
            BatcherOptions { batch_window_us: 100, max_batch: 32 },
            Arc::new(Metrics::new()),
        ) {
            Ok(svc) => {
                let handle = svc.handle();
                EmbedderRig {
                    embedder: Box::new(ServiceEmbedder::new(handle)),
                    _service: Some(svc),
                    is_pjrt: true,
                }
            }
            Err(e) => {
                eprintln!(
                    "note: PJRT embedder unavailable ({e}); using HashEmbedder fallback"
                );
                EmbedderRig::hash()
            }
        }
    }

    /// Pure-rust fallback rig.
    pub fn hash() -> EmbedderRig {
        EmbedderRig {
            _service: None,
            embedder: Box::new(HashEmbedder::new(256)),
            is_pjrt: false,
        }
    }

    pub fn embedder(&self) -> &dyn Embedder {
        self.embedder.as_ref()
    }

    /// Embed a batch of texts (chunked to keep reply queues bounded).
    pub fn embed_texts(&self, texts: &[&str]) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(texts.len());
        for chunk in texts.chunks(256) {
            out.extend(self.embedder.embed(chunk));
        }
        out
    }
}

/// A fully-embedded benchmark: prompts + their vectors, per split.
pub struct Experiment {
    pub benchmark: Benchmark,
    /// train_emb[split][i] = embedding of splits[split].train[i]
    pub train_emb: Vec<Vec<Vec<f32>>>,
    pub test_emb: Vec<Vec<Vec<f32>>>,
    pub registry: ModelRegistry,
    pub policy: RoutePolicy,
}

impl Experiment {
    /// Generate + embed the full benchmark.
    pub fn build(params: &DataParams, rig: &EmbedderRig) -> Experiment {
        let benchmark = gen::generate(params);
        let mut train_emb = Vec::with_capacity(benchmark.splits.len());
        let mut test_emb = Vec::with_capacity(benchmark.splits.len());
        for split in &benchmark.splits {
            let train_texts: Vec<&str> = split.train.iter().map(|s| s.text.as_str()).collect();
            let test_texts: Vec<&str> = split.test.iter().map(|s| s.text.as_str()).collect();
            train_emb.push(rig.embed_texts(&train_texts));
            test_emb.push(rig.embed_texts(&test_texts));
        }
        let registry = ModelRegistry::routerbench();
        let policy = RoutePolicy::new(&registry);
        Experiment { benchmark, train_emb, test_emb, registry, policy }
    }

    pub fn n_models(&self) -> usize {
        MODELS.len()
    }

    pub fn split(&self, idx: usize) -> &DatasetSplit {
        &self.benchmark.splits[idx]
    }

    /// Regression training set (baselines) over the first `frac` of the
    /// train split (1.0 = all).
    pub fn train_set(&self, split: usize, frac: f64) -> TrainSet {
        let s = &self.benchmark.splits[split];
        let n = ((s.train.len() as f64) * frac).round() as usize;
        let n = n.min(s.train.len()).max(1);
        let emb: Vec<Vec<f32>> = self.train_emb[split][..n].to_vec();
        let qual: Vec<Vec<f32>> = s.train[..n].iter().map(|x| x.quality.clone()).collect();
        TrainSet::new(Matrix::from_rows(&emb), Matrix::from_rows(&qual))
    }

    /// Feedback-supervision training set (the paper's online protocol):
    /// labels exist only for the models compared on each prompt — win=1,
    /// loss=0, draw=0.5 — exactly the information Eagle's ELO consumes.
    /// Multiple comparisons touching the same (prompt, model) average.
    pub fn train_set_feedback(&self, split: usize, frac: f64) -> TrainSet {
        let s = &self.benchmark.splits[split];
        let n = ((s.train.len() as f64) * frac).round() as usize;
        let n = n.min(s.train.len()).max(1);
        let m = MODELS.len();
        let mut label_sum = vec![0.0f32; n * m];
        let mut label_cnt = vec![0.0f32; n * m];
        for f in &s.feedback {
            if f.sample >= n {
                continue;
            }
            let sa = f.comparison.outcome.score_a() as f32;
            label_sum[f.sample * m + f.comparison.a] += sa;
            label_cnt[f.sample * m + f.comparison.a] += 1.0;
            label_sum[f.sample * m + f.comparison.b] += 1.0 - sa;
            label_cnt[f.sample * m + f.comparison.b] += 1.0;
        }
        let emb: Vec<Vec<f32>> = self.train_emb[split][..n].to_vec();
        let mut qualities = Matrix::zeros(n, m);
        let mut mask = Matrix::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                let c = label_cnt[i * m + j];
                if c > 0.0 {
                    *qualities.at_mut(i, j) = label_sum[i * m + j] / c;
                    *mask.at_mut(i, j) = 1.0;
                }
            }
        }
        TrainSet::new_masked(Matrix::from_rows(&emb), qualities, mask)
    }

    /// Eagle observations from the feedback stream: records whose sample
    /// index falls inside the first `frac` of the train split, grouped per
    /// prompt (the vector DB stores one entry per prompt holding all of
    /// its pairwise records).
    pub fn observations(&self, split: usize, frac: f64) -> Vec<Observation> {
        let s = &self.benchmark.splits[split];
        let n = ((s.train.len() as f64) * frac).round() as usize;
        let mut per_prompt: Vec<Vec<crate::elo::Comparison>> = vec![Vec::new(); n];
        for f in &s.feedback {
            if f.sample < n {
                per_prompt[f.sample].push(f.comparison);
            }
        }
        per_prompt
            .into_iter()
            .enumerate()
            .filter(|(_, c)| !c.is_empty())
            .map(|(i, comparisons)| Observation {
                embedding: self.train_emb[split][i].clone(),
                comparisons,
            })
            .collect()
    }

    /// Fit an Eagle router on a feedback prefix of one dataset.
    pub fn fit_eagle(&self, split: usize, params: EagleParams, frac: f64) -> EagleRouter<FlatStore> {
        let dim = self.train_emb[split].first().map(|v| v.len()).unwrap_or(256);
        let obs = self.observations(split, frac);
        EagleRouter::fit(params, self.n_models(), FlatStore::with_capacity(dim, obs.len()), &obs)
    }

    /// Evaluate a router on one dataset's test split.
    pub fn eval(&self, router: &dyn crate::coordinator::Router, split: usize) -> CostQualityCurve {
        super::evaluate_router(
            router,
            &self.benchmark.splits[split].test,
            &self.test_emb[split],
            &self.policy,
            crate::routerbench::DATASETS[self.benchmark.splits[split].dataset],
        )
    }
}

/// Build the default experiment from a [`Config`] (shared CLI/bench entry).
pub fn default_experiment(cfg: &Config) -> Result<(EmbedderRig, Experiment)> {
    let rig = EmbedderRig::auto(Path::new(&cfg.embed.artifacts_dir));
    let exp = Experiment::build(&cfg.data, &rig);
    Ok((rig, exp))
}

/// Smaller data params for fast benches (documented in EXPERIMENTS.md).
pub fn bench_data_params(seed: u64, per_dataset: usize) -> DataParams {
    DataParams {
        seed,
        per_dataset,
        train_fraction: 0.7,
        comparisons_per_prompt: 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_exp() -> Experiment {
        let rig = EmbedderRig::hash();
        Experiment::build(&bench_data_params(3, 120), &rig)
    }

    #[test]
    fn build_embeds_every_prompt() {
        let exp = small_exp();
        for (si, split) in exp.benchmark.splits.iter().enumerate() {
            assert_eq!(exp.train_emb[si].len(), split.train.len());
            assert_eq!(exp.test_emb[si].len(), split.test.len());
        }
    }

    #[test]
    fn train_set_fraction() {
        let exp = small_exp();
        let full = exp.train_set(0, 1.0);
        let half = exp.train_set(0, 0.5);
        assert_eq!(full.len(), exp.split(0).train.len());
        assert_eq!(half.len(), exp.split(0).train.len() / 2);
    }

    #[test]
    fn observations_respect_prefix() {
        let exp = small_exp();
        let all = exp.observations(0, 1.0);
        let some = exp.observations(0, 0.5);
        assert!(some.len() < all.len());
        // one observation per prompt, carrying all of its comparisons
        assert_eq!(all.len(), exp.split(0).train.len());
        let total: usize = all.iter().map(|o| o.comparisons.len()).sum();
        assert_eq!(total, exp.split(0).feedback.len());
    }

    #[test]
    fn fit_and_eval_eagle_runs() {
        let exp = small_exp();
        let router = exp.fit_eagle(0, EagleParams::default(), 1.0);
        let curve = exp.eval(&router, 0);
        assert!(!curve.points.is_empty());
        let auc = curve.auc();
        assert!((0.0..=1.0).contains(&auc), "auc = {auc}");
    }

    #[test]
    fn eagle_beats_random_scores_on_synthetic() {
        use crate::coordinator::Router;
        struct RandomRouter;
        impl Router for RandomRouter {
            fn name(&self) -> String {
                "random".into()
            }
            fn scores(&self, q: &[f32]) -> Vec<f64> {
                // arbitrary but query-dependent noise
                (0..MODELS.len())
                    .map(|m| (q[m % q.len()] as f64 * 1000.0).sin())
                    .collect()
            }
        }
        let exp = small_exp();
        let eagle = exp.fit_eagle(0, EagleParams::default(), 1.0);
        let e_auc = exp.eval(&eagle, 0).auc();
        let r_auc = exp.eval(&RandomRouter, 0).auc();
        assert!(e_auc > r_auc, "eagle {e_auc} vs random {r_auc}");
    }
}
