//! Deterministic scenario matrix: routing methods × serving scenarios.
//!
//! A `dsfb-fusion-bench`-style runner: every cell of the matrix is a
//! named metric computed from a **seeded** configuration, so identical
//! seeds reproduce identical CSV/JSON outputs byte-for-byte (the
//! `scenario_matrix` bench runs the matrix twice and diffs the
//! artifacts). Scenarios stress the parts of the serving story a single
//! cost-quality curve hides:
//!
//! - **baseline** — the §3 protocol on one dataset, all routing methods;
//! - **drift** — user preference between the top-2 models flips mid-way
//!   through the feedback stream; measures how much online `update`
//!   recovers versus a frozen router (`adaptation_gain`);
//! - **cold_start** — all feedback involving the `mbpp` specialist is
//!   withheld, then replayed (`recovery_gain`);
//! - **burst_skew** — topic-sorted bursty ingest across K=4 hash shards;
//!   checks the bit-identical-scores claim under pathological arrival
//!   order (`score_divergence` must be exactly 0) plus shard imbalance;
//! - **adversarial** — seeded garbage and valid lines interleaved through
//!   the real wire protocol ([`ServerState::handle_lines`]), plus a
//!   durable delta-log corruption/recovery pass through the real frame
//!   codec.
//!
//! Methods are the [`PolicySpec`] families plus two references:
//! `budget`, `cost_aware`, `threshold`, `cheapest`, `best_single`.
//! Metric families are emitted as `scenario.<scenario>.<method>.<metric>`
//! for `BENCH_scenario_matrix.json`, which CI feeds into the `bench-diff`
//! trend gate (`auc` and `*_ratio` names carry gating direction).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{EagleParams, EpochParams, ShardParams};
use crate::coordinator::durable::{DurableOptions, DurableStore, StoreMeta};
use crate::coordinator::policy::{approx_tokens, PolicySpec, RoutePolicy};
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::router::{EagleRouter, Observation};
use crate::coordinator::sharded::{shard_of, ShardedRouter};
use crate::elo::Outcome;
use crate::embedding::{BatcherOptions, EmbedService};
use crate::json::{self, Value};
use crate::metrics::Metrics;
use crate::routerbench::models::model_index;
use crate::routerbench::Sample;
use crate::server::protocol::Response;
use crate::server::ServerState;
use crate::util::{l2_normalize, Rng};
use crate::vectordb::flat::FlatStore;

use super::harness::{bench_data_params, EmbedderRig, Experiment};
use super::{cost_savings_at_matched_quality, single_model_point, CostQualityCurve, CurvePoint};

/// Bumped whenever the JSON artifact layout changes.
pub const SCHEMA_VERSION: u32 = 1;

/// Routing methods evaluated in every quality scenario.
pub const METHODS: &[&str] = &["budget", "cost_aware", "threshold", "cheapest", "best_single"];

/// All scenarios, in run order.
pub const SCENARIOS: &[&str] = &["baseline", "drift", "cold_start", "burst_skew", "adversarial"];

/// Quality tolerance for the cost-savings-at-matched-quality metric:
/// routers must reach 95% of the best single model's quality.
const MATCH_TOLERANCE: f64 = 0.05;

/// Threshold sweep for the calibrated-threshold method (its cost axis).
const THRESHOLDS: &[f64] = &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

const SHARD_HASH_SEED: u64 = 0xEA61E;

/// Seeded matrix configuration: everything downstream derives from it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScenarioConfig {
    /// Master seed: drives data generation, the adversarial line mix,
    /// and the durable corruption history.
    pub seed: u64,
    /// Prompts per RouterBench dataset (the smoke default keeps the full
    /// matrix under a couple of seconds).
    pub per_dataset: usize,
}

impl ScenarioConfig {
    /// CI smoke configuration (also the bench default).
    pub fn smoke() -> ScenarioConfig {
        ScenarioConfig { seed: 7, per_dataset: 72 }
    }

    /// Heavier local configuration for report-quality numbers.
    pub fn full() -> ScenarioConfig {
        ScenarioConfig { seed: 7, per_dataset: 240 }
    }
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig::smoke()
    }
}

/// One matrix cell: `(scenario, method, metric) -> value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub scenario: String,
    pub method: String,
    pub metric: String,
    pub value: f64,
}

/// The completed matrix, cells sorted by `(scenario, method, metric)`.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixResult {
    pub seed: u64,
    pub per_dataset: usize,
    pub cells: Vec<Cell>,
}

impl MatrixResult {
    /// Look up one cell's value.
    pub fn get(&self, scenario: &str, method: &str, metric: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.method == method && c.metric == metric)
            .map(|c| c.value)
    }

    /// Stable CSV rendering (`scenario,method,metric,value`, sorted).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("scenario,method,metric,value\n");
        for c in &self.cells {
            out.push_str(&format!("{},{},{},{}\n", c.scenario, c.method, c.metric, c.value));
        }
        out
    }

    /// Stable JSON rendering (BTreeMap-ordered keys, sorted cells).
    pub fn to_json(&self) -> String {
        let cells: Vec<Value> = self
            .cells
            .iter()
            .map(|c| {
                json::obj(vec![
                    ("scenario", json::str_v(&c.scenario)),
                    ("method", json::str_v(&c.method)),
                    ("metric", json::str_v(&c.metric)),
                    ("value", json::num(c.value)),
                ])
            })
            .collect();
        json::obj(vec![
            ("schema_version", json::num(f64::from(SCHEMA_VERSION))),
            ("seed", json::num(self.seed as f64)),
            ("per_dataset", json::num(self.per_dataset as f64)),
            ("cells", Value::Arr(cells)),
        ])
        .to_json()
    }

    /// Flat metric names for `BENCH_scenario_matrix.json`:
    /// `scenario.<scenario>.<method>.<metric>`.
    pub fn metrics(&self) -> Vec<(String, f64)> {
        self.cells
            .iter()
            .map(|c| (format!("scenario.{}.{}.{}", c.scenario, c.method, c.metric), c.value))
            .collect()
    }

    /// Write `scenario_summary.csv` and `scenario_matrix.json` into `dir`;
    /// returns the two paths.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<(PathBuf, PathBuf)> {
        let csv = dir.join("scenario_summary.csv");
        let jsonp = dir.join("scenario_matrix.json");
        std::fs::write(&csv, self.to_csv())?;
        std::fs::write(&jsonp, self.to_json())?;
        Ok((csv, jsonp))
    }
}

/// Run the full matrix. Deterministic: same config, same cells.
pub fn run_matrix(cfg: &ScenarioConfig) -> MatrixResult {
    let rig = EmbedderRig::hash();
    let exp = Experiment::build(&bench_data_params(cfg.seed, cfg.per_dataset), &rig);
    let mut cells = Vec::new();
    baseline_cells(&exp, &mut cells);
    drift_cells(&exp, &mut cells);
    cold_start_cells(&exp, &mut cells);
    burst_skew_cells(&exp, &mut cells);
    adversarial_cells(cfg, &mut cells);
    cells.sort_by(|a, b| {
        (&a.scenario, &a.method, &a.metric).cmp(&(&b.scenario, &b.method, &b.metric))
    });
    MatrixResult { seed: cfg.seed, per_dataset: cfg.per_dataset, cells }
}

fn cell(scenario: &str, method: &str, metric: &str, value: f64) -> Cell {
    Cell {
        scenario: scenario.into(),
        method: method.into(),
        metric: metric.into(),
        value,
    }
}

// ---------------------------------------------------------------------------
// method evaluation
// ---------------------------------------------------------------------------

/// Best single model on a test split: highest mean quality, ties to the
/// cheaper mean cost.
fn best_single_model(test: &[Sample]) -> usize {
    let m = test.first().map(|s| s.quality.len()).unwrap_or(1);
    (0..m)
        .max_by(|&a, &b| {
            let (ca, qa) = single_model_point(test, a);
            let (cb, qb) = single_model_point(test, b);
            qa.partial_cmp(&qb).unwrap().then(cb.partial_cmp(&ca).unwrap())
        })
        .unwrap_or(0)
}

/// Mean cost/quality of routing every test sample through `choose`.
fn sweep_point(
    axis: f64,
    test: &[Sample],
    mut choose: impl FnMut(usize) -> usize,
) -> CurvePoint {
    let n = test.len().max(1) as f64;
    let mut cost = 0.0;
    let mut quality = 0.0;
    for (i, s) in test.iter().enumerate() {
        let m = choose(i);
        cost += s.cost[m] as f64;
        quality += s.quality[m] as f64;
    }
    CurvePoint { budget: axis, mean_cost: cost / n, mean_quality: quality / n }
}

/// Cost-quality curve of one routing method over precomputed scores.
/// The sweep axis is the budget for budget-family methods and the
/// threshold for the calibrated-threshold method; single-choice
/// references collapse to one point.
fn method_curve(
    method: &str,
    scores: &[Vec<f64>],
    test: &[Sample],
    policy: &RoutePolicy,
) -> CostQualityCurve {
    assert_eq!(scores.len(), test.len(), "score/sample mismatch");
    let points = match method {
        "budget" | "cost_aware" => policy
            .budget_sweep()
            .into_iter()
            .map(|budget| {
                let spec = if method == "budget" {
                    PolicySpec::Budget { budget }
                } else {
                    PolicySpec::CostAware { budget }
                };
                sweep_point(budget, test, |i| {
                    policy.select_spec(&scores[i], spec, approx_tokens(&test[i].text))
                })
            })
            .collect(),
        "threshold" => THRESHOLDS
            .iter()
            .map(|&threshold| {
                let spec = PolicySpec::Threshold { threshold };
                sweep_point(threshold, test, |i| {
                    policy.select_spec(&scores[i], spec, approx_tokens(&test[i].text))
                })
            })
            .collect(),
        "cheapest" => vec![sweep_point(0.0, test, |_| policy.cheapest())],
        "best_single" => {
            let best = best_single_model(test);
            vec![sweep_point(0.0, test, |_| best)]
        }
        other => panic!("unknown method {other}"),
    };
    CostQualityCurve { router: method.to_string(), dataset: "scenario".into(), points }
}

/// Emit `auc` and `cost_savings_ratio` cells for every method.
fn push_method_cells(
    scenario: &str,
    scores: &[Vec<f64>],
    test: &[Sample],
    policy: &RoutePolicy,
    cells: &mut Vec<Cell>,
) {
    let reference = single_model_point(test, best_single_model(test));
    for method in METHODS {
        let curve = method_curve(method, scores, test, policy);
        cells.push(cell(scenario, method, "auc", curve.auc()));
        let savings =
            cost_savings_at_matched_quality(&curve, reference, MATCH_TOLERANCE).unwrap_or(0.0);
        cells.push(cell(scenario, method, "cost_savings_ratio", savings));
    }
}

// ---------------------------------------------------------------------------
// scenarios
// ---------------------------------------------------------------------------

/// Primary dataset for baseline / drift / burst_skew (mmlu).
const PRIMARY_SPLIT: usize = 0;
/// Specialist dataset for cold_start (mbpp).
const CODE_SPLIT: usize = 5;

fn baseline_cells(exp: &Experiment, cells: &mut Vec<Cell>) {
    let router = exp.fit_eagle(PRIMARY_SPLIT, EagleParams::default(), 1.0);
    let scores = router.score_batch(&exp.test_emb[PRIMARY_SPLIT]);
    push_method_cells(
        "baseline",
        &scores,
        &exp.split(PRIMARY_SPLIT).test,
        &exp.policy,
        cells,
    );
}

/// Top-2 models by mean quality on a train split (descending).
fn top2_models(train: &[Sample]) -> (usize, usize) {
    let m = train.first().map(|s| s.quality.len()).unwrap_or(2);
    let mut means: Vec<(f64, usize)> = (0..m)
        .map(|j| {
            let q =
                train.iter().map(|s| s.quality[j] as f64).sum::<f64>() / train.len().max(1) as f64;
            (q, j)
        })
        .collect();
    means.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    (means[0].1, means[1].1)
}

fn drift_cells(exp: &Experiment, cells: &mut Vec<Cell>) {
    let split = exp.split(PRIMARY_SPLIT);
    let (hi, lo) = top2_models(&split.train);
    let obs = exp.observations(PRIMARY_SPLIT, 1.0);
    let half = obs.len() / 2;
    let dim = exp.train_emb[PRIMARY_SPLIT].first().map(|v| v.len()).unwrap_or(256);

    // the post-drift regime: the top-2 models swap quality
    let drifted_test: Vec<Sample> = split
        .test
        .iter()
        .map(|s| {
            let mut s = s.clone();
            s.quality.swap(hi, lo);
            s
        })
        .collect();

    // frozen router: trained on the pre-drift half only
    let mut router = EagleRouter::fit(
        EagleParams::default(),
        exp.n_models(),
        FlatStore::with_capacity(dim, obs.len()),
        &obs[..half],
    );
    let frozen_scores = router.score_batch(&exp.test_emb[PRIMARY_SPLIT]);
    let auc_frozen =
        method_curve("budget", &frozen_scores, &drifted_test, &exp.policy).auc();

    // adapted router: sees the second half with outcomes between the
    // top-2 models flipped (the drifted preference stream)
    let drifted_tail: Vec<Observation> = obs[half..]
        .iter()
        .map(|o| {
            let mut o = o.clone();
            for c in &mut o.comparisons {
                if (c.a == hi && c.b == lo) || (c.a == lo && c.b == hi) {
                    c.outcome = match c.outcome {
                        Outcome::WinA => Outcome::WinB,
                        Outcome::WinB => Outcome::WinA,
                        Outcome::Draw => Outcome::Draw,
                    };
                }
            }
            o
        })
        .collect();
    router.update(&drifted_tail);
    let adapted_scores = router.score_batch(&exp.test_emb[PRIMARY_SPLIT]);

    push_method_cells("drift", &adapted_scores, &drifted_test, &exp.policy, cells);
    let auc_adapted = method_curve("budget", &adapted_scores, &drifted_test, &exp.policy).auc();
    cells.push(cell("drift", "budget", "auc_frozen", auc_frozen));
    cells.push(cell("drift", "budget", "adaptation_gain", auc_adapted - auc_frozen));
}

fn cold_start_cells(exp: &Experiment, cells: &mut Vec<Cell>) {
    let split = exp.split(CODE_SPLIT);
    let specialist = model_index("code-llama-34b").expect("roster has the code specialist");
    let all = exp.observations(CODE_SPLIT, 1.0);
    let dim = exp.train_emb[CODE_SPLIT].first().map(|v| v.len()).unwrap_or(256);

    // withhold every comparison touching the specialist...
    let mut cold = Vec::with_capacity(all.len());
    let mut withheld = Vec::new();
    for o in &all {
        let (keep, drop): (Vec<_>, Vec<_>) = o
            .comparisons
            .iter()
            .copied()
            .partition(|c| c.a != specialist && c.b != specialist);
        if !keep.is_empty() {
            cold.push(Observation { embedding: o.embedding.clone(), comparisons: keep });
        }
        if !drop.is_empty() {
            withheld.push(Observation { embedding: o.embedding.clone(), comparisons: drop });
        }
    }

    let mut router = EagleRouter::fit(
        EagleParams::default(),
        exp.n_models(),
        FlatStore::with_capacity(dim, all.len()),
        &cold,
    );
    let cold_scores = router.score_batch(&exp.test_emb[CODE_SPLIT]);
    let auc_cold = method_curve("budget", &cold_scores, &split.test, &exp.policy).auc();

    // ...then replay the withheld records (the specialist warms up)
    router.update(&withheld);
    let warm_scores = router.score_batch(&exp.test_emb[CODE_SPLIT]);

    push_method_cells("cold_start", &warm_scores, &split.test, &exp.policy, cells);
    let auc_warm = method_curve("budget", &warm_scores, &split.test, &exp.policy).auc();
    cells.push(cell("cold_start", "budget", "auc_cold", auc_cold));
    cells.push(cell("cold_start", "budget", "recovery_gain", auc_warm - auc_cold));
}

fn burst_skew_cells(exp: &Experiment, cells: &mut Vec<Cell>) {
    const K: usize = 4;
    let split = exp.split(PRIMARY_SPLIT);
    let dim = exp.train_emb[PRIMARY_SPLIT].first().map(|v| v.len()).unwrap_or(256);
    let obs = exp.observations(PRIMARY_SPLIT, 1.0);

    // bursty arrival: all of topic 0, then all of topic 1, ... (stable
    // within a topic). Observation i belongs to train prompt i only when
    // every prompt has feedback; recover the topic through the index map.
    let mut order: Vec<usize> = (0..obs.len()).collect();
    order.sort_by_key(|&i| (split.train[i].topic, i));
    let bursty: Vec<Observation> = order.iter().map(|&i| obs[i].clone()).collect();

    let cadence = EpochParams { publish_every: 64, publish_interval_ms: 60_000 };
    let shards = ShardParams { count: K, hash_seed: SHARD_HASH_SEED };
    let mut sharded =
        ShardedRouter::new(EagleParams::default(), exp.n_models(), dim, cadence, shards);
    let mut per_shard = [0usize; K];
    for o in &bursty {
        per_shard[shard_of(&o.embedding, SHARD_HASH_SEED, K)] += 1;
        sharded.observe(o.clone());
    }
    sharded.publish_all();
    let snap = sharded.handle().load();
    let scores = snap.score_batch(&exp.test_emb[PRIMARY_SPLIT]);

    // reference: a flat router fed the identical stream
    let flat = EagleRouter::fit(
        EagleParams::default(),
        exp.n_models(),
        FlatStore::with_capacity(dim, bursty.len()),
        &bursty,
    );
    let flat_scores = flat.score_batch(&exp.test_emb[PRIMARY_SPLIT]);
    let divergence = scores
        .iter()
        .flatten()
        .zip(flat_scores.iter().flatten())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let max_shard = per_shard.iter().copied().max().unwrap_or(0);
    let imbalance = max_shard as f64 * K as f64 / bursty.len().max(1) as f64;

    push_method_cells("burst_skew", &scores, &split.test, &exp.policy, cells);
    cells.push(cell("burst_skew", "sharded", "score_divergence", divergence));
    cells.push(cell("burst_skew", "sharded", "shard_imbalance", imbalance));
}

// ---------------------------------------------------------------------------
// adversarial
// ---------------------------------------------------------------------------

/// One deterministically-garbled line: every variant must be a parse
/// reject (the scenario asserts errors == garbage lines).
fn garbage_line(rng: &mut Rng, i: usize) -> String {
    match rng.below(7) {
        0 => format!("!!not json at all {i}"),
        1 => {
            // truncated valid request
            let full = format!("{{\"op\":\"route\",\"text\":\"q{i}\",\"budget\":0.01}}");
            full[..full.len() - 3].to_string()
        }
        2 => format!("{{\"op\":\"warp\",\"text\":\"q{i}\"}}"),
        3 => "{\"v\":3,\"op\":\"ping\"}".to_string(),
        4 => format!("{{\"v\":2,\"op\":\"ping\",\"junk\":{i}}}"),
        5 => "[1,2,3]".to_string(),
        _ => format!("{{\"v\":2,\"op\":\"route\",\"text\":\"q{i}\",\"threshold\":0.5}}"),
    }
}

/// A deterministically-valid line exercising v1, v2 policies, hello and
/// feedback through the real codec.
fn valid_line(rng: &mut Rng, i: usize, registry: &ModelRegistry) -> String {
    match rng.below(6) {
        0 => format!("{{\"op\":\"route\",\"text\":\"adv query {i}\",\"budget\":0.01}}"),
        1 => format!(
            "{{\"v\":2,\"op\":\"route\",\"text\":\"adv query {i}\",\"policy\":\"cost_aware\",\"budget\":0.02}}"
        ),
        2 => format!(
            "{{\"v\":2,\"op\":\"route\",\"text\":\"adv query {i}\",\"policy\":\"threshold\",\"threshold\":0.6}}"
        ),
        3 => "{\"v\":2,\"op\":\"hello\"}".to_string(),
        4 => format!("{{\"v\":2,\"op\":\"route_batch\",\"texts\":[\"adv a {i}\",\"adv b {i}\"]}}"),
        _ => {
            let a = rng.below(registry.len());
            let mut b = rng.below(registry.len() - 1);
            if b >= a {
                b += 1;
            }
            format!(
                "{{\"op\":\"feedback\",\"text\":\"adv fb {i}\",\"model_a\":\"{}\",\"model_b\":\"{}\",\"score_a\":1}}",
                registry.entry(a).name,
                registry.entry(b).name
            )
        }
    }
}

/// Wire half of the adversarial scenario: a seeded mix of garbage and
/// valid lines through [`ServerState::handle_lines`] (hash embedder, no
/// TCP — the parse/dispatch/reply path is identical).
fn adversarial_wire_cells(cfg: &ScenarioConfig, cells: &mut Vec<Cell>) {
    const DIM: usize = 64;
    const LINES: usize = 320;
    let metrics = Arc::new(Metrics::new());
    let service = EmbedService::start_hash(
        DIM,
        BatcherOptions { batch_window_us: 50, max_batch: 16 },
        metrics.clone(),
    );
    let registry = ModelRegistry::routerbench();
    let router = EagleRouter::new(EagleParams::default(), registry.len(), FlatStore::new(DIM));
    let state = ServerState::builder(router, registry.clone(), service.handle(), metrics)
        .epoch(EpochParams { publish_every: 32, publish_interval_ms: 60_000 })
        .build();

    let mut rng = Rng::new(cfg.seed ^ 0xAD5E_11E5);
    let mut lines = Vec::with_capacity(LINES);
    let mut garbage = 0usize;
    for i in 0..LINES {
        if rng.chance(0.4) {
            garbage += 1;
            lines.push(garbage_line(&mut rng, i));
        } else {
            lines.push(valid_line(&mut rng, i, &registry));
        }
    }

    let mut srv_rng = Rng::new(cfg.seed ^ 0x5E7E_C7ED);
    let mut errors = 0usize;
    for unit in lines.chunks(8) {
        for resp in state.handle_lines(unit, &mut srv_rng) {
            if matches!(resp, Response::Error(_)) {
                errors += 1;
            }
        }
    }
    state.stop();

    // every garbage line errors, every valid line succeeds — anything
    // else is a protocol bug, surfaced as survived = 0
    let survived = f64::from(u8::from(errors == garbage));
    cells.push(cell("adversarial", "wire", "error_reply_rate", errors as f64 / LINES as f64));
    cells.push(cell("adversarial", "wire", "survived", survived));
}

/// Durable half: append a seeded history through the real frame codec,
/// flip one byte at the tail of a delta log, and measure how much of the
/// history recovery salvages.
fn adversarial_durable_cells(cfg: &ScenarioConfig, cells: &mut Vec<Cell>) {
    const DIM: usize = 16;
    const K: usize = 2;
    const N: usize = 120;
    let n_models = ModelRegistry::routerbench().len();
    let dir = std::env::temp_dir()
        .join(format!("eagle_scenario_durable_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let meta = StoreMeta {
        params: EagleParams::default(),
        n_models,
        dim: DIM,
        shards: ShardParams { count: K, hash_seed: SHARD_HASH_SEED },
    };
    let opts = DurableOptions { seal_bytes: 1 << 20, fsync: false, mmap: true };
    let store = DurableStore::create(&dir, meta, opts.clone()).expect("create durable store");
    let mut writers: Vec<_> = (0..K).map(|s| store.lane_writer(s).expect("lane writer")).collect();

    let mut rng = Rng::new(cfg.seed ^ 0xD15C_C0DE);
    for gid in 0..N {
        let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        let a = rng.below(n_models);
        let mut b = rng.below(n_models - 1);
        if b >= a {
            b += 1;
        }
        let outcome = match rng.below(3) {
            0 => Outcome::WinA,
            1 => Outcome::WinB,
            _ => Outcome::Draw,
        };
        let obs = Observation::single(v, crate::elo::Comparison { a, b, outcome });
        let shard = shard_of(&obs.embedding, SHARD_HASH_SEED, K);
        writers[shard].append(gid as u32, &obs).expect("append");
        if gid == N / 2 {
            writers[0].seal().expect("seal");
        }
    }
    for w in &mut writers {
        w.sync().expect("sync");
    }
    drop(writers);
    drop(store);

    // flip the last byte of shard 0's newest non-empty delta log: the
    // final frame's checksum breaks and recovery must drop exactly the
    // torn tail, keeping everything before it
    let shard_dir = dir.join("shard-0");
    let mut logs: Vec<PathBuf> = std::fs::read_dir(&shard_dir)
        .expect("read shard dir")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.starts_with("delta-"))
                && p.metadata().map(|m| m.len() > 0).unwrap_or(false)
        })
        .collect();
    logs.sort();
    let target = logs.last().expect("a non-empty delta log to corrupt");
    let mut bytes = std::fs::read(target).expect("read delta log");
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(target, bytes).expect("write corrupted log");

    let (recovered, ratio) = match DurableStore::open(&dir, opts) {
        Ok((_store, recovery)) => {
            let total = recovery.total_records();
            let cadence = EpochParams { publish_every: 64, publish_interval_ms: 60_000 };
            let ok = recovery.into_router(cadence).is_ok();
            (f64::from(u8::from(ok)), total as f64 / N as f64)
        }
        Err(_) => (0.0, 0.0),
    };
    let _ = std::fs::remove_dir_all(&dir);

    cells.push(cell("adversarial", "durable", "recovered_ratio", ratio));
    cells.push(cell("adversarial", "durable", "survived", recovered));
}

fn adversarial_cells(cfg: &ScenarioConfig, cells: &mut Vec<Cell>) {
    adversarial_wire_cells(cfg, cells);
    adversarial_durable_cells(cfg, cells);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_sort_render_and_lookup() {
        let r = MatrixResult {
            seed: 1,
            per_dataset: 2,
            cells: vec![
                cell("a", "m", "auc", 0.5),
                cell("a", "m", "cost_savings_ratio", 0.25),
            ],
        };
        assert_eq!(r.get("a", "m", "auc"), Some(0.5));
        assert_eq!(r.get("a", "m", "nope"), None);
        let csv = r.to_csv();
        assert!(csv.starts_with("scenario,method,metric,value\n"));
        assert!(csv.contains("a,m,auc,0.5\n"));
        let doc = json::parse(&r.to_json()).unwrap();
        assert_eq!(doc.get("schema_version").as_f64(), Some(1.0));
        assert_eq!(doc.get("cells").as_arr().unwrap().len(), 2);
        let names: Vec<String> = r.metrics().into_iter().map(|(k, _)| k).collect();
        assert_eq!(names[0], "scenario.a.m.auc");
    }

    #[test]
    fn matrix_runs_deterministically_and_covers_every_cell() {
        let cfg = ScenarioConfig { seed: 11, per_dataset: 40 };
        let first = run_matrix(&cfg);
        let second = run_matrix(&cfg);
        assert_eq!(first.to_csv(), second.to_csv(), "CSV must be seed-stable");
        assert_eq!(first.to_json(), second.to_json(), "JSON must be seed-stable");

        // every quality scenario × method has both gated metrics
        for scenario in ["baseline", "drift", "cold_start", "burst_skew"] {
            for method in METHODS {
                let auc = first.get(scenario, method, "auc").unwrap();
                assert!((0.0..=1.0).contains(&auc), "{scenario}/{method} auc = {auc}");
                assert!(
                    first.get(scenario, method, "cost_savings_ratio").is_some(),
                    "{scenario}/{method} missing cost_savings_ratio"
                );
            }
        }

        // sharded scoring is bit-identical even under bursty skew
        assert_eq!(first.get("burst_skew", "sharded", "score_divergence"), Some(0.0));
        let imb = first.get("burst_skew", "sharded", "shard_imbalance").unwrap();
        assert!(imb >= 1.0, "max/mean shard load must be >= 1, got {imb}");

        // the wire survived the garbage mix and rejected exactly it
        assert_eq!(first.get("adversarial", "wire", "survived"), Some(1.0));
        let err = first.get("adversarial", "wire", "error_reply_rate").unwrap();
        assert!(err > 0.0 && err < 1.0, "error rate {err}");

        // corruption lost only the torn tail
        assert_eq!(first.get("adversarial", "durable", "survived"), Some(1.0));
        let ratio = first.get("adversarial", "durable", "recovered_ratio").unwrap();
        assert!(ratio > 0.9 && ratio <= 1.0, "recovered {ratio}");
    }
}
