//! Evaluation harness: the paper's §3 protocol.
//!
//! For each router and dataset: sweep willingness-to-pay levels, route
//! every test prompt under each budget, and record (mean $ cost, mean
//! quality). The **AUC** is the trapezoidal integral of quality over the
//! *normalized* cost axis — "a router's average performance across all
//! cost scenarios" (Fig 2b). Also computes the non-decreasing convex
//! envelope RouterBench uses so pathological routers don't get credit for
//! spending more and scoring less.

pub mod harness;
pub mod scenario;

use crate::coordinator::policy::RoutePolicy;
use crate::coordinator::Router;
use crate::routerbench::Sample;
use crate::util::trapezoid_auc;

/// One point on a router's cost-quality curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurvePoint {
    pub budget: f64,
    pub mean_cost: f64,
    pub mean_quality: f64,
}

/// A router's full cost-quality curve on one dataset.
#[derive(Debug, Clone)]
pub struct CostQualityCurve {
    pub router: String,
    pub dataset: String,
    pub points: Vec<CurvePoint>,
}

impl CostQualityCurve {
    /// Non-decreasing quality envelope over increasing cost: for every
    /// point, the best quality achievable at or below that cost.
    pub fn envelope(&self) -> Vec<(f64, f64)> {
        let mut pts: Vec<(f64, f64)> =
            self.points.iter().map(|p| (p.mean_cost, p.mean_quality)).collect();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut best = f64::MIN;
        for p in &mut pts {
            best = best.max(p.1);
            p.1 = best;
        }
        pts
    }

    /// AUC: trapezoidal integral of mean quality over the
    /// willingness-to-pay axis, normalized by the budget span (paper Fig
    /// 2a/2b: "average performance across all cost scenarios"). All
    /// routers on a dataset share the same budget sweep, so AUCs are
    /// directly comparable and the per-sample oracle provably dominates.
    pub fn auc(&self) -> f64 {
        if self.points.len() < 2 {
            return self.points.first().map(|p| p.mean_quality).unwrap_or(0.0);
        }
        let pts: Vec<(f64, f64)> =
            self.points.iter().map(|p| (p.budget, p.mean_quality)).collect();
        let span = pts.last().unwrap().0 - pts.first().unwrap().0;
        if span <= 0.0 {
            return pts.last().unwrap().1;
        }
        trapezoid_auc(&pts) / span
    }
}

/// Evaluate one router on one dataset's test split.
///
/// `embeddings[i]` must be the embedding of `test[i]`'s prompt.
pub fn evaluate_router(
    router: &dyn Router,
    test: &[Sample],
    embeddings: &[Vec<f32>],
    policy: &RoutePolicy,
    dataset: &str,
) -> CostQualityCurve {
    assert_eq!(test.len(), embeddings.len(), "embedding/sample mismatch");
    let budgets = policy.budget_sweep();
    let mut points = Vec::with_capacity(budgets.len());

    // score each test prompt once; selection per budget reuses the scores
    let scores: Vec<Vec<f64>> = embeddings.iter().map(|e| router.scores(e)).collect();

    for &budget in &budgets {
        let mut cost_sum = 0.0f64;
        let mut quality_sum = 0.0f64;
        for (sample, score) in test.iter().zip(&scores) {
            let choice = policy.select(score, budget);
            cost_sum += sample.cost[choice] as f64;
            quality_sum += sample.quality[choice] as f64;
        }
        let n = test.len().max(1) as f64;
        points.push(CurvePoint {
            budget,
            mean_cost: cost_sum / n,
            mean_quality: quality_sum / n,
        });
    }
    CostQualityCurve { router: router.name(), dataset: dataset.to_string(), points }
}

/// Reference curves: the oracle (per-sample best affordable model) and each
/// single model, for context in reports.
pub fn oracle_curve(test: &[Sample], policy: &RoutePolicy, dataset: &str) -> CostQualityCurve {
    let budgets = policy.budget_sweep();
    let mut points = Vec::with_capacity(budgets.len());
    for &budget in &budgets {
        let mut cost_sum = 0.0;
        let mut quality_sum = 0.0;
        for s in test {
            // oracle: best quality among affordable; ties -> cheapest
            let mut best: Option<usize> = None;
            for m in 0..s.quality.len() {
                if policy.costs()[m] > budget {
                    continue;
                }
                best = match best {
                    None => Some(m),
                    Some(b) => {
                        if s.quality[m] > s.quality[b]
                            || (s.quality[m] == s.quality[b]
                                && s.cost[m] < s.cost[b])
                        {
                            Some(m)
                        } else {
                            Some(b)
                        }
                    }
                };
            }
            let choice = best.unwrap_or_else(|| policy.cheapest());
            cost_sum += s.cost[choice] as f64;
            quality_sum += s.quality[choice] as f64;
        }
        let n = test.len().max(1) as f64;
        points.push(CurvePoint {
            budget,
            mean_cost: cost_sum / n,
            mean_quality: quality_sum / n,
        });
    }
    CostQualityCurve { router: "oracle".into(), dataset: dataset.into(), points }
}

/// Mean quality and cost of always using one model (row for reports).
pub fn single_model_point(test: &[Sample], model: usize) -> (f64, f64) {
    let n = test.len().max(1) as f64;
    let cost = test.iter().map(|s| s.cost[model] as f64).sum::<f64>() / n;
    let quality = test.iter().map(|s| s.quality[model] as f64).sum::<f64>() / n;
    (cost, quality)
}

/// Summed AUC across datasets (the paper's headline aggregate).
pub fn summed_auc(curves: &[CostQualityCurve]) -> f64 {
    curves.iter().map(|c| c.auc()).sum()
}

/// Percentage improvement of `ours` over `baseline`.
pub fn improvement_pct(ours: f64, baseline: f64) -> f64 {
    if baseline == 0.0 {
        0.0
    } else {
        (ours - baseline) / baseline * 100.0
    }
}

/// Cost savings at matched quality (RouterBench's headline routing win):
/// the fraction of a reference spend the router saves while still
/// delivering at least `(1 - tolerance) *` the reference quality.
///
/// Walks the router's non-decreasing quality [envelope](CostQualityCurve::envelope)
/// for the cheapest point whose quality clears the bar, then compares its
/// cost to `reference` (typically [`single_model_point`] of the best single
/// model). Returns `None` when the router never reaches the bar, and
/// clamps at 0 when matching quality costs *more* than the reference — a
/// negative saving is a routing loss, and reporting it as 0 keeps the
/// metric's "bigger is better" trend-gate orientation.
pub fn cost_savings_at_matched_quality(
    curve: &CostQualityCurve,
    reference: (f64, f64),
    tolerance: f64,
) -> Option<f64> {
    let (ref_cost, ref_quality) = reference;
    if ref_cost <= 0.0 {
        return None;
    }
    let bar = ref_quality * (1.0 - tolerance);
    let matched = curve
        .envelope()
        .into_iter()
        .find(|&(_, q)| q >= bar)?; // envelope is cost-sorted: first hit is cheapest
    Some(((ref_cost - matched.0) / ref_cost).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Router;

    struct FixedRouter(Vec<f64>);

    impl Router for FixedRouter {
        fn name(&self) -> String {
            "fixed".into()
        }
        fn scores(&self, _q: &[f32]) -> Vec<f64> {
            self.0.clone()
        }
    }

    fn mk_samples() -> Vec<Sample> {
        // 2 models: model 0 cheap/bad, model 1 pricey/good
        (0..10)
            .map(|i| Sample {
                dataset: 0,
                topic: 0,
                text: format!("q{i}"),
                difficulty: 0.5,
                quality: vec![0.2, 0.9],
                cost: vec![0.001, 0.01],
            })
            .collect()
    }

    fn mk_embeddings(n: usize) -> Vec<Vec<f32>> {
        (0..n).map(|_| vec![1.0, 0.0]).collect()
    }

    #[test]
    fn curve_shape_quality_rises_with_budget() {
        let samples = mk_samples();
        let policy = RoutePolicy::from_costs(vec![0.001, 0.01]);
        let router = FixedRouter(vec![0.2, 0.9]);
        let curve =
            evaluate_router(&router, &samples, &mk_embeddings(10), &policy, "test");
        let q_low = curve.points.first().unwrap().mean_quality;
        let q_high = curve.points.last().unwrap().mean_quality;
        assert!(q_low < q_high);
        assert!((q_high - 0.9).abs() < 1e-6);
    }

    #[test]
    fn auc_between_extremes() {
        let samples = mk_samples();
        let policy = RoutePolicy::from_costs(vec![0.001, 0.01]);
        let router = FixedRouter(vec![0.2, 0.9]);
        let curve =
            evaluate_router(&router, &samples, &mk_embeddings(10), &policy, "test");
        let auc = curve.auc();
        assert!(auc > 0.2 && auc <= 0.9, "auc = {auc}");
    }

    #[test]
    fn envelope_is_nondecreasing() {
        let c = CostQualityCurve {
            router: "x".into(),
            dataset: "d".into(),
            points: vec![
                CurvePoint { budget: 1.0, mean_cost: 1.0, mean_quality: 0.5 },
                CurvePoint { budget: 2.0, mean_cost: 2.0, mean_quality: 0.3 },
                CurvePoint { budget: 3.0, mean_cost: 3.0, mean_quality: 0.8 },
            ],
        };
        let env = c.envelope();
        assert_eq!(env[1].1, 0.5); // lifted from 0.3
        assert_eq!(env[2].1, 0.8);
    }

    #[test]
    fn oracle_at_least_as_good_as_any_router() {
        let samples = mk_samples();
        let policy = RoutePolicy::from_costs(vec![0.001, 0.01]);
        let router = FixedRouter(vec![0.9, 0.2]); // deliberately wrong
        let rc = evaluate_router(&router, &samples, &mk_embeddings(10), &policy, "t");
        let oc = oracle_curve(&samples, &policy, "t");
        assert!(oc.auc() >= rc.auc() - 1e-9);
    }

    #[test]
    fn single_model_point_means() {
        let samples = mk_samples();
        let (c, q) = single_model_point(&samples, 1);
        assert!((c - 0.01).abs() < 1e-6);
        assert!((q - 0.9).abs() < 1e-6);
    }

    #[test]
    fn improvement_pct_math() {
        assert!((improvement_pct(1.2, 1.0) - 20.0).abs() < 1e-9);
        assert_eq!(improvement_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn cost_savings_at_matched_quality_metric() {
        let samples = mk_samples();
        let policy = RoutePolicy::from_costs(vec![0.001, 0.01]);
        let router = FixedRouter(vec![0.2, 0.9]);
        let curve = evaluate_router(&router, &samples, &mk_embeddings(10), &policy, "t");
        let best_single = single_model_point(&samples, 1); // (0.01, 0.9)

        // at zero tolerance the router must pay for model 1 everywhere:
        // no savings, but the bar is reached
        let s0 = cost_savings_at_matched_quality(&curve, best_single, 0.0).unwrap();
        assert!((0.0..=1e-9).contains(&s0), "s0 = {s0}");

        // a bar below the cheap model's quality is matched at the cheap
        // model's cost: savings = 1 - 0.001/0.01 = 0.9
        let s_loose = cost_savings_at_matched_quality(&curve, best_single, 0.8).unwrap();
        assert!((s_loose - 0.9).abs() < 1e-9, "s_loose = {s_loose}");

        // an unreachable bar: reference quality far above anything
        assert_eq!(cost_savings_at_matched_quality(&curve, (0.01, 5.0), 0.0), None);
        // degenerate reference cost
        assert_eq!(cost_savings_at_matched_quality(&curve, (0.0, 0.9), 0.0), None);
    }

    #[test]
    fn summed_auc_adds() {
        let samples = mk_samples();
        let policy = RoutePolicy::from_costs(vec![0.001, 0.01]);
        let router = FixedRouter(vec![0.2, 0.9]);
        let c1 = evaluate_router(&router, &samples, &mk_embeddings(10), &policy, "a");
        let c2 = c1.clone();
        let total = summed_auc(&[c1.clone(), c2]);
        assert!((total - 2.0 * c1.auc()).abs() < 1e-12);
    }
}
