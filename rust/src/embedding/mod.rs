//! Embedding service: tokenization + dynamic batching in front of the PJRT
//! embedder.
//!
//! PJRT handles are `!Send`, so a dedicated **engine thread** owns the
//! [`Runtime`]; callers talk to it through an mpsc channel and get their
//! vector back on a oneshot-style reply channel. The engine loop implements
//! the classic dynamic batcher: it drains whatever is queued (up to
//! `max_batch`), waits at most `batch_window_us` for batch-mates, pads to
//! the smallest compiled bucket, and runs one PJRT dispatch for the whole
//! batch — amortizing dispatch overhead exactly like a vLLM-style serving
//! engine batches decode steps.
//!
//! [`HashEmbedder`] is a pure-rust fallback (hashed bag-of-words random
//! projection) used by unit tests and benches that must run without built
//! artifacts; it preserves the only property the routers rely on (shared
//! tokens => nearby vectors) but is NOT the serving path.

use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::Metrics;
use crate::runtime::Runtime;
use crate::tokenizer::{self, Tokenized};
use crate::util::l2_normalize;

/// Anything that maps texts to L2-normalized embedding vectors.
pub trait Embedder: Send {
    /// Embedding dimensionality.
    fn dim(&self) -> usize;

    /// Embed a batch of texts (one vector per text, unit L2 norm or zero).
    fn embed(&self, texts: &[&str]) -> Vec<Vec<f32>>;
}

// ---------------------------------------------------------------------------
// PJRT-backed service

enum EngineMsg {
    Embed { tokenized: Tokenized, reply: mpsc::Sender<Result<Vec<f32>>> },
    Shutdown,
}

/// Handle to the embedding engine thread. Cloneable; cheap.
#[derive(Clone)]
pub struct EmbedHandle {
    tx: mpsc::Sender<EngineMsg>,
    dim: usize,
    seq_len: usize,
    vocab: u32,
}

/// The engine thread plus its handle. Dropping joins the thread.
pub struct EmbedService {
    handle: EmbedHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Dynamic-batcher tuning knobs (see [`crate::config::EmbedParams`]).
#[derive(Debug, Clone, Copy)]
pub struct BatcherOptions {
    pub batch_window_us: u64,
    pub max_batch: usize,
}

impl Default for BatcherOptions {
    fn default() -> Self {
        BatcherOptions { batch_window_us: 200, max_batch: 32 }
    }
}

impl EmbedService {
    /// Start an engine thread backed by the pure-rust [`HashEmbedder`]
    /// instead of PJRT: same handle type, same dynamic batcher, no
    /// artifacts required. Tests and benches that exercise the serving
    /// stack end-to-end (batching, embed-on-applier ingest) use this so
    /// they run on a bare machine; it is NOT the serving path.
    pub fn start_hash(dim: usize, opts: BatcherOptions, metrics: Arc<Metrics>) -> EmbedService {
        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let embedder = HashEmbedder::new(dim);
        let join = std::thread::Builder::new()
            .name("eagle-embed-hash".to_string())
            .spawn(move || hash_engine_loop(embedder, rx, opts, metrics))
            .expect("spawn hash embed thread");
        EmbedService {
            handle: EmbedHandle {
                tx,
                dim,
                seq_len: tokenizer::SEQ_LEN,
                vocab: tokenizer::VOCAB_SIZE,
            },
            join: Some(join),
        }
    }

    /// Start the engine thread over the artifacts in `dir`.
    pub fn start(dir: &Path, opts: BatcherOptions, metrics: Arc<Metrics>) -> Result<EmbedService> {
        // Load the manifest on the caller thread first so startup errors
        // surface synchronously and we know dim/seq for the handle.
        let manifest = crate::runtime::Manifest::load(dir)?;
        let dim = manifest.model.d_model;
        let seq_len = manifest.model.seq_len;
        let vocab = manifest.model.vocab_size;
        let dir = dir.to_path_buf();

        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name("eagle-embed-engine".to_string())
            .spawn(move || {
                let runtime = match Runtime::load(&dir) {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(runtime, rx, opts, metrics);
            })
            .map_err(|e| anyhow!("spawn engine thread: {e}"))?;

        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;

        Ok(EmbedService {
            handle: EmbedHandle { tx, dim, seq_len, vocab },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> EmbedHandle {
        self.handle.clone()
    }
}

impl Drop for EmbedService {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(EngineMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EmbedHandle {
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Embed one text (blocks until the engine replies).
    pub fn embed_one(&self, text: &str) -> Result<Vec<f32>> {
        let tokenized = tokenizer::tokenize(text, self.seq_len, self.vocab);
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Embed { tokenized, reply: reply_tx })
            .map_err(|_| anyhow!("embed engine is down"))?;
        reply_rx.recv().map_err(|_| anyhow!("embed engine dropped request"))?
    }

    /// Embed many texts; the engine batches them into compiled buckets.
    pub fn embed_many(&self, texts: &[&str]) -> Result<Vec<Vec<f32>>> {
        let mut replies = Vec::with_capacity(texts.len());
        for t in texts {
            let tokenized = tokenizer::tokenize(t, self.seq_len, self.vocab);
            let (reply_tx, reply_rx) = mpsc::channel();
            self.tx
                .send(EngineMsg::Embed { tokenized, reply: reply_tx })
                .map_err(|_| anyhow!("embed engine is down"))?;
            replies.push(reply_rx);
        }
        replies
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow!("embed engine dropped request"))?)
            .collect()
    }

    /// Embed many texts with **per-text** results: a failed text yields
    /// its own `Err` without poisoning the rest of the slab. The ingest
    /// pipeline uses this so one bad record (or one transient engine
    /// error) drops exactly the affected records, never the whole batch.
    pub fn embed_each(&self, texts: &[&str]) -> Vec<Result<Vec<f32>>> {
        let mut replies = Vec::with_capacity(texts.len());
        for t in texts {
            let tokenized = tokenizer::tokenize(t, self.seq_len, self.vocab);
            let (reply_tx, reply_rx) = mpsc::channel();
            match self.tx.send(EngineMsg::Embed { tokenized, reply: reply_tx }) {
                Ok(()) => replies.push(Some(reply_rx)),
                Err(_) => replies.push(None),
            }
        }
        replies
            .into_iter()
            .map(|rx| match rx {
                Some(rx) => match rx.recv() {
                    Ok(res) => res,
                    Err(_) => Err(anyhow!("embed engine dropped request")),
                },
                None => Err(anyhow!("embed engine is down")),
            })
            .collect()
    }
}

/// One queued embed request awaiting its engine reply.
type PendingEmbed = (Tokenized, mpsc::Sender<Result<Vec<f32>>>);

/// The drain-or-wait batching state machine shared by the PJRT and hash
/// engine threads: block for the first request, linger up to `window`
/// for batch-mates (capped at `max_batch`), hand the batch to `run`, and
/// flush the partial batch once on shutdown/disconnect.
fn batch_loop<F>(rx: mpsc::Receiver<EngineMsg>, window: Duration, max_batch: usize, mut run: F)
where
    F: FnMut(&mut Vec<PendingEmbed>),
{
    let max_batch = max_batch.max(1);
    loop {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(EngineMsg::Embed { tokenized, reply }) => (tokenized, reply),
            Ok(EngineMsg::Shutdown) | Err(_) => return,
        };
        let mut batch = vec![first];
        // Linger up to `window` for batch-mates.
        let deadline = Instant::now() + window;
        while batch.len() < max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(EngineMsg::Embed { tokenized, reply }) => batch.push((tokenized, reply)),
                Ok(EngineMsg::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    run(&mut batch);
                    return;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
            }
            if timeout.is_zero() {
                break;
            }
        }
        run(&mut batch);
    }
}

/// The engine loop: drain-or-wait batching, bucket padding, PJRT dispatch.
fn engine_loop(
    runtime: Runtime,
    rx: mpsc::Receiver<EngineMsg>,
    opts: BatcherOptions,
    metrics: Arc<Metrics>,
) {
    let seq = runtime.manifest().model.seq_len;
    let dim = runtime.manifest().model.d_model;
    let max_batch = opts.max_batch.min(runtime.manifest().max_bucket()).max(1);
    let window = Duration::from_micros(opts.batch_window_us);
    batch_loop(rx, window, max_batch, |batch| {
        run_batch(&runtime, batch, seq, dim, &metrics)
    });
}

fn run_batch(
    runtime: &Runtime,
    batch: &mut Vec<(Tokenized, mpsc::Sender<Result<Vec<f32>>>)>,
    seq: usize,
    dim: usize,
    metrics: &Metrics,
) {
    if batch.is_empty() {
        return;
    }
    let t0 = Instant::now();
    let n = batch.len();
    let bucket = match runtime.manifest().pick_bucket(n) {
        Some(b) => b,
        None => {
            // Shouldn't happen (engine_loop caps at max_bucket); fail soft.
            for (_, reply) in batch.drain(..) {
                let _ = reply.send(Err(anyhow!("batch exceeds compiled buckets")));
            }
            metrics.errors.inc();
            return;
        }
    };

    // Pad to the bucket with empty rows.
    let mut tokens = vec![0i32; bucket * seq];
    let mut mask = vec![0f32; bucket * seq];
    for (i, (t, _)) in batch.iter().enumerate() {
        tokens[i * seq..(i + 1) * seq].copy_from_slice(&t.ids);
        mask[i * seq..(i + 1) * seq].copy_from_slice(&t.mask);
    }

    match runtime.embed_batch(&tokens, &mask, bucket) {
        Ok(flat) => {
            // Record metrics BEFORE replying: callers may read counters as
            // soon as their reply arrives (tests do exactly that).
            metrics.embed_batches.inc();
            metrics.embed_queries.add(n as u64);
            metrics.embed_latency.record(t0.elapsed());
            for (i, (_, reply)) in batch.drain(..).enumerate() {
                let v = flat[i * dim..(i + 1) * dim].to_vec();
                let _ = reply.send(Ok(v));
            }
        }
        Err(e) => {
            metrics.errors.inc();
            let msg = format!("{e}");
            for (_, reply) in batch.drain(..) {
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// The hash-backend engine loop: the same [`batch_loop`] state machine as
/// the PJRT engine, with the PJRT dispatch replaced by
/// [`HashEmbedder::embed_tokenized`]. Embeddings are bit-identical to
/// calling [`HashEmbedder::embed`] on the same text (both sides share the
/// default tokenizer parameters), which is what lets end-to-end tests
/// replay the server's ingest stream against a reference router.
fn hash_engine_loop(
    embedder: HashEmbedder,
    rx: mpsc::Receiver<EngineMsg>,
    opts: BatcherOptions,
    metrics: Arc<Metrics>,
) {
    let window = Duration::from_micros(opts.batch_window_us);
    batch_loop(rx, window, opts.max_batch, |batch| {
        if batch.is_empty() {
            return;
        }
        let t0 = Instant::now();
        metrics.embed_batches.inc();
        metrics.embed_queries.add(batch.len() as u64);
        for (tok, reply) in batch.drain(..) {
            let _ = reply.send(Ok(embedder.embed_tokenized(&tok)));
        }
        metrics.embed_latency.record(t0.elapsed());
    });
}

/// Blocking [`Embedder`] adapter over an [`EmbedHandle`].
pub struct ServiceEmbedder {
    handle: EmbedHandle,
}

impl ServiceEmbedder {
    pub fn new(handle: EmbedHandle) -> Self {
        ServiceEmbedder { handle }
    }
}

impl Embedder for ServiceEmbedder {
    fn dim(&self) -> usize {
        self.handle.dim()
    }

    fn embed(&self, texts: &[&str]) -> Vec<Vec<f32>> {
        self.handle
            .embed_many(texts)
            .unwrap_or_else(|_| texts.iter().map(|_| vec![0.0; self.handle.dim()]).collect())
    }
}

// ---------------------------------------------------------------------------
// Pure-rust fallback embedder

/// Hashed bag-of-words random-projection embedder (test/bench fallback).
///
/// Each vocabulary word deterministically seeds a pseudo-random unit
/// direction; a text embeds as the normalized sum of its word directions
/// (with positional damping so word order matters slightly). Shares the
/// tokenizer with the real path.
#[derive(Debug, Clone)]
pub struct HashEmbedder {
    dim: usize,
}

impl HashEmbedder {
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0);
        HashEmbedder { dim }
    }

    fn word_direction(&self, id: i32, out: &mut [f32]) {
        let mut rng = crate::util::Rng::with_stream(id as u64, 0xE19);
        for x in out.iter_mut() {
            *x = (rng.normal()) as f32;
        }
        l2_normalize(out);
    }

    /// Embed an already-tokenized prompt (the hash engine-thread path).
    /// [`HashEmbedder::embed`] is exactly
    /// `embed_tokenized(tokenize_default(text))`.
    pub fn embed_tokenized(&self, tok: &Tokenized) -> Vec<f32> {
        let mut dir = vec![0f32; self.dim];
        let mut v = vec![0f32; self.dim];
        for (pos, (&id, &m)) in tok.ids.iter().zip(&tok.mask).enumerate() {
            if m == 0.0 {
                break;
            }
            self.word_direction(id, &mut dir);
            // light positional damping: later tokens weigh less
            let w = 1.0 / (1.0 + 0.02 * pos as f32);
            for (o, &d) in v.iter_mut().zip(dir.iter()) {
                *o += w * d;
            }
        }
        l2_normalize(&mut v);
        v
    }
}

impl Embedder for HashEmbedder {
    fn dim(&self) -> usize {
        self.dim
    }

    fn embed(&self, texts: &[&str]) -> Vec<Vec<f32>> {
        texts
            .iter()
            .map(|t| self.embed_tokenized(&tokenizer::tokenize_default(t)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cosine;

    #[test]
    fn hash_embedder_unit_norm() {
        let e = HashEmbedder::new(64);
        let vs = e.embed(&["hello world", "", "one two three"]);
        assert!((norm(&vs[0]) - 1.0).abs() < 1e-5);
        assert_eq!(norm(&vs[1]), 0.0); // empty text -> zero vector
        assert!((norm(&vs[2]) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn hash_embedder_deterministic() {
        let e = HashEmbedder::new(32);
        assert_eq!(e.embed(&["alpha beta"]), e.embed(&["alpha beta"]));
    }

    #[test]
    fn hash_embedder_token_overlap_similarity() {
        let e = HashEmbedder::new(128);
        let vs = e.embed(&[
            "solve the quadratic equation for x",
            "solve the linear equation for y",
            "write a poem about autumn leaves",
        ]);
        let same_domain = cosine(&vs[0], &vs[1]);
        let cross_domain = cosine(&vs[0], &vs[2]);
        assert!(
            same_domain > cross_domain + 0.1,
            "same={same_domain} cross={cross_domain}"
        );
    }

    #[test]
    fn hash_embedder_case_insensitive() {
        let e = HashEmbedder::new(32);
        let vs = e.embed(&["Hello World", "hello world!"]);
        assert!((cosine(&vs[0], &vs[1]) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn batcher_options_default() {
        let o = BatcherOptions::default();
        assert_eq!(o.max_batch, 32);
        assert!(o.batch_window_us > 0);
    }

    #[test]
    fn hash_service_matches_direct_embedder_exactly() {
        // the hash-backed engine must be bit-identical to HashEmbedder so
        // e2e tests can replay server streams against a reference router
        let metrics = std::sync::Arc::new(crate::metrics::Metrics::new());
        let svc = EmbedService::start_hash(
            64,
            BatcherOptions { batch_window_us: 50, max_batch: 8 },
            metrics.clone(),
        );
        let handle = svc.handle();
        assert_eq!(handle.dim(), 64);
        let direct = HashEmbedder::new(64);
        let texts = ["solve for x", "write a poem", "", "hello hello world"];
        let via_service = handle.embed_many(&texts).unwrap();
        let via_direct = direct.embed(&texts);
        assert_eq!(via_service, via_direct);
        assert_eq!(handle.embed_one(texts[0]).unwrap(), via_direct[0]);
        assert!(metrics.embed_batches.get() >= 1);
        assert_eq!(metrics.embed_queries.get(), 5);
    }

    fn norm(v: &[f32]) -> f32 {
        v.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    // EmbedService integration tests (needing artifacts) live in
    // rust/tests/runtime_integration.rs.
}
