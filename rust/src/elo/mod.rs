//! ELO rating engine — the core of both Eagle-Global and Eagle-Local.
//!
//! Implements the paper's Eq. (1)–(2):
//!
//! ```text
//! R' = R + K * (S - E)              (1)
//! E  = 1 / (1 + 10^((R_opp - R)/400))   (2)
//! ```
//!
//! Eagle-Global replays every pairwise feedback record once at startup and
//! then applies new records *incrementally* (this is the source of the
//! paper's 100–200x online-update speedup over retraining-based routers).
//! Eagle-Local seeds a fresh engine from the global ratings and replays only
//! the N retrieved neighbors per query.

use std::collections::HashMap;

/// Initial rating for a model never seen before (chess convention, and the
/// value any constant shift of which cancels in rankings).
pub const INITIAL_RATING: f64 = 1000.0;

/// Paper default K-factor (Appendix A.1).
pub const DEFAULT_K: f64 = 32.0;

/// Outcome of one pairwise comparison between model `a` and model `b`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    WinA,
    WinB,
    Draw,
}

impl Outcome {
    /// Actual score S for player `a` (1 win, 0.5 draw, 0 loss).
    pub fn score_a(self) -> f64 {
        match self {
            Outcome::WinA => 1.0,
            Outcome::Draw => 0.5,
            Outcome::WinB => 0.0,
        }
    }

    /// The outcome with the roles of a and b swapped.
    pub fn flipped(self) -> Outcome {
        match self {
            Outcome::WinA => Outcome::WinB,
            Outcome::WinB => Outcome::WinA,
            Outcome::Draw => Outcome::Draw,
        }
    }

    /// Encode for snapshots: 1.0 / 0.5 / 0.0 (= S for a).
    pub fn encode(self) -> f64 {
        self.score_a()
    }

    pub fn decode(x: f64) -> Option<Outcome> {
        if x == 1.0 {
            Some(Outcome::WinA)
        } else if x == 0.5 {
            Some(Outcome::Draw)
        } else if x == 0.0 {
            Some(Outcome::WinB)
        } else {
            None
        }
    }
}

/// One pairwise feedback record: "model `a` vs model `b` on some prompt".
/// Models are dense indices into the model registry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Comparison {
    pub a: usize,
    pub b: usize,
    pub outcome: Outcome,
}

/// Expected score E of a player rated `r` against an opponent rated `r_opp`
/// (paper Eq. 2).
pub fn expected_score(r: f64, r_opp: f64) -> f64 {
    1.0 / (1.0 + 10f64.powf((r_opp - r) / 400.0))
}

/// An ELO rating table over a fixed number of models.
///
/// Dense `Vec<f64>` storage: model ids are registry indices, and the local
/// engine is rebuilt per request — allocation-free ops matter (§Perf).
#[derive(Debug, Clone, PartialEq)]
pub struct EloEngine {
    ratings: Vec<f64>,
    k: f64,
    updates: u64,
}

impl EloEngine {
    /// Fresh engine: every model starts at [`INITIAL_RATING`].
    pub fn new(n_models: usize, k: f64) -> Self {
        EloEngine { ratings: vec![INITIAL_RATING; n_models], k, updates: 0 }
    }

    /// Engine seeded from existing ratings (Eagle-Local seeds from global).
    pub fn seeded(ratings: Vec<f64>, k: f64) -> Self {
        EloEngine { ratings, k, updates: 0 }
    }

    /// Re-seed in place without reallocating (hot path of Eagle-Local).
    pub fn reseed_from(&mut self, ratings: &[f64]) {
        debug_assert_eq!(ratings.len(), self.ratings.len());
        self.ratings.copy_from_slice(ratings);
        self.updates = 0;
    }

    pub fn n_models(&self) -> usize {
        self.ratings.len()
    }

    pub fn k(&self) -> f64 {
        self.k
    }

    /// Number of comparisons applied since creation / reseed.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    pub fn rating(&self, model: usize) -> f64 {
        self.ratings[model]
    }

    pub fn ratings(&self) -> &[f64] {
        &self.ratings
    }

    /// Apply one comparison (paper Eq. 1). O(1).
    pub fn update(&mut self, cmp: Comparison) {
        debug_assert!(cmp.a != cmp.b, "self-play comparison");
        let ra = self.ratings[cmp.a];
        let rb = self.ratings[cmp.b];
        let ea = expected_score(ra, rb);
        let sa = cmp.outcome.score_a();
        let delta = self.k * (sa - ea);
        self.ratings[cmp.a] = ra + delta;
        self.ratings[cmp.b] = rb - delta;
        self.updates += 1;
    }

    /// Replay a batch of comparisons in order.
    pub fn replay(&mut self, cmps: &[Comparison]) {
        for &c in cmps {
            self.update(c);
        }
    }

    /// Models sorted by rating, best first. Ties break by lower index
    /// (deterministic).
    pub fn ranking(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.ratings.len()).collect();
        idx.sort_by(|&i, &j| {
            self.ratings[j]
                .partial_cmp(&self.ratings[i])
                .unwrap()
                .then(i.cmp(&j))
        });
        idx
    }

    /// Sum of all ratings; conserved by [`update`] (zero-sum exchanges).
    pub fn total_rating(&self) -> f64 {
        self.ratings.iter().sum()
    }
}

/// Eagle-Global: an [`EloEngine`] plus bookkeeping for incremental updates.
///
/// `apply_new` consumes only the new feedback records — the paper's
/// "updating global scores once, rather than iteratively optimizing".
///
/// Ratings are **trajectory-averaged** (the paper: "we calculate the
/// *average* ELO rating across all pairwise feedback information"): the
/// reported rating of a model is the mean of its rating after every
/// update, not the last iterate. Sequential ELO's last iterate
/// random-walks with std ~K/2 points, which drowns the 20-40 point gaps
/// between mid-tier models; the trajectory mean converges like 1/sqrt(T)
/// (ablation in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct GlobalElo {
    engine: EloEngine,
    history_len: usize,
    rating_sum: Vec<f64>,
    samples: u64,
}

/// The complete resumable state of a [`GlobalElo`] (see
/// [`GlobalElo::export_state`]): the sequential last iterate plus the
/// trajectory-averaging accumulator, not just the averaged ratings.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalEloState {
    pub last_iterate: Vec<f64>,
    pub rating_sum: Vec<f64>,
    pub samples: u64,
    pub history_len: usize,
}

impl GlobalElo {
    pub fn new(n_models: usize, k: f64) -> Self {
        GlobalElo {
            engine: EloEngine::new(n_models, k),
            history_len: 0,
            rating_sum: vec![0.0; n_models],
            samples: 0,
        }
    }

    /// Initialize from a full history (one pass, no retraining).
    pub fn initialize(n_models: usize, k: f64, history: &[Comparison]) -> Self {
        let mut g = GlobalElo::new(n_models, k);
        g.apply_new(history);
        g
    }

    /// Restore from a snapshot: averaged ratings verbatim, no replay.
    /// The trajectory restarts from the restored point (the sequential
    /// engine is reseeded at the averaged ratings).
    pub fn restore(ratings: Vec<f64>, k: f64, history_len: usize) -> Self {
        GlobalElo {
            rating_sum: ratings.clone(),
            samples: 1,
            engine: EloEngine::seeded(ratings, k),
            history_len,
        }
    }

    /// Export the *full* internal state — last iterate, trajectory sum,
    /// sample count, history length. Unlike the averaged ratings alone
    /// (see [`GlobalElo::restore`]), this is enough to resume folding new
    /// comparisons bit-identically to a table that never stopped; the
    /// durable-store checkpoint ([`crate::coordinator::durable`]) rides it.
    pub fn export_state(&self) -> GlobalEloState {
        GlobalEloState {
            last_iterate: self.engine.ratings().to_vec(),
            rating_sum: self.rating_sum.clone(),
            samples: self.samples,
            history_len: self.history_len,
        }
    }

    /// Rebuild from an exported full state. `apply_new` on the result
    /// behaves bit-identically to the original table (the diagnostic
    /// per-engine update counter restarts at zero; nothing else differs).
    pub fn from_state(state: GlobalEloState, k: f64) -> Self {
        GlobalElo {
            engine: EloEngine::seeded(state.last_iterate, k),
            history_len: state.history_len,
            rating_sum: state.rating_sum,
            samples: state.samples,
        }
    }

    /// Incrementally fold in newly collected feedback.
    pub fn apply_new(&mut self, new_records: &[Comparison]) {
        for &c in new_records {
            self.engine.update(c);
            for (sum, &r) in self.rating_sum.iter_mut().zip(self.engine.ratings()) {
                *sum += r;
            }
            self.samples += 1;
        }
        self.history_len += new_records.len();
    }

    pub fn history_len(&self) -> usize {
        self.history_len
    }

    /// Trajectory-averaged ratings (the scores Eagle uses).
    pub fn ratings(&self) -> Vec<f64> {
        if self.samples == 0 {
            return self.engine.ratings().to_vec();
        }
        self.rating_sum.iter().map(|s| s / self.samples as f64).collect()
    }

    /// Last-iterate (sequential) ratings — exposed for the averaging
    /// ablation and diagnostics.
    pub fn last_iterate(&self) -> &[f64] {
        self.engine.ratings()
    }

    pub fn engine(&self) -> &EloEngine {
        &self.engine
    }

    /// Models sorted by averaged rating, best first.
    pub fn ranking(&self) -> Vec<usize> {
        let ratings = self.ratings();
        let mut idx: Vec<usize> = (0..ratings.len()).collect();
        idx.sort_by(|&i, &j| ratings[j].partial_cmp(&ratings[i]).unwrap().then(i.cmp(&j)));
        idx
    }
}

/// Convert named pairwise records to dense [`Comparison`]s given a
/// name -> index map (used by dataset loaders and the server).
pub fn to_dense(
    records: &[(String, String, Outcome)],
    index: &HashMap<String, usize>,
) -> Result<Vec<Comparison>, String> {
    records
        .iter()
        .map(|(a, b, o)| {
            let ia = *index.get(a).ok_or_else(|| format!("unknown model '{a}'"))?;
            let ib = *index.get(b).ok_or_else(|| format!("unknown model '{b}'"))?;
            Ok(Comparison { a: ia, b: ib, outcome: *o })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    fn rand_cmp(rng: &mut Rng, n: usize) -> Comparison {
        let a = rng.below(n);
        let mut b = rng.below(n - 1);
        if b >= a {
            b += 1;
        }
        let outcome = match rng.below(3) {
            0 => Outcome::WinA,
            1 => Outcome::WinB,
            _ => Outcome::Draw,
        };
        Comparison { a, b, outcome }
    }

    #[test]
    fn export_state_resumes_bit_identically() {
        // the durable checkpoint contract: a table rebuilt from its full
        // exported state folds future comparisons bit-identically to one
        // that never stopped — averaged ratings, last iterate, history
        prop::check("from_state(export_state) == uninterrupted", 40, |rng| {
            let n = 2 + rng.below(6);
            let mut live = GlobalElo::new(n, 32.0);
            for _ in 0..rng.below(200) {
                live.apply_new(&[rand_cmp(rng, n)]);
            }
            let mut resumed = GlobalElo::from_state(live.export_state(), 32.0);
            for _ in 0..rng.below(100) {
                let c = rand_cmp(rng, n);
                live.apply_new(&[c]);
                resumed.apply_new(&[c]);
            }
            prop::assert_prop(resumed.ratings() == live.ratings(), "averaged ratings")?;
            prop::assert_prop(
                resumed.last_iterate() == live.last_iterate(),
                "last iterate",
            )?;
            prop::assert_prop(
                resumed.history_len() == live.history_len(),
                "history length",
            )?;
            prop::assert_prop(
                resumed.export_state() == live.export_state(),
                "exported state",
            )
        });
    }

    #[test]
    fn expected_score_symmetry() {
        prop::check("E(a,b) + E(b,a) = 1", 200, |rng| {
            let ra = rng.range_f64(0.0, 3000.0);
            let rb = rng.range_f64(0.0, 3000.0);
            prop::assert_close(
                expected_score(ra, rb) + expected_score(rb, ra),
                1.0,
                1e-12,
                "symmetry",
            )
        });
    }

    #[test]
    fn expected_score_equal_ratings() {
        assert!((expected_score(1000.0, 1000.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn expected_score_400_gap_is_10x() {
        // A 400-point gap means 10:1 odds: E = 10/11.
        let e = expected_score(1400.0, 1000.0);
        assert!((e - 10.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn update_win_raises_loser_drops() {
        let mut e = EloEngine::new(2, DEFAULT_K);
        e.update(Comparison { a: 0, b: 1, outcome: Outcome::WinA });
        assert!(e.rating(0) > INITIAL_RATING);
        assert!(e.rating(1) < INITIAL_RATING);
        // equal ratings, K=32: delta is exactly 16
        assert!((e.rating(0) - 1016.0).abs() < 1e-12);
    }

    #[test]
    fn draw_between_equals_is_noop() {
        let mut e = EloEngine::new(2, DEFAULT_K);
        e.update(Comparison { a: 0, b: 1, outcome: Outcome::Draw });
        assert_eq!(e.rating(0), INITIAL_RATING);
        assert_eq!(e.rating(1), INITIAL_RATING);
    }

    #[test]
    fn rating_sum_conserved() {
        prop::check("total rating conserved", 100, |rng| {
            let n = 2 + rng.below(10);
            let mut e = EloEngine::new(n, DEFAULT_K);
            let before = e.total_rating();
            for _ in 0..200 {
                e.update(rand_cmp(rng, n));
            }
            prop::assert_close(e.total_rating(), before, 1e-6, "conservation")
        });
    }

    #[test]
    fn stronger_model_ranks_higher() {
        // model 0 beats model 1 80% of the time -> must rank above it.
        let mut rng = Rng::new(42);
        let mut e = EloEngine::new(2, DEFAULT_K);
        for _ in 0..500 {
            let outcome = if rng.chance(0.8) { Outcome::WinA } else { Outcome::WinB };
            e.update(Comparison { a: 0, b: 1, outcome });
        }
        assert_eq!(e.ranking(), vec![0, 1]);
        assert!(e.rating(0) - e.rating(1) > 100.0);
    }

    #[test]
    fn transitive_strength_recovered() {
        // latent order 0 > 1 > 2 with noisy outcomes.
        let mut rng = Rng::new(7);
        let strength = [3.0f64, 1.5, 0.0];
        let mut e = EloEngine::new(3, DEFAULT_K);
        for _ in 0..3000 {
            let c = rand_cmp(&mut rng, 3);
            let pa = 1.0 / (1.0 + (-(strength[c.a] - strength[c.b])).exp());
            let outcome = if rng.chance(pa) { Outcome::WinA } else { Outcome::WinB };
            e.update(Comparison { outcome, ..c });
        }
        assert_eq!(e.ranking(), vec![0, 1, 2]);
    }

    #[test]
    fn flipped_comparison_equivalent() {
        prop::check("a-vs-b == b-vs-a flipped", 100, |rng| {
            let c = rand_cmp(rng, 5);
            let mut e1 = EloEngine::new(5, DEFAULT_K);
            let mut e2 = EloEngine::new(5, DEFAULT_K);
            e1.update(c);
            e2.update(Comparison { a: c.b, b: c.a, outcome: c.outcome.flipped() });
            for m in 0..5 {
                prop::assert_close(e1.rating(m), e2.rating(m), 1e-12, "flip")?;
            }
            Ok(())
        });
    }

    #[test]
    fn incremental_equals_full_replay() {
        // The property behind Table 3a: applying new records to an existing
        // global engine is identical to replaying the concatenated history.
        prop::check("incremental == replay", 50, |rng| {
            let n = 3 + rng.below(6);
            let hist: Vec<Comparison> = (0..300).map(|_| rand_cmp(rng, n)).collect();
            let (old, new) = hist.split_at(200);

            let mut incremental = GlobalElo::initialize(n, DEFAULT_K, old);
            incremental.apply_new(new);

            let full = GlobalElo::initialize(n, DEFAULT_K, &hist);
            for m in 0..n {
                prop::assert_close(
                    incremental.ratings()[m],
                    full.ratings()[m],
                    1e-9,
                    "ratings",
                )?;
            }
            prop::assert_prop(incremental.history_len() == 300, "history len")
        });
    }

    #[test]
    fn reseed_resets_to_given_ratings() {
        let mut e = EloEngine::new(3, DEFAULT_K);
        e.update(Comparison { a: 0, b: 1, outcome: Outcome::WinA });
        let seed = vec![900.0, 1100.0, 1000.0];
        e.reseed_from(&seed);
        assert_eq!(e.ratings(), seed.as_slice());
        assert_eq!(e.updates(), 0);
    }

    #[test]
    fn k_scales_adjustment() {
        let mut lo = EloEngine::new(2, 16.0);
        let mut hi = EloEngine::new(2, 64.0);
        let c = Comparison { a: 0, b: 1, outcome: Outcome::WinA };
        lo.update(c);
        hi.update(c);
        let d_lo = lo.rating(0) - INITIAL_RATING;
        let d_hi = hi.rating(0) - INITIAL_RATING;
        assert!((d_hi / d_lo - 4.0).abs() < 1e-9);
    }

    #[test]
    fn ranking_deterministic_ties() {
        let e = EloEngine::new(4, DEFAULT_K);
        assert_eq!(e.ranking(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn outcome_encode_decode() {
        for o in [Outcome::WinA, Outcome::WinB, Outcome::Draw] {
            assert_eq!(Outcome::decode(o.encode()), Some(o));
        }
        assert_eq!(Outcome::decode(0.3), None);
    }

    #[test]
    fn prop_decode_encode_roundtrips() {
        // decode ∘ encode ≡ id on outcomes; everything else decodes to None
        prop::check("decode(encode(o)) == o", 100, |rng| {
            let o = [Outcome::WinA, Outcome::WinB, Outcome::Draw][rng.below(3)];
            prop::assert_prop(Outcome::decode(o.encode()) == Some(o), "roundtrip")?;
            let junk = rng.f64();
            if junk != 0.0 && junk != 0.5 && junk != 1.0 {
                prop::assert_prop(
                    Outcome::decode(junk).is_none(),
                    "non-score decoded to an outcome",
                )?;
            }
            prop::assert_prop(Outcome::decode(f64::NAN).is_none(), "NaN decoded")?;
            prop::assert_prop(Outcome::decode(-1.0).is_none(), "negative decoded")
        });
    }

    #[test]
    fn prop_updates_zero_sum_for_non_draw() {
        // a non-draw update transfers rating: the winner's gain equals the
        // loser's loss (one shared delta), every bystander is untouched,
        // and the transfer is strictly nonzero
        prop::check("non-draw updates are zero-sum", 200, |rng| {
            let n = 2 + rng.below(8);
            let mut e = EloEngine::new(n, DEFAULT_K);
            // randomize the table first so ratings are unequal
            for _ in 0..rng.below(100) {
                e.update(rand_cmp(rng, n));
            }
            let before = e.ratings().to_vec();
            let mut c = rand_cmp(rng, n);
            c.outcome = if rng.chance(0.5) { Outcome::WinA } else { Outcome::WinB };
            e.update(c);
            let delta_a = e.rating(c.a) - before[c.a];
            let delta_b = e.rating(c.b) - before[c.b];
            prop::assert_prop(delta_a != 0.0 && delta_b != 0.0, "no transfer happened")?;
            let (winner_delta, loser_delta) = match c.outcome {
                Outcome::WinA => (delta_a, delta_b),
                _ => (delta_b, delta_a),
            };
            prop::assert_prop(winner_delta > 0.0, "winner did not gain")?;
            prop::assert_prop(loser_delta < 0.0, "loser did not lose")?;
            prop::assert_close(delta_a + delta_b, 0.0, 1e-9, "zero-sum")?;
            for m in 0..n {
                if m != c.a && m != c.b {
                    prop::assert_prop(e.rating(m) == before[m], "bystander moved")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_incremental_update_equals_full_replay_any_split() {
        // Table 3a's foundation, over arbitrary split points (the fixed
        // 200/100 split lives in incremental_equals_full_replay)
        prop::check("incremental == replay at any split", 40, |rng| {
            let n = 2 + rng.below(8);
            let len = 1 + rng.below(400);
            let hist: Vec<Comparison> = (0..len).map(|_| rand_cmp(rng, n)).collect();
            let cut = rng.below(len + 1);

            let mut incremental = GlobalElo::initialize(n, DEFAULT_K, &hist[..cut]);
            incremental.apply_new(&hist[cut..]);
            let full = GlobalElo::initialize(n, DEFAULT_K, &hist);

            for m in 0..n {
                prop::assert_close(
                    incremental.ratings()[m],
                    full.ratings()[m],
                    1e-9,
                    "averaged ratings",
                )?;
                prop::assert_close(
                    incremental.last_iterate()[m],
                    full.last_iterate()[m],
                    1e-9,
                    "last iterate",
                )?;
            }
            prop::assert_prop(incremental.history_len() == len, "history length")
        });
    }

    #[test]
    fn to_dense_maps_names() {
        let mut index = HashMap::new();
        index.insert("gpt".to_string(), 0);
        index.insert("claude".to_string(), 1);
        let recs = vec![("gpt".to_string(), "claude".to_string(), Outcome::WinB)];
        let dense = to_dense(&recs, &index).unwrap();
        assert_eq!(dense[0], Comparison { a: 0, b: 1, outcome: Outcome::WinB });
        let bad = vec![("nope".to_string(), "claude".to_string(), Outcome::Draw)];
        assert!(to_dense(&bad, &index).is_err());
    }
}
