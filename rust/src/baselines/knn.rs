//! KNN quality predictor: the paper's KNN baseline (Appendix A.2 —
//! 40 nearest neighbors, cosine similarity).
//!
//! Predicted quality of model j for query q = mean quality_j over the 40
//! nearest training prompts. `fit` stores the data (like sklearn's brute
//! KNeighborsRegressor); prediction pays the scan.

use super::{QualityPredictor, TrainSet};
use crate::vectordb::topk::TopK;

/// KNN regressor over cosine similarity.
pub struct KnnPredictor {
    k: usize,
    data: Option<TrainSet>,
    /// Per-model observed-label means (fallback when no labelled neighbor).
    means: Vec<f64>,
}

impl KnnPredictor {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        KnnPredictor { k, data: None, means: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.data.as_ref().map_or(0, |d| d.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl QualityPredictor for KnnPredictor {
    fn name(&self) -> &'static str {
        "knn"
    }

    fn fit(&mut self, data: &TrainSet) {
        self.data = Some(data.clone());
        self.means = data.label_means();
    }

    fn update(&mut self, new_data: &TrainSet) {
        // sklearn-equivalent online behavior: concatenate and "refit"
        // (refit for brute KNN == restage the data).
        match &mut self.data {
            Some(d) => d.extend(new_data),
            None => self.data = Some(new_data.clone()),
        }
        self.means = self.data.as_ref().unwrap().label_means();
    }

    fn predict(&self, query: &[f32]) -> Vec<f64> {
        let Some(data) = &self.data else {
            return Vec::new();
        };
        let n_models = data.n_models();
        if data.is_empty() {
            return vec![0.5; n_models];
        }
        let dot = crate::vectordb::kernel::dot_fn();
        let mut topk = TopK::new(self.k);
        for i in 0..data.len() {
            topk.push(i as u32, dot(data.embeddings.row(i), query));
        }
        let hits = topk.into_sorted();
        let mut out = vec![0.0f64; n_models];
        let mut counts = vec![0.0f64; n_models];
        for (id, _) in &hits {
            let q = data.qualities.row(*id as usize);
            let m = data.mask.row(*id as usize);
            for j in 0..n_models {
                out[j] += (m[j] * q[j]) as f64;
                counts[j] += m[j] as f64;
            }
        }
        for j in 0..n_models {
            out[j] = if counts[j] > 0.0 {
                out[j] / counts[j]
            } else {
                // no labelled neighbor for this model: global label mean
                self.means.get(j).copied().unwrap_or(0.5)
            };
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::synthetic_regression;
    use super::*;
    use crate::util::Rng;

    #[test]
    fn predicts_neighbor_average() {
        let data = TrainSet::new(
            super::super::linalg::Matrix::from_rows(&[
                vec![1.0, 0.0],
                vec![0.99, 0.1],
                vec![0.0, 1.0],
            ]),
            super::super::linalg::Matrix::from_rows(&[
                vec![1.0],
                vec![0.8],
                vec![0.0],
            ]),
        );
        let mut knn = KnnPredictor::new(2);
        knn.fit(&data);
        // query along x: neighbors are rows 0,1 -> mean 0.9
        let p = knn.predict(&[1.0, 0.0]);
        assert!((p[0] - 0.9).abs() < 1e-6, "{p:?}");
    }

    #[test]
    fn k_larger_than_data_uses_all() {
        let data = TrainSet::new(
            super::super::linalg::Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]),
            super::super::linalg::Matrix::from_rows(&[vec![1.0], vec![0.0]]),
        );
        let mut knn = KnnPredictor::new(40);
        knn.fit(&data);
        let p = knn.predict(&[0.7, 0.7]);
        assert!((p[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn update_appends() {
        let (a, _) = synthetic_regression(&mut Rng::new(1), 30, 8, 2);
        let (b, _) = synthetic_regression(&mut Rng::new(2), 20, 8, 2);
        let mut knn = KnnPredictor::new(5);
        knn.fit(&a);
        assert_eq!(knn.len(), 30);
        knn.update(&b);
        assert_eq!(knn.len(), 50);
    }

    #[test]
    fn update_without_fit_works() {
        let (a, _) = synthetic_regression(&mut Rng::new(3), 10, 8, 2);
        let mut knn = KnnPredictor::new(5);
        knn.update(&a);
        assert_eq!(knn.len(), 10);
    }

    #[test]
    fn empty_predictor_returns_empty() {
        let knn = KnnPredictor::new(5);
        assert!(knn.predict(&[1.0, 0.0]).is_empty());
    }

    #[test]
    fn learns_synthetic_task_reasonably() {
        let mut rng = Rng::new(7);
        let (all, _) = synthetic_regression(&mut rng, 700, 16, 3);
        let (train, test) = (all.prefix(600), all.suffix(600));
        let mut knn = KnnPredictor::new(40);
        knn.fit(&train);
        // KNN on smooth sigmoid targets: better than predicting the mean
        let mse = knn.mse(&test);
        assert!(mse < 0.08, "mse = {mse}");
    }
}
