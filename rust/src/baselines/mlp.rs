//! MLP quality predictor: the paper's MLP baseline (Appendix A.2 — two
//! layers, hidden 100, ReLU), trained with Adam on MSE. Equivalent of
//! sklearn's `MLPRegressor(hidden_layer_sizes=(100,), activation="relu")`.
//!
//! `update` follows the retraining-based protocol: append + full refit —
//! the cost Table 3a measures.

use super::linalg::Matrix;
use super::{QualityPredictor, TrainSet};
use crate::util::Rng;

/// Training hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct MlpOptions {
    pub hidden: usize,
    pub epochs: usize,
    pub lr: f64,
    pub batch_size: usize,
    pub seed: u64,
}

impl Default for MlpOptions {
    fn default() -> Self {
        MlpOptions { hidden: 100, epochs: 60, lr: 1e-3, batch_size: 64, seed: 0x317 }
    }
}

/// Adam state for one parameter tensor.
struct Adam {
    m: Matrix,
    v: Matrix,
    t: i32,
}

impl Adam {
    fn new(rows: usize, cols: usize) -> Self {
        Adam { m: Matrix::zeros(rows, cols), v: Matrix::zeros(rows, cols), t: 0 }
    }

    fn step(&mut self, w: &mut Matrix, grad: &Matrix, lr: f64) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t);
        let bc2 = 1.0 - B2.powi(self.t);
        for i in 0..w.data.len() {
            let g = grad.data[i] as f64;
            let m = B1 * self.m.data[i] as f64 + (1.0 - B1) * g;
            let v = B2 * self.v.data[i] as f64 + (1.0 - B2) * g * g;
            self.m.data[i] = m as f32;
            self.v.data[i] = v as f32;
            let mhat = m / bc1;
            let vhat = v / bc2;
            w.data[i] -= (lr * mhat / (vhat.sqrt() + EPS)) as f32;
        }
    }
}

/// Two-layer MLP: x -> ReLU(x W1 + b1) -> W2 + b2.
pub struct MlpPredictor {
    opts: MlpOptions,
    w1: Matrix,
    b1: Matrix,
    w2: Matrix,
    b2: Matrix,
    data: Option<TrainSet>,
    fitted: bool,
    /// Final training loss of the last fit (diagnostics).
    pub last_loss: f64,
}

impl MlpPredictor {
    pub fn new(opts: MlpOptions) -> Self {
        MlpPredictor {
            opts,
            w1: Matrix::zeros(1, 1),
            b1: Matrix::zeros(1, 1),
            w2: Matrix::zeros(1, 1),
            b2: Matrix::zeros(1, 1),
            data: None,
            fitted: false,
            last_loss: f64::NAN,
        }
    }

    fn init(&mut self, in_dim: usize, out_dim: usize) {
        let mut rng = Rng::new(self.opts.seed);
        let h = self.opts.hidden;
        // He-style init for ReLU
        let s1 = (2.0f32 / in_dim as f32).sqrt();
        let s2 = (2.0f32 / h as f32).sqrt();
        self.w1 = Matrix::random(in_dim, h, s1, &mut rng);
        self.b1 = Matrix::zeros(1, h);
        self.w2 = Matrix::random(h, out_dim, s2, &mut rng);
        self.b2 = Matrix::zeros(1, out_dim);
    }

    /// Forward pass for a batch; returns (hidden-post-relu, output).
    fn forward(&self, x: &Matrix) -> (Matrix, Matrix) {
        let mut h = x.matmul(&self.w1);
        for i in 0..h.rows {
            let b = &self.b1.data;
            let row = h.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                *r = (*r + b[j]).max(0.0); // bias + ReLU
            }
        }
        let mut y = h.matmul(&self.w2);
        for i in 0..y.rows {
            let b = &self.b2.data;
            let row = y.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                *r += b[j];
            }
        }
        (h, y)
    }

    fn train(&mut self) {
        let Some(data) = self.data.clone() else { return };
        if data.is_empty() {
            return;
        }
        let (n, in_dim, out_dim) = (data.len(), data.embeddings.cols, data.n_models());
        self.init(in_dim, out_dim);
        let mut a_w1 = Adam::new(in_dim, self.opts.hidden);
        let mut a_b1 = Adam::new(1, self.opts.hidden);
        let mut a_w2 = Adam::new(self.opts.hidden, out_dim);
        let mut a_b2 = Adam::new(1, out_dim);

        let mut rng = Rng::new(self.opts.seed ^ 0xAD);
        let mut order: Vec<usize> = (0..n).collect();
        let bs = self.opts.batch_size.min(n).max(1);

        for _epoch in 0..self.opts.epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0f64;
            let mut batches = 0usize;
            for chunk in order.chunks(bs) {
                // gather batch
                let xb = Matrix::from_rows(
                    &chunk.iter().map(|&i| data.embeddings.row(i).to_vec()).collect::<Vec<_>>(),
                );
                let yb = Matrix::from_rows(
                    &chunk.iter().map(|&i| data.qualities.row(i).to_vec()).collect::<Vec<_>>(),
                );
                let mb = Matrix::from_rows(
                    &chunk.iter().map(|&i| data.mask.row(i).to_vec()).collect::<Vec<_>>(),
                );
                let (h, y) = self.forward(&xb);
                // masked MSE: dL/dy = 2 m (y - t) / sum(m)
                let labelled: f32 = mb.data.iter().sum::<f32>().max(1.0);
                let scale = 2.0 / labelled;
                let mut dy = y.clone();
                dy.axpy(-1.0, &yb);
                for (d, &m) in dy.data.iter_mut().zip(&mb.data) {
                    *d *= m;
                }
                let mse: f64 = dy.data.iter().map(|d| (*d as f64) * (*d as f64)).sum::<f64>()
                    / labelled as f64;
                epoch_loss += mse;
                batches += 1;
                for d in &mut dy.data {
                    *d *= scale;
                }
                // grads
                let g_w2 = h.t_matmul(&dy);
                let mut g_b2 = Matrix::zeros(1, out_dim);
                for i in 0..dy.rows {
                    for j in 0..out_dim {
                        g_b2.data[j] += dy.at(i, j);
                    }
                }
                let mut dh = dy.matmul_t(&self.w2); // [b, hidden]
                for i in 0..dh.rows {
                    for j in 0..dh.cols {
                        if h.at(i, j) <= 0.0 {
                            *dh.at_mut(i, j) = 0.0; // ReLU mask
                        }
                    }
                }
                let g_w1 = xb.t_matmul(&dh);
                let mut g_b1 = Matrix::zeros(1, self.opts.hidden);
                for i in 0..dh.rows {
                    for j in 0..self.opts.hidden {
                        g_b1.data[j] += dh.at(i, j);
                    }
                }
                a_w1.step(&mut self.w1, &g_w1, self.opts.lr);
                a_b1.step(&mut self.b1, &g_b1, self.opts.lr);
                a_w2.step(&mut self.w2, &g_w2, self.opts.lr);
                a_b2.step(&mut self.b2, &g_b2, self.opts.lr);
            }
            self.last_loss = epoch_loss / batches.max(1) as f64;
        }
        self.fitted = true;
    }
}

impl QualityPredictor for MlpPredictor {
    fn name(&self) -> &'static str {
        "mlp"
    }

    fn fit(&mut self, data: &TrainSet) {
        self.data = Some(data.clone());
        self.train();
    }

    fn update(&mut self, new_data: &TrainSet) {
        match &mut self.data {
            Some(d) => d.extend(new_data),
            None => self.data = Some(new_data.clone()),
        }
        self.train(); // full refit: the paper's retraining cost
    }

    fn predict(&self, query: &[f32]) -> Vec<f64> {
        if !self.fitted {
            return Vec::new();
        }
        let x = Matrix::from_rows(&[query.to_vec()]);
        let (_, y) = self.forward(&x);
        y.row(0).iter().map(|&v| v as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::synthetic_regression;
    use super::*;

    fn quick_opts() -> MlpOptions {
        MlpOptions { hidden: 32, epochs: 40, lr: 3e-3, batch_size: 32, seed: 5 }
    }

    #[test]
    fn learns_synthetic_task() {
        let mut rng = Rng::new(11);
        let (all, _) = synthetic_regression(&mut rng, 500, 16, 3);
        let (train, test) = (all.prefix(400), all.suffix(400));
        let mut mlp = MlpPredictor::new(quick_opts());
        mlp.fit(&train);
        let mse = mlp.mse(&test);
        assert!(mse < 0.02, "mse = {mse}");
    }

    #[test]
    fn loss_decreases_during_training() {
        let mut rng = Rng::new(13);
        let (train, _) = synthetic_regression(&mut rng, 200, 8, 2);
        let mut one = MlpPredictor::new(MlpOptions { epochs: 1, ..quick_opts() });
        one.fit(&train);
        let early = one.last_loss;
        let mut many = MlpPredictor::new(MlpOptions { epochs: 40, ..quick_opts() });
        many.fit(&train);
        assert!(many.last_loss < early, "{} !< {early}", many.last_loss);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(17);
        let (train, _) = synthetic_regression(&mut rng, 100, 8, 2);
        let mut a = MlpPredictor::new(quick_opts());
        let mut b = MlpPredictor::new(quick_opts());
        a.fit(&train);
        b.fit(&train);
        assert_eq!(a.predict(train.embeddings.row(0)), b.predict(train.embeddings.row(0)));
    }

    #[test]
    fn unfitted_returns_empty() {
        let mlp = MlpPredictor::new(quick_opts());
        assert!(mlp.predict(&[0.0; 8]).is_empty());
    }

    #[test]
    fn update_refits_on_union() {
        let mut rng = Rng::new(19);
        let (a, _) = synthetic_regression(&mut rng, 50, 8, 2);
        let (b, _) = synthetic_regression(&mut rng, 50, 8, 2);
        let mut m = MlpPredictor::new(quick_opts());
        m.fit(&a);
        m.update(&b);
        assert_eq!(m.data.as_ref().unwrap().len(), 100);
        assert!(m.fitted);
    }

    #[test]
    fn output_dim_matches_models() {
        let mut rng = Rng::new(23);
        let (train, _) = synthetic_regression(&mut rng, 60, 8, 5);
        let mut m = MlpPredictor::new(quick_opts());
        m.fit(&train);
        assert_eq!(m.predict(train.embeddings.row(3)).len(), 5);
    }
}
