//! Minimal dense linear algebra for the baseline trainers.
//!
//! Row-major f32 matrices with the handful of kernels MLP/SVM training
//! needs: GEMM (ikj loop order, 4-wide inner unrolling via the vectordb dot
//! kernel), GEMV, transpose-GEMM, axpy. Sizes here are small (batch x 256
//! x 100), so clarity beats blocking.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty(), "empty matrix");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Matrix { rows: rows.len(), cols, data }
    }

    /// Seeded uniform init in [-scale, scale].
    pub fn random(rows: usize, cols: usize, scale: f32, rng: &mut crate::util::Rng) -> Self {
        let data = (0..rows * cols).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    /// C = A @ B  (A: m x k, B: k x n).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let c_row = c.row_mut(i);
            for p in 0..k {
                let a = a_row[p];
                if a == 0.0 {
                    continue;
                }
                let b_row = b.row(p);
                for j in 0..n {
                    c_row[j] += a * b_row[j];
                }
            }
        }
        c
    }

    /// C = Aᵀ @ B  (A: k x m, B: k x n) — gradient accumulation shape.
    pub fn t_matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "t_matmul shape");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut c = Matrix::zeros(m, n);
        for p in 0..k {
            let a_row = self.row(p);
            let b_row = b.row(p);
            for i in 0..m {
                let a = a_row[i];
                if a == 0.0 {
                    continue;
                }
                let c_row = c.row_mut(i);
                for j in 0..n {
                    c_row[j] += a * b_row[j];
                }
            }
        }
        c
    }

    /// C = A @ Bᵀ  (A: m x k, B: n x k) — backprop through weights shape.
    pub fn matmul_t(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_t shape");
        let dot = crate::vectordb::kernel::dot_fn();
        let (m, n) = (self.rows, b.rows);
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let c_row = c.row_mut(i);
            for (j, cj) in c_row.iter_mut().enumerate() {
                *cj = dot(a_row, b.row(j));
            }
        }
        c
    }

    /// self += alpha * other (elementwise).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.data.len(), other.data.len(), "axpy shape");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

/// y += alpha * x for vectors.
pub fn vec_axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn rand_m(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::random(r, c, 1.0, rng)
    }

    fn close(a: &Matrix, b: &Matrix, tol: f32) -> bool {
        a.rows == b.rows
            && a.cols == b.cols
            && a.data.iter().zip(&b.data).all(|(x, y)| (x - y).abs() < tol)
    }

    #[test]
    fn matmul_matches_naive() {
        prop::check("matmul == naive", 50, |rng| {
            let (m, k, n) = (1 + rng.below(8), 1 + rng.below(8), 1 + rng.below(8));
            let a = rand_m(rng, m, k);
            let b = rand_m(rng, k, n);
            prop::assert_prop(close(&a.matmul(&b), &naive_matmul(&a, &b), 1e-4), "matmul")
        });
    }

    #[test]
    fn t_matmul_matches_transpose() {
        prop::check("t_matmul", 50, |rng| {
            let (k, m, n) = (1 + rng.below(8), 1 + rng.below(8), 1 + rng.below(8));
            let a = rand_m(rng, k, m);
            let b = rand_m(rng, k, n);
            // naive: transpose a then matmul
            let mut at = Matrix::zeros(m, k);
            for i in 0..k {
                for j in 0..m {
                    *at.at_mut(j, i) = a.at(i, j);
                }
            }
            prop::assert_prop(close(&a.t_matmul(&b), &naive_matmul(&at, &b), 1e-4), "t_matmul")
        });
    }

    #[test]
    fn matmul_t_matches_transpose() {
        prop::check("matmul_t", 50, |rng| {
            let (m, k, n) = (1 + rng.below(8), 1 + rng.below(8), 1 + rng.below(8));
            let a = rand_m(rng, m, k);
            let b = rand_m(rng, n, k);
            let mut bt = Matrix::zeros(k, n);
            for i in 0..n {
                for j in 0..k {
                    *bt.at_mut(j, i) = b.at(i, j);
                }
            }
            prop::assert_prop(close(&a.matmul_t(&b), &naive_matmul(&a, &bt), 1e-4), "matmul_t")
        });
    }

    #[test]
    fn axpy_and_frob() {
        let mut a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 5.0, 6.0]);
        assert!((Matrix::from_rows(&[vec![3.0, 4.0]]).frob() - 5.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "matmul shape")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    #[should_panic(expected = "ragged rows")]
    fn ragged_rows_panics() {
        let _ = Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]);
    }
}
