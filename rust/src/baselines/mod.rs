//! Baseline routers from the paper's evaluation (RouterBench's regression
//! formulation, Appendix A.2): predict each model's response quality for a
//! query embedding, then route under the budget policy.
//!
//! - [`knn::KnnPredictor`] — 40-NN cosine average (sklearn
//!   `KNeighborsRegressor` equivalent).
//! - [`mlp::MlpPredictor`] — 2-layer MLP, hidden 100, ReLU, Adam on MSE
//!   (sklearn `MLPRegressor` equivalent).
//! - [`svm::SvmPredictor`] — per-model LinearSVR, epsilon-insensitive loss
//!   with eps = 0, SGD (sklearn `LinearSVR` equivalent).
//!
//! All three are **retraining-based**: their [`QualityPredictor::update`]
//! appends the new data and refits from scratch — exactly the cost the
//! paper's Table 3a charges them for online adaptation. Eagle's update is
//! incremental (see [`crate::coordinator`]).

pub mod knn;
pub mod linalg;
pub mod mlp;
pub mod svm;

use linalg::Matrix;

/// A labelled training set: one embedding row per prompt, one quality row
/// per prompt (columns = models), and a label mask.
///
/// Two supervision modes (DESIGN.md §Evaluation-protocol):
/// - **full labels** (`mask` all ones): RouterBench's offline formulation —
///   every (prompt, model) quality is observed;
/// - **feedback labels** (sparse `mask`): the paper's online setting — only
///   the models actually compared on a prompt carry labels (win=1, loss=0,
///   draw=0.5), everything else is unobserved. This is the same
///   information Eagle's ELO consumes.
#[derive(Debug, Clone)]
pub struct TrainSet {
    pub embeddings: Matrix,
    pub qualities: Matrix,
    /// 1.0 where `qualities` is observed, 0.0 where missing.
    pub mask: Matrix,
}

impl TrainSet {
    pub fn new(embeddings: Matrix, qualities: Matrix) -> Self {
        assert_eq!(embeddings.rows, qualities.rows, "row count mismatch");
        let mask = Matrix {
            rows: qualities.rows,
            cols: qualities.cols,
            data: vec![1.0; qualities.rows * qualities.cols],
        };
        TrainSet { embeddings, qualities, mask }
    }

    pub fn new_masked(embeddings: Matrix, qualities: Matrix, mask: Matrix) -> Self {
        assert_eq!(embeddings.rows, qualities.rows, "row count mismatch");
        assert_eq!(qualities.rows, mask.rows, "mask rows");
        assert_eq!(qualities.cols, mask.cols, "mask cols");
        TrainSet { embeddings, qualities, mask }
    }

    /// Column means over observed labels (0.5 for never-observed models).
    pub fn label_means(&self) -> Vec<f64> {
        let m = self.n_models();
        let mut sums = vec![0.0f64; m];
        let mut counts = vec![0.0f64; m];
        for i in 0..self.len() {
            for j in 0..m {
                let w = self.mask.at(i, j) as f64;
                sums[j] += w * self.qualities.at(i, j) as f64;
                counts[j] += w;
            }
        }
        sums.iter()
            .zip(&counts)
            .map(|(s, c)| if *c > 0.0 { s / c } else { 0.5 })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.embeddings.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn n_models(&self) -> usize {
        self.qualities.cols
    }

    /// Concatenate another set (same dims) onto this one.
    pub fn extend(&mut self, other: &TrainSet) {
        assert_eq!(self.embeddings.cols, other.embeddings.cols);
        assert_eq!(self.qualities.cols, other.qualities.cols);
        self.embeddings.data.extend_from_slice(&other.embeddings.data);
        self.embeddings.rows += other.embeddings.rows;
        self.qualities.data.extend_from_slice(&other.qualities.data);
        self.qualities.rows += other.qualities.rows;
        self.mask.data.extend_from_slice(&other.mask.data);
        self.mask.rows += other.mask.rows;
    }

    /// Rows [n..] as a copy (held-out remainder).
    pub fn suffix(&self, n: usize) -> TrainSet {
        let n = n.min(self.len());
        TrainSet {
            embeddings: Matrix {
                rows: self.len() - n,
                cols: self.embeddings.cols,
                data: self.embeddings.data[n * self.embeddings.cols..].to_vec(),
            },
            qualities: Matrix {
                rows: self.len() - n,
                cols: self.qualities.cols,
                data: self.qualities.data[n * self.qualities.cols..].to_vec(),
            },
            mask: Matrix {
                rows: self.len() - n,
                cols: self.mask.cols,
                data: self.mask.data[n * self.mask.cols..].to_vec(),
            },
        }
    }

    /// First `n` rows as a view-copy (stage prefixes for Fig 3b).
    pub fn prefix(&self, n: usize) -> TrainSet {
        let n = n.min(self.len());
        TrainSet {
            embeddings: Matrix {
                rows: n,
                cols: self.embeddings.cols,
                data: self.embeddings.data[..n * self.embeddings.cols].to_vec(),
            },
            qualities: Matrix {
                rows: n,
                cols: self.qualities.cols,
                data: self.qualities.data[..n * self.qualities.cols].to_vec(),
            },
            mask: Matrix {
                rows: n,
                cols: self.mask.cols,
                data: self.mask.data[..n * self.mask.cols].to_vec(),
            },
        }
    }
}

/// Per-model quality prediction interface shared by the three baselines.
pub trait QualityPredictor {
    fn name(&self) -> &'static str;

    /// Fit from scratch on `data`.
    fn fit(&mut self, data: &TrainSet);

    /// Online adaptation: baselines append + refit (full retraining cost).
    fn update(&mut self, new_data: &TrainSet);

    /// Predicted quality per model for one query embedding.
    fn predict(&self, query: &[f32]) -> Vec<f64>;

    /// Mean squared error over a labelled set (diagnostics).
    fn mse(&self, data: &TrainSet) -> f64 {
        let mut se = 0.0;
        let mut n = 0usize;
        for i in 0..data.len() {
            let pred = self.predict(data.embeddings.row(i));
            for (j, p) in pred.iter().enumerate() {
                let d = p - data.qualities.at(i, j) as f64;
                se += d * d;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            se / n as f64
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    /// A learnable synthetic task: quality_j(x) = sigmoid(w_j . x).
    pub fn synthetic_regression(
        rng: &mut Rng,
        n: usize,
        dim: usize,
        n_models: usize,
    ) -> (TrainSet, Vec<Vec<f32>>) {
        let w: Vec<Vec<f32>> = (0..n_models)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut emb = Vec::with_capacity(n);
        let mut qual = Vec::with_capacity(n);
        for _ in 0..n {
            let mut x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            crate::util::l2_normalize(&mut x);
            let q: Vec<f32> = w
                .iter()
                .map(|wj| {
                    let s: f32 = wj.iter().zip(&x).map(|(a, b)| a * b).sum();
                    1.0 / (1.0 + (-2.0 * s).exp())
                })
                .collect();
            emb.push(x);
            qual.push(q);
        }
        (
            TrainSet::new(Matrix::from_rows(&emb), Matrix::from_rows(&qual)),
            w,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trainset_extend_and_prefix() {
        let a = TrainSet::new(
            Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]),
            Matrix::from_rows(&[vec![0.5], vec![0.7]]),
        );
        let mut ab = a.clone();
        ab.extend(&a);
        assert_eq!(ab.len(), 4);
        assert_eq!(ab.n_models(), 1);
        let p = ab.prefix(3);
        assert_eq!(p.len(), 3);
        assert_eq!(p.embeddings.row(2), &[1.0, 0.0]);
        // prefix larger than len clamps
        assert_eq!(ab.prefix(100).len(), 4);
    }

    #[test]
    #[should_panic(expected = "row count mismatch")]
    fn trainset_rejects_mismatch() {
        let _ = TrainSet::new(Matrix::zeros(2, 4), Matrix::zeros(3, 1));
    }
}
