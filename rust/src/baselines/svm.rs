//! SVM quality predictor: the paper's SVM baseline (Appendix A.2 —
//! `LinearSVR` with epsilon = 0).
//!
//! One linear regressor per model, trained with SGD on the
//! epsilon-insensitive loss + L2 regularization:
//!
//! ```text
//! L(w, b) = C * mean_i max(0, |w.x_i + b - y_i| - eps) + 0.5 ||w||^2
//! ```
//!
//! With eps = 0 this is L1 regression with ridge regularization, matching
//! sklearn's default LinearSVR objective. `update` appends + refits.

use super::linalg::vec_axpy;
#[cfg(test)]
use super::linalg::Matrix;
use super::{QualityPredictor, TrainSet};
use crate::util::Rng;
use crate::vectordb::kernel;

/// Training hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct SvmOptions {
    pub epsilon: f64,
    pub epochs: usize,
    pub lr: f64,
    /// Loss weight C (sklearn default 1.0).
    pub c: f64,
    pub seed: u64,
}

impl Default for SvmOptions {
    fn default() -> Self {
        SvmOptions { epsilon: 0.0, epochs: 40, lr: 1e-2, c: 1.0, seed: 0x5A }
    }
}

/// Per-model LinearSVR bank.
pub struct SvmPredictor {
    opts: SvmOptions,
    /// [n_models][dim] weight vectors.
    weights: Vec<Vec<f32>>,
    biases: Vec<f32>,
    data: Option<TrainSet>,
    fitted: bool,
}

impl SvmPredictor {
    pub fn new(opts: SvmOptions) -> Self {
        SvmPredictor { opts, weights: Vec::new(), biases: Vec::new(), data: None, fitted: false }
    }

    fn train(&mut self) {
        let Some(data) = self.data.clone() else { return };
        if data.is_empty() {
            return;
        }
        let (n, dim, n_models) = (data.len(), data.embeddings.cols, data.n_models());
        self.weights = vec![vec![0.0f32; dim]; n_models];
        self.biases = vec![0.0f32; n_models];

        let mut rng = Rng::new(self.opts.seed);
        let mut order: Vec<usize> = (0..n).collect();
        let eps = self.opts.epsilon as f32;
        let c = self.opts.c as f32;
        let dot = kernel::dot_fn();

        for epoch in 0..self.opts.epochs {
            rng.shuffle(&mut order);
            // 1/t learning-rate decay
            let lr = (self.opts.lr / (1.0 + epoch as f64 * 0.1)) as f32;
            for &i in &order {
                let x = data.embeddings.row(i);
                for j in 0..n_models {
                    if data.mask.at(i, j) == 0.0 {
                        continue; // unobserved label (feedback supervision)
                    }
                    let y = data.qualities.at(i, j);
                    let w = &mut self.weights[j];
                    let pred = dot(w, x) + self.biases[j];
                    let r = pred - y;
                    // subgradient of eps-insensitive L1
                    let g = if r > eps {
                        1.0
                    } else if r < -eps {
                        -1.0
                    } else {
                        0.0
                    };
                    if g != 0.0 {
                        vec_axpy(w, -lr * c * g, x);
                        self.biases[j] -= lr * c * g;
                    }
                    // L2 shrinkage (ridge term), scaled to per-sample
                    let shrink = 1.0 - lr / n as f32;
                    for wv in w.iter_mut() {
                        *wv *= shrink;
                    }
                }
            }
        }
        self.fitted = true;
    }

    /// Weight L2 norm of one model's regressor (diagnostics).
    pub fn weight_norm(&self, model: usize) -> f32 {
        self.weights[model].iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

impl QualityPredictor for SvmPredictor {
    fn name(&self) -> &'static str {
        "svm"
    }

    fn fit(&mut self, data: &TrainSet) {
        self.data = Some(data.clone());
        self.train();
    }

    fn update(&mut self, new_data: &TrainSet) {
        match &mut self.data {
            Some(d) => d.extend(new_data),
            None => self.data = Some(new_data.clone()),
        }
        self.train(); // full refit: the paper's retraining cost
    }

    fn predict(&self, query: &[f32]) -> Vec<f64> {
        if !self.fitted {
            return Vec::new();
        }
        let dot = kernel::dot_fn();
        self.weights
            .iter()
            .zip(&self.biases)
            .map(|(w, b)| (dot(w, query) + b) as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::synthetic_regression;
    use super::*;

    fn quick_opts() -> SvmOptions {
        SvmOptions { epochs: 30, lr: 5e-2, ..Default::default() }
    }

    #[test]
    fn fits_linear_task_well() {
        // purely linear targets: y_j = w_j . x (svm should nail this)
        let mut rng = Rng::new(3);
        let dim = 8;
        let w_true: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 0.5).collect();
        let mut emb = Vec::new();
        let mut qual = Vec::new();
        for _ in 0..300 {
            let x: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let y: f32 = x.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f32>() + 0.3;
            emb.push(x);
            qual.push(vec![y]);
        }
        let data = TrainSet::new(Matrix::from_rows(&emb), Matrix::from_rows(&qual));
        let mut svm = SvmPredictor::new(quick_opts());
        svm.fit(&data);
        let mse = svm.mse(&data);
        assert!(mse < 0.01, "mse = {mse}");
    }

    #[test]
    fn learns_synthetic_task_reasonably() {
        let mut rng = Rng::new(5);
        let (all, _) = synthetic_regression(&mut rng, 500, 16, 3);
        let (train, test) = (all.prefix(400), all.suffix(400));
        let mut svm = SvmPredictor::new(quick_opts());
        svm.fit(&train);
        // sigmoid targets with a linear model: noticeably better than mean
        let mse = svm.mse(&test);
        assert!(mse < 0.05, "mse = {mse}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(7);
        let (train, _) = synthetic_regression(&mut rng, 100, 8, 2);
        let mut a = SvmPredictor::new(quick_opts());
        let mut b = SvmPredictor::new(quick_opts());
        a.fit(&train);
        b.fit(&train);
        assert_eq!(a.predict(train.embeddings.row(1)), b.predict(train.embeddings.row(1)));
    }

    #[test]
    fn unfitted_returns_empty() {
        let svm = SvmPredictor::new(quick_opts());
        assert!(svm.predict(&[0.0; 4]).is_empty());
    }

    #[test]
    fn update_refits_on_union() {
        let mut rng = Rng::new(9);
        let (a, _) = synthetic_regression(&mut rng, 50, 8, 2);
        let (b, _) = synthetic_regression(&mut rng, 50, 8, 2);
        let mut svm = SvmPredictor::new(quick_opts());
        svm.fit(&a);
        let norm_before = svm.weight_norm(0);
        svm.update(&b);
        assert_eq!(svm.data.as_ref().unwrap().len(), 100);
        assert!(svm.weight_norm(0) > 0.0);
        let _ = norm_before;
    }

    #[test]
    fn regularization_bounds_weights() {
        let mut rng = Rng::new(11);
        let (train, _) = synthetic_regression(&mut rng, 200, 8, 2);
        let mut svm = SvmPredictor::new(quick_opts());
        svm.fit(&train);
        for m in 0..2 {
            assert!(svm.weight_norm(m) < 50.0);
        }
    }
}
