//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a closure over `n` deterministically generated cases and
//! reports the seed of the first failing case so it can be replayed with
//! [`replay`]. Generators are plain functions over [`Rng`].
//!
//! ```no_run
//! # // no_run: doctest binaries miss the xla_extension rpath (lib tests
//! # // cover this API); compile-checked only.
//! use eagle::util::prop;
//! prop::check("sum commutes", 256, |rng| {
//!     let a = rng.f64();
//!     let b = rng.f64();
//!     prop::assert_close(a + b, b + a, 1e-12, "commutativity")
//! });
//! ```

use super::Rng;

/// Result of a single property case. `Err` carries a human-readable reason.
pub type CaseResult = Result<(), String>;

/// Run `cases` deterministic cases of `property`. Panics (with the failing
/// case seed) on the first failure.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    for case in 0..cases {
        let seed = fixed_seed(name, case);
        let mut rng = Rng::new(seed);
        if let Err(reason) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {reason}"
            );
        }
    }
}

/// Re-run a single case by seed (for debugging a reported failure).
pub fn replay<F>(seed: u64, mut property: F) -> CaseResult
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    let mut rng = Rng::new(seed);
    property(&mut rng)
}

/// Deterministic per-case seed derived from the property name.
fn fixed_seed(name: &str, case: u64) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ case.wrapping_mul(0x9e3779b97f4a7c15)
}

/// Approximate float equality assertion for property bodies.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> CaseResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol})"))
    }
}

/// Boolean assertion for property bodies.
pub fn assert_prop(cond: bool, what: &str) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

/// Generate a random f32 vector with entries in [-1, 1).
pub fn vec_f32(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

/// Generate a random lowercase ASCII "sentence" of 1..=max_words words.
pub fn sentence(rng: &mut Rng, max_words: usize) -> String {
    let n = 1 + rng.below(max_words);
    let mut out = String::new();
    for i in 0..n {
        if i > 0 {
            out.push(' ');
        }
        let wlen = 1 + rng.below(8);
        for _ in 0..wlen {
            out.push((b'a' + rng.below(26) as u8) as char);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 64, |rng| {
            let x = rng.f64();
            assert_prop((0.0..1.0).contains(&x), "f64 in unit interval")
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 8, |rng| {
            assert_prop(rng.f64() < -1.0, "impossible")
        });
    }

    #[test]
    fn replay_reproduces_case() {
        let mut captured = Vec::new();
        check("capture", 4, |rng| {
            captured.push(rng.next_u64());
            Ok(())
        });
        let seed = fixed_seed("capture", 2);
        let r = replay(seed, |rng| {
            assert_prop(rng.next_u64() == captured[2], "replay mismatch")
        });
        assert!(r.is_ok());
    }

    #[test]
    fn sentence_is_nonempty_lowercase() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = sentence(&mut rng, 10);
            assert!(!s.is_empty());
            assert!(s.chars().all(|c| c.is_ascii_lowercase() || c == ' '));
        }
    }

    #[test]
    fn vec_f32_range() {
        let mut rng = Rng::new(2);
        for x in vec_f32(&mut rng, 1000) {
            assert!((-1.0..1.0).contains(&x));
        }
    }
}
