//! Small shared substrates: deterministic RNG, statistics helpers, timing.
//!
//! The offline build environment carries no `rand`/`statrs`-style crates, so
//! these are implemented in-tree (DESIGN.md §Substitutions). Everything is
//! deterministic given a seed — all experiments in EXPERIMENTS.md are
//! reproducible bit-for-bit.

pub mod prop;

/// Permuted congruential generator (PCG-XSH-RR 64/32), the same generator
/// family used by `rand_pcg`. Deterministic, splittable via [`Rng::fork`].
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    const MULT: u64 = 6364136223846793005;

    /// Create a generator from a seed and stream id.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent generator; used to give each subsystem
    /// (dataset, model, noise source) its own stream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Rng::with_stream(seed, tag)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Log-normal with the given underlying mu/sigma.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: first k entries are the sample
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Trapezoidal integral of y over x. Points need not be sorted; they are
/// sorted by x first. Duplicate x values collapse to their mean y.
pub fn trapezoid_auc(points: &[(f64, f64)]) -> f64 {
    if points.len() < 2 {
        return 0.0;
    }
    let mut pts = points.to_vec();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut auc = 0.0;
    for w in pts.windows(2) {
        auc += (w[1].0 - w[0].0) * (w[0].1 + w[1].1) * 0.5;
    }
    auc
}

/// Monotonic wall-clock stopwatch.
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: std::time::Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_micros(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }
}

/// Cosine similarity between two equal-length vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut dot = 0.0f32;
    let mut na = 0.0f32;
    let mut nb = 0.0f32;
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na += a[i] * a[i];
        nb += b[i] * b[i];
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Normalize a vector to unit L2 norm in place (no-op on the zero vector).
pub fn l2_normalize(v: &mut [f32]) {
    let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if n > 1e-12 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seed_sensitivity() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn rng_fork_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_uniformity_rough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.below(4)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        assert!(mean(&xs).abs() < 0.02);
        assert!((stddev(&xs) - 1.0).abs() < 0.02);
    }

    #[test]
    fn chance_probability() {
        let mut r = Rng::new(13);
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        assert!((2_200..2_800).contains(&hits));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 30);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((49.0..=51.0).contains(&p50));
    }

    #[test]
    fn trapezoid_triangle() {
        let pts = [(0.0, 0.0), (1.0, 1.0)];
        assert!((trapezoid_auc(&pts) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trapezoid_unsorted_input() {
        let a = trapezoid_auc(&[(1.0, 1.0), (0.0, 0.0), (2.0, 1.0)]);
        let b = trapezoid_auc(&[(0.0, 0.0), (1.0, 1.0), (2.0, 1.0)]);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn cosine_identical_and_orthogonal() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn l2_normalize_unit() {
        let mut v = vec![3.0, 4.0];
        l2_normalize(&mut v);
        assert!((v[0] - 0.6).abs() < 1e-6 && (v[1] - 0.8).abs() < 1e-6);
        let mut z = vec![0.0, 0.0];
        l2_normalize(&mut z);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn mean_stddev_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }
}
