//! # Eagle — Efficient Training-Free Router for Multi-LLM Inference
//!
//! A rust + JAX + Pallas reproduction of *Eagle* (Zhao, Jin, Mao 2024):
//! a serving-side router that picks, per query and per budget, the LLM
//! expected to give the best answer, using a **global** ELO ranking over
//! all pairwise user feedback combined with a **local** ELO computed from
//! the N nearest historical queries by embedding similarity:
//!
//! ```text
//! Score(X) = P * Global(X) + (1 - P) * Local(X)
//! ```
//!
//! ## Architecture (three layers, python never serves)
//!
//! - **Layer 3 (this crate)** — the serving coordinator: request routing,
//!   dynamic embed batching, vector database, ELO engines, budget policy,
//!   feedback ingestion, baselines, evaluation harness, TCP front-end.
//! - **Layer 2** — `python/compile/model.py`: the MiniStella JAX encoder,
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! - **Layer 1** — `python/compile/kernels/`: Pallas flash-attention and
//!   similarity kernels inside the lowered HLO.
//!
//! The [`runtime`] module loads the HLO artifacts via the PJRT C API and
//! executes them on the request path. See DESIGN.md for the experiment
//! index and EXPERIMENTS.md for measured results.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod elo;
pub mod embedding;
pub mod eval;
pub mod runtime;
pub mod json;
pub mod metrics;
pub mod mmap;
pub mod routerbench;
pub mod server;
pub mod tokenizer;
pub mod util;
pub mod vectordb;
