//! Admission-control counters for the serving front-end.
//!
//! Every connection or request the event loop refuses is counted here by
//! reason, mirroring the drop taxonomy of
//! [`crate::coordinator::ingest::IngestMetrics`]: overload is an
//! *observable, bounded* state, never a silent one. The counters are
//! appended to the `stats` report so operators can tell load shedding
//! (`shed_*`) apart from hygiene closes (`closed_*`) at a glance.

use crate::metrics::Counter;

/// Per-reason admission counters, reported via the `stats` op.
#[derive(Debug, Default)]
pub struct ShedMetrics {
    /// Connections refused at accept because `max_connections` open
    /// connections already exist (the client gets one error line, then
    /// the socket closes).
    pub shed_conn_limit: Counter,
    /// Request lines answered with a load-shed error because the global
    /// `max_inflight` execution budget was exhausted at dispatch time.
    pub shed_inflight: Counter,
    /// Connections reaped by the idle sweep (`idle_timeout_ms` with no
    /// traffic and nothing in flight).
    pub closed_idle: Counter,
    /// Connections closed for an oversized frame (an unterminated
    /// request line beyond the per-connection buffer cap — the
    /// slow-loris / runaway-frame guard).
    pub closed_oversize: Counter,
}

impl ShedMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total load-shed replies + refused connections (not hygiene closes).
    pub fn shed_total(&self) -> u64 {
        self.shed_conn_limit.get() + self.shed_inflight.get()
    }

    /// One-line report, same shape as the ingest drop taxonomy.
    pub fn report(&self) -> String {
        format!(
            "server: shed(conn_limit={} inflight={}) closed(idle={} oversize={})",
            self.shed_conn_limit.get(),
            self.shed_inflight.get(),
            self.closed_idle.get(),
            self.closed_oversize.get(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_names_every_reason() {
        let m = ShedMetrics::new();
        m.shed_conn_limit.inc();
        m.shed_inflight.add(3);
        m.closed_idle.inc();
        let r = m.report();
        assert!(r.contains("conn_limit=1"), "{r}");
        assert!(r.contains("inflight=3"), "{r}");
        assert!(r.contains("idle=1"), "{r}");
        assert!(r.contains("oversize=0"), "{r}");
        assert_eq!(m.shed_total(), 4);
    }
}
