//! Readiness-polled connection fan-in for the serving front-end.
//!
//! One event-loop thread owns the listener and every client socket
//! (epoll on Linux, a `poll(2)` shim on other unix — raw syscalls, no
//! new dependencies). Connections are non-blocking state machines:
//! reads accumulate bytes until complete request lines appear, complete
//! lines are dispatched to a small worker pool as ordered *units* (one
//! outstanding unit per connection preserves response order and keeps
//! the pipelined co-batch amortization of
//! [`super::ServerState::handle_lines`]), and responses are buffered and
//! flushed when the socket is writable. Idle connections cost zero
//! wakeups — they sit in the poller until bytes arrive or the idle
//! sweep reaps them — so keep-alive clients can no longer pin one
//! worker each the way the old fixed worker pool allowed (`workers`
//! idle clients used to starve everyone else).
//!
//! Admission control is explicit and counted ([`super::shed::ShedMetrics`]):
//!
//! - `max_connections`: accepts beyond the cap get one load-shed error
//!   line and are closed (`shed_conn_limit`);
//! - `max_inflight`: request lines beyond the global execution budget
//!   are answered with a load-shed error inside their unit, in order
//!   (`shed_inflight`);
//! - `idle_timeout_ms`: connections with no traffic and nothing in
//!   flight are closed by a periodic sweep (`closed_idle`);
//! - an unterminated request line larger than [`MAX_LINE_BYTES`] closes
//!   the connection (`closed_oversize` — the slow-loris guard).
//!
//! Workers never touch sockets: they execute units against the shared
//! [`super::ServerState`] and hand the encoded bytes back to the loop
//! through a completion list plus a self-wake socket pair.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::util::Rng;

use super::protocol::{encode_response, Response};
use super::{ServerState, MAX_PIPELINE};

/// Poller token of the TCP listener.
const LISTENER: u64 = 0;
/// Poller token of the worker-side wake socket.
const WAKER: u64 = 1;
/// First token handed to a client connection.
const FIRST_CONN: u64 = 2;

/// Longest accepted request line. A client dribbling an unterminated
/// line forever (slow loris) is cut off here instead of growing the
/// read buffer without bound.
const MAX_LINE_BYTES: usize = 256 * 1024;
/// Pending response bytes beyond which the loop stops reading more
/// requests from a connection until the client drains its replies.
const MAX_OUT_BYTES: usize = 4 * 1024 * 1024;
/// Complete-but-undispatched lines per connection before reads pause
/// (TCP backpressure takes over; two units' worth keeps the pipeline
/// primed).
const MAX_PENDING_LINES: usize = 2 * MAX_PIPELINE;

/// Load-shed reply for a request line over the in-flight budget.
pub(super) const SHED_INFLIGHT_MSG: &str =
    "overloaded: in-flight request budget exhausted (load shed)";
/// Load-shed reply for a connection over the connection cap.
pub(super) const SHED_CONN_MSG: &str =
    "overloaded: connection limit reached (load shed)";

#[cfg(target_os = "linux")]
mod sys {
    //! epoll via raw syscalls (`std` already links libc on unix, so the
    //! `extern` declarations below add no dependency).

    use std::io;

    pub const EV_READ: u32 = 0x001; // EPOLLIN
    pub const EV_WRITE: u32 = 0x004; // EPOLLOUT

    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const MAX_EVENTS: usize = 64;

    // x86-64 packs epoll_event (matches the kernel ABI); other
    // architectures use natural alignment
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Poller {
        epfd: i32,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: i32, fd: i32, token: u64, interest: u32) -> io::Result<()> {
            // RDHUP so a peer half-close surfaces as readable (read
            // then returns 0 and the conn winds down)
            let mut ev = EpollEvent { events: interest | EPOLLRDHUP, data: token };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&mut self, fd: i32, token: u64, interest: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&mut self, fd: i32, token: u64, interest: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: i32) {
            let mut ev = EpollEvent { events: 0, data: 0 };
            let _ = unsafe { epoll_ctl(self.epfd, EPOLL_CTL_DEL, fd, &mut ev) };
        }

        /// Wait for readiness; `timeout_ms < 0` blocks. Fills `out` with
        /// `(token, readable, writable)`; EINTR is an empty wake.
        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<(u64, bool, bool)>) -> io::Result<()> {
            out.clear();
            let mut events = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
            let n = unsafe {
                epoll_wait(self.epfd, events.as_mut_ptr(), MAX_EVENTS as i32, timeout_ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in events.iter().take(n as usize) {
                // copy out of the (possibly packed) struct before use
                let flags = ev.events;
                let token = ev.data;
                let readable = flags & (EV_READ | EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0;
                let writable = flags & (EV_WRITE | EPOLLERR | EPOLLHUP) != 0;
                out.push((token, readable, writable));
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            let _ = unsafe { close(self.epfd) };
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    //! Portable `poll(2)` shim for non-Linux unix: O(n) per wait, same
    //! interface as the epoll backend.

    use std::io;

    pub const EV_READ: u32 = 0x1;
    pub const EV_WRITE: u32 = 0x4;

    const POLLIN: i16 = 0x1;
    const POLLOUT: i16 = 0x4;
    const POLLERR: i16 = 0x8;
    const POLLHUP: i16 = 0x10;
    const POLLNVAL: i16 = 0x20;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }

    pub struct Poller {
        /// `(fd, token, interest)` per registered descriptor.
        entries: Vec<(i32, u64, u32)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { entries: Vec::new() })
        }

        pub fn register(&mut self, fd: i32, token: u64, interest: u32) -> io::Result<()> {
            self.entries.push((fd, token, interest));
            Ok(())
        }

        pub fn modify(&mut self, fd: i32, token: u64, interest: u32) -> io::Result<()> {
            for e in self.entries.iter_mut() {
                if e.0 == fd {
                    e.1 = token;
                    e.2 = interest;
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
        }

        pub fn deregister(&mut self, fd: i32) {
            self.entries.retain(|e| e.0 != fd);
        }

        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<(u64, bool, bool)>) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = Vec::with_capacity(self.entries.len());
            for &(fd, _, interest) in &self.entries {
                let mut events: i16 = 0;
                if interest & EV_READ != 0 {
                    events |= POLLIN;
                }
                if interest & EV_WRITE != 0 {
                    events |= POLLOUT;
                }
                fds.push(PollFd { fd, events, revents: 0 });
            }
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u32, timeout_ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for (pf, &(_, token, _)) in fds.iter().zip(&self.entries) {
                let r = pf.revents;
                if r == 0 {
                    continue;
                }
                let readable = r & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0;
                let writable = r & (POLLOUT | POLLERR | POLLHUP) != 0;
                out.push((token, readable, writable));
            }
            Ok(())
        }
    }
}

use sys::{Poller, EV_READ, EV_WRITE};

/// One line inside a dispatch unit: either executed against the state
/// or pre-shed at admission (the worker emits the error reply in place,
/// preserving per-connection response order).
enum UnitLine {
    Execute(String),
    Shed,
}

/// An ordered batch of request lines from one connection. At most one
/// unit per connection is outstanding at a time.
struct Unit {
    token: u64,
    lines: Vec<UnitLine>,
}

/// A finished unit: encoded response bytes plus the in-flight budget to
/// refund. Budget is refunded even if the connection is already gone.
struct Completion {
    token: u64,
    bytes: Vec<u8>,
    executed: usize,
}

/// Per-connection state machine.
struct Conn {
    stream: std::net::TcpStream,
    /// Raw bytes read but not yet split into lines (partial tail).
    buf: Vec<u8>,
    /// Complete request lines awaiting dispatch.
    lines: VecDeque<String>,
    /// Encoded response bytes awaiting the socket.
    out: Vec<u8>,
    out_pos: usize,
    /// A unit is executing on the worker pool (at most one).
    unit_inflight: bool,
    /// Current poller interest mask (avoid redundant `modify` calls).
    interest: u32,
    last_activity: Instant,
    /// Peer closed (or errored); wind down once everything drains.
    eof: bool,
}

impl Conn {
    fn out_pending(&self) -> bool {
        self.out_pos < self.out.len()
    }
}

/// Handles returned by [`start`], owned by [`super::Server`].
pub(super) struct LoopHandles {
    pub addr: std::net::SocketAddr,
    /// Writing a byte wakes the loop (shutdown and worker completions).
    pub wake: UnixStream,
    pub loop_thread: std::thread::JoinHandle<()>,
    pub workers: Vec<std::thread::JoinHandle<()>>,
}

/// Bind `addr`, spawn the worker pool and the event-loop thread.
pub(super) fn start(state: Arc<ServerState>, addr: &str, workers: usize) -> Result<LoopHandles> {
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let local = listener.local_addr()?;

    let (wake_tx, wake_rx) = UnixStream::pair().context("wake pair")?;
    wake_tx.set_nonblocking(true)?;
    wake_rx.set_nonblocking(true)?;

    let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
    let (job_tx, job_rx) = mpsc::channel::<Unit>();
    let job_rx = Arc::new(Mutex::new(job_rx));

    let mut worker_handles = Vec::with_capacity(workers.max(1));
    for w in 0..workers.max(1) {
        let rx = job_rx.clone();
        let st = state.clone();
        let comp = completions.clone();
        let wake = wake_tx.try_clone().context("clone wake")?;
        worker_handles.push(
            std::thread::Builder::new()
                .name(format!("eagle-worker-{w}"))
                .spawn(move || worker_loop(rx, st, comp, wake, w as u64))
                .map_err(|e| anyhow!("spawn worker: {e}"))?,
        );
    }

    let mut poller = Poller::new().context("create poller")?;
    poller.register(listener.as_raw_fd(), LISTENER, EV_READ).context("register listener")?;
    poller.register(wake_rx.as_raw_fd(), WAKER, EV_READ).context("register waker")?;

    let admission = state.admission.clone();
    let el = EventLoop {
        state,
        poller,
        listener,
        wake_rx,
        completions,
        jobs: job_tx,
        conns: HashMap::new(),
        next_token: FIRST_CONN,
        inflight: 0,
        max_connections: admission.max_connections.max(1),
        max_inflight: admission.max_inflight.max(1),
        idle_timeout: if admission.idle_timeout_ms == 0 {
            None
        } else {
            Some(Duration::from_millis(admission.idle_timeout_ms))
        },
    };
    let loop_thread = std::thread::Builder::new()
        .name("eagle-event-loop".into())
        .spawn(move || el.run())
        .map_err(|e| anyhow!("spawn event loop: {e}"))?;

    Ok(LoopHandles { addr: local, wake: wake_tx, loop_thread, workers: worker_handles })
}

/// Worker: executes units against the shared state (no socket I/O) and
/// hands encoded bytes back through the completion list + wake socket.
/// Exits when the loop drops the job sender.
fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<Unit>>>,
    state: Arc<ServerState>,
    completions: Arc<Mutex<Vec<Completion>>>,
    mut wake: UnixStream,
    seed: u64,
) {
    let mut rng = Rng::with_stream(0x5EED, seed);
    loop {
        let unit = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(u) => u,
                Err(_) => return,
            }
        };
        let token = unit.token;
        let mut exec: Vec<String> = Vec::new();
        let mut executed_slot: Vec<bool> = Vec::with_capacity(unit.lines.len());
        for line in unit.lines {
            match line {
                UnitLine::Execute(s) => {
                    exec.push(s);
                    executed_slot.push(true);
                }
                UnitLine::Shed => executed_slot.push(false),
            }
        }
        let answers = if exec.is_empty() {
            Vec::new()
        } else {
            state.handle_lines(&exec, &mut rng)
        };
        let mut answers = answers.into_iter();
        let mut bytes = Vec::new();
        for was_executed in &executed_slot {
            let resp = if *was_executed {
                answers.next().expect("one response per executed line")
            } else {
                Response::Error(SHED_INFLIGHT_MSG.to_string())
            };
            bytes.extend_from_slice(encode_response(&resp).as_bytes());
            bytes.push(b'\n');
        }
        completions.lock().unwrap().push(Completion { token, bytes, executed: exec.len() });
        // best effort: a full wake pipe means a wake is already pending
        let _ = wake.write_all(&[1u8]);
    }
}

struct EventLoop {
    state: Arc<ServerState>,
    poller: Poller,
    listener: TcpListener,
    wake_rx: UnixStream,
    completions: Arc<Mutex<Vec<Completion>>>,
    jobs: mpsc::Sender<Unit>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    /// Request lines currently executing across all connections.
    inflight: usize,
    max_connections: usize,
    max_inflight: usize,
    idle_timeout: Option<Duration>,
}

impl EventLoop {
    fn run(mut self) {
        let mut events: Vec<(u64, bool, bool)> = Vec::with_capacity(64);
        let sweep_period = self.idle_timeout.map(|t| (t / 4).max(Duration::from_millis(10)));
        let mut next_sweep = sweep_period.map(|p| Instant::now() + p);
        loop {
            if self.state.stopped() {
                break;
            }
            let timeout_ms: i32 = match next_sweep {
                None => -1, // nothing scheduled: sleep until an event
                Some(at) => {
                    let now = Instant::now();
                    if at <= now {
                        0
                    } else {
                        (at.duration_since(now).as_millis().min(60_000) as i32) + 1
                    }
                }
            };
            if self.poller.wait(timeout_ms, &mut events).is_err() {
                break;
            }
            if self.state.stopped() {
                break;
            }
            for i in 0..events.len() {
                let (token, readable, writable) = events[i];
                match token {
                    LISTENER => self.accept_ready(),
                    WAKER => self.drain_wake(),
                    t => {
                        if readable {
                            self.conn_readable(t);
                        }
                        if writable && self.conns.contains_key(&t) {
                            self.flush_out(t);
                            self.update_interest_or_close(t);
                        }
                    }
                }
            }
            self.drain_completions();
            if let (Some(period), Some(at)) = (sweep_period, next_sweep) {
                if Instant::now() >= at {
                    self.sweep_idle();
                    next_sweep = Some(Instant::now() + period);
                }
            }
        }
        // dropping `self` closes every socket and the job sender, which
        // drains the worker pool
    }

    /// Accept everything pending; over the connection cap the client
    /// gets one load-shed error line and the socket closes.
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nodelay(true).ok();
                    if self.conns.len() >= self.max_connections {
                        self.state.shed.shed_conn_limit.inc();
                        let reply = format!(
                            "{}\n",
                            encode_response(&Response::Error(SHED_CONN_MSG.to_string()))
                        );
                        let mut s = stream;
                        let _ = s.set_nonblocking(true);
                        let _ = s.write_all(reply.as_bytes());
                        continue; // drop closes the socket
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let fd = stream.as_raw_fd();
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.register(fd, token, EV_READ).is_err() {
                        continue;
                    }
                    self.conns.insert(
                        token,
                        Conn {
                            stream,
                            buf: Vec::new(),
                            lines: VecDeque::new(),
                            out: Vec::new(),
                            out_pos: 0,
                            unit_inflight: false,
                            interest: EV_READ,
                            last_activity: Instant::now(),
                            eof: false,
                        },
                    );
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                // transient (EMFILE etc.); the next readiness retries
                Err(_) => break,
            }
        }
    }

    fn drain_wake(&mut self) {
        let mut tmp = [0u8; 256];
        loop {
            match self.wake_rx.read(&mut tmp) {
                Ok(0) => break, // all workers gone (shutdown)
                Ok(_) => continue,
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn conn_readable(&mut self, token: u64) {
        enum After {
            Continue,
            Close,
        }
        let mut after = After::Continue;
        {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            let mut tmp = [0u8; 16 * 1024];
            loop {
                match conn.stream.read(&mut tmp) {
                    Ok(0) => {
                        // EOF: a trailing partial line still gets served
                        // (clients may half-close after the last request)
                        conn.eof = true;
                        if !conn.buf.is_empty() {
                            let tail = std::mem::take(&mut conn.buf);
                            conn.lines.push_back(String::from_utf8_lossy(&tail).into_owned());
                        }
                        break;
                    }
                    Ok(n) => {
                        conn.buf.extend_from_slice(&tmp[..n]);
                        conn.last_activity = Instant::now();
                        if conn.buf.len() > MAX_OUT_BYTES {
                            // runaway pipelining while paused never gets
                            // this far (reads pause first); only a truly
                            // hostile burst lands here
                            after = After::Close;
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        after = After::Close;
                        break;
                    }
                }
            }
        }
        match after {
            After::Close => self.close_conn(token),
            After::Continue => self.pump(token),
        }
    }

    /// Advance a connection's state machine: split lines, dispatch a
    /// unit if possible, flush output, then re-arm or close.
    fn pump(&mut self, token: u64) {
        if self.extract_lines(token) {
            self.state.shed.closed_oversize.inc();
            self.close_conn(token);
            return;
        }
        self.maybe_dispatch(token);
        self.flush_out(token);
        self.update_interest_or_close(token);
    }

    /// Split complete lines out of the read buffer (up to the pending
    /// cap). Returns true when the connection must close because an
    /// unterminated line exceeds [`MAX_LINE_BYTES`].
    fn extract_lines(&mut self, token: u64) -> bool {
        let Some(conn) = self.conns.get_mut(&token) else { return false };
        while conn.lines.len() < MAX_PENDING_LINES {
            let Some(pos) = conn.buf.iter().position(|&b| b == b'\n') else {
                // no complete line left: the remaining tail must stay
                // bounded (slow-loris / runaway-frame guard)
                return conn.buf.len() > MAX_LINE_BYTES;
            };
            let rest = conn.buf.split_off(pos + 1);
            let mut line = std::mem::replace(&mut conn.buf, rest);
            line.pop(); // the '\n'
            // lossy: undecodable bytes still yield one (error) response
            // per line instead of killing the connection
            conn.lines.push_back(String::from_utf8_lossy(&line).into_owned());
        }
        false
    }

    /// Dispatch one unit if the connection has lines and none in flight.
    /// Lines beyond the global `max_inflight` budget are pre-shed into
    /// the unit so their error replies keep the response order.
    fn maybe_dispatch(&mut self, token: u64) {
        let budget = self.max_inflight.saturating_sub(self.inflight);
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.unit_inflight || conn.lines.is_empty() {
            return;
        }
        let take = conn.lines.len().min(MAX_PIPELINE);
        let admit = take.min(budget);
        let mut lines = Vec::with_capacity(take);
        for i in 0..take {
            let line = conn.lines.pop_front().expect("counted line");
            if i < admit {
                lines.push(UnitLine::Execute(line));
            } else {
                lines.push(UnitLine::Shed);
            }
        }
        let shed = take - admit;
        if shed > 0 {
            self.state.shed.shed_inflight.add(shed as u64);
            self.state.metrics.errors.add(shed as u64);
        }
        self.inflight += admit;
        conn.unit_inflight = true;
        // send can only fail when every worker is gone (shutdown)
        let _ = self.jobs.send(Unit { token, lines });
    }

    fn flush_out(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        while conn.out_pos < conn.out.len() {
            match conn.stream.write(&conn.out[conn.out_pos..]) {
                Ok(0) => break,
                Ok(n) => {
                    conn.out_pos += n;
                    conn.last_activity = Instant::now();
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    // write error: the peer is gone; drop the rest
                    conn.eof = true;
                    conn.out.clear();
                    conn.out_pos = 0;
                    break;
                }
            }
        }
        if conn.out_pos >= conn.out.len() {
            conn.out.clear();
            conn.out_pos = 0;
        } else if conn.out_pos > 64 * 1024 {
            conn.out.drain(..conn.out_pos);
            conn.out_pos = 0;
        }
    }

    /// Close a fully drained EOF connection, otherwise update the
    /// poller interest to what the state machine currently needs.
    fn update_interest_or_close(&mut self, token: u64) {
        let close = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            conn.eof && !conn.unit_inflight && conn.lines.is_empty() && !conn.out_pending()
        };
        if close {
            self.close_conn(token);
            return;
        }
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let want_read = !conn.eof
            && conn.lines.len() < MAX_PENDING_LINES
            && conn.out.len() - conn.out_pos < MAX_OUT_BYTES;
        let mut interest = 0u32;
        if want_read {
            interest |= EV_READ;
        }
        if conn.out_pending() {
            interest |= EV_WRITE;
        }
        if interest != conn.interest {
            conn.interest = interest;
            let fd = conn.stream.as_raw_fd();
            let _ = self.poller.modify(fd, token, interest);
        }
    }

    /// Append finished units to their connections and refund the
    /// in-flight budget (refunded even if the connection closed early).
    fn drain_completions(&mut self) {
        let done: Vec<Completion> = {
            let mut guard = self.completions.lock().unwrap();
            guard.drain(..).collect()
        };
        for c in done {
            self.inflight = self.inflight.saturating_sub(c.executed);
            let exists = match self.conns.get_mut(&c.token) {
                Some(conn) => {
                    conn.out.extend_from_slice(&c.bytes);
                    conn.unit_inflight = false;
                    conn.last_activity = Instant::now();
                    true
                }
                None => false,
            };
            if exists {
                self.pump(c.token);
            }
        }
    }

    /// Reap connections with no traffic and nothing in flight for
    /// longer than the idle timeout.
    fn sweep_idle(&mut self) {
        let Some(timeout) = self.idle_timeout else { return };
        let now = Instant::now();
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                !c.unit_inflight
                    && c.lines.is_empty()
                    && !c.out_pending()
                    && now.duration_since(c.last_activity) >= timeout
            })
            .map(|(&t, _)| t)
            .collect();
        for t in stale {
            self.state.shed.closed_idle.inc();
            self.close_conn(t);
        }
    }

    fn close_conn(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            self.poller.deregister(conn.stream.as_raw_fd());
            // dropping the stream closes the socket
        }
    }
}
