//! Serving front-end: a threaded TCP server speaking the newline-JSON
//! protocol, wired to the Eagle router, the embedding service, and the
//! feedback pipeline.
//!
//! ```text
//!         TCP workers (N)        engine thread          applier thread
//! route:  parse -> embed ------> PJRT batch ----+
//!         -> router.scores ---------------------+--> reply
//! feedback: parse -> queue.push               (async)
//!                         applier: pop -> embed -> router.observe
//! ```
//!
//! The router sits behind an `RwLock`: routes take the read lock (scores
//! are pure), the single applier thread takes the write lock per feedback
//! record — request tail latency is unaffected by feedback bursts
//! (backpressure lands on the bounded [`FeedbackQueue`] instead).

pub mod client;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::feedback::{ComparisonSampler, FeedbackQueue, Verdict};
use crate::coordinator::policy::BudgetPolicy;
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::router::EagleRouter;
use crate::embedding::EmbedHandle;
use crate::metrics::Metrics;
use crate::util::Rng;
use crate::vectordb::flat::FlatStore;

use protocol::{encode_response, parse_request, Request, Response};

/// Shared server state.
pub struct ServerState {
    pub router: RwLock<EagleRouter<FlatStore>>,
    pub registry: ModelRegistry,
    pub policy: BudgetPolicy,
    pub embed: EmbedHandle,
    pub metrics: Arc<Metrics>,
    pub sampler: ComparisonSampler,
    pub queue: FeedbackQueue,
    /// Where the admin `snapshot` op persists state (None = op disabled).
    pub snapshot_path: Option<std::path::PathBuf>,
    stop: AtomicBool,
}

impl ServerState {
    pub fn new(
        router: EagleRouter<FlatStore>,
        registry: ModelRegistry,
        embed: EmbedHandle,
        metrics: Arc<Metrics>,
    ) -> Self {
        let policy = BudgetPolicy::new(&registry);
        ServerState {
            router: RwLock::new(router),
            registry,
            policy,
            embed,
            metrics,
            sampler: ComparisonSampler::default(),
            queue: FeedbackQueue::new(4096),
            snapshot_path: None,
            stop: AtomicBool::new(false),
        }
    }

    /// Enable the admin `snapshot` op, persisting to `path`.
    pub fn with_snapshot_path(mut self, path: std::path::PathBuf) -> Self {
        self.snapshot_path = Some(path);
        self
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Handle one parsed request (shared by TCP handler and tests).
    pub fn handle(&self, req: Request, rng: &mut Rng) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Snapshot => match &self.snapshot_path {
                None => Response::Error("snapshot op disabled (no path configured)".into()),
                Some(path) => {
                    let router = self.router.read().unwrap();
                    let entries = {
                        use crate::vectordb::VectorIndex as _;
                        router.store().len() as u64
                    };
                    match crate::coordinator::state::save_to(&router, path) {
                        Ok(()) => Response::SnapshotSaved {
                            path: path.display().to_string(),
                            entries,
                        },
                        Err(e) => {
                            self.metrics.errors.inc();
                            Response::Error(format!("snapshot: {e}"))
                        }
                    }
                }
            },
            Request::Stats => Response::Stats {
                report: self.metrics.report(),
                requests: self.metrics.requests.get(),
                feedback: self.metrics.feedback.get(),
            },
            Request::Route { text, budget } => {
                let t0 = Instant::now();
                self.metrics.requests.inc();
                let emb = match self.embed.embed_one(&text) {
                    Ok(e) => e,
                    Err(e) => {
                        self.metrics.errors.inc();
                        return Response::Error(format!("embed: {e}"));
                    }
                };
                let (scores, ratings) = {
                    let router = self.router.read().unwrap();
                    let s = router.combined_scores(&emb);
                    let g = router.global().ratings().to_vec();
                    (s, g)
                };
                let choice = self.policy.select(&scores, budget);
                let compare_with = self
                    .sampler
                    .pick_partner(rng, choice, &ratings)
                    .map(|m| self.registry.entry(m).name.clone());
                self.metrics.route_latency.record(t0.elapsed());
                Response::Routed {
                    model: self.registry.entry(choice).name.clone(),
                    model_index: choice,
                    compare_with,
                    expected_cost: self.registry.entry(choice).expected_cost,
                }
            }
            Request::Feedback { text, model_a, model_b, score_a } => {
                let (Some(a), Some(b)) =
                    (self.registry.index_of(&model_a), self.registry.index_of(&model_b))
                else {
                    self.metrics.errors.inc();
                    return Response::Error(format!(
                        "unknown model in feedback: {model_a} / {model_b}"
                    ));
                };
                if a == b {
                    self.metrics.errors.inc();
                    return Response::Error("feedback: model_a == model_b".into());
                }
                if ![0.0, 0.5, 1.0].contains(&score_a) {
                    self.metrics.errors.inc();
                    return Response::Error("feedback: score_a must be 0, 0.5 or 1".into());
                }
                // Embed synchronously (cheap relative to the round trip),
                // queue the router update for the applier thread.
                let emb = match self.embed.embed_one(&text) {
                    Ok(e) => e,
                    Err(e) => {
                        self.metrics.errors.inc();
                        return Response::Error(format!("embed: {e}"));
                    }
                };
                self.metrics.feedback.inc();
                self.queue.push(Verdict { embedding: emb, model_a: a, model_b: b, score_a });
                Response::FeedbackAccepted
            }
        }
    }
}

/// The running server: worker threads + feedback applier.
pub struct Server {
    pub state: Arc<ServerState>,
    pub addr: std::net::SocketAddr,
    workers: Vec<std::thread::JoinHandle<()>>,
    applier: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on `addr` ("127.0.0.1:0" picks a free port).
    pub fn start(state: Arc<ServerState>, addr: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers.max(1) {
            let listener = listener.try_clone()?;
            let state = state.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("eagle-worker-{w}"))
                    .spawn(move || worker_loop(listener, state, w as u64))
                    .map_err(|e| anyhow!("spawn worker: {e}"))?,
            );
        }

        // feedback applier: single writer
        let applier_state = state.clone();
        let applier = std::thread::Builder::new()
            .name("eagle-feedback-applier".into())
            .spawn(move || applier_loop(applier_state))
            .map_err(|e| anyhow!("spawn applier: {e}"))?;

        Ok(Server { state, addr: local, workers: handles, applier: Some(applier) })
    }

    /// Signal shutdown and join all threads.
    pub fn shutdown(mut self) {
        self.state.stop();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(a) = self.applier.take() {
            let _ = a.join();
        }
    }
}

fn worker_loop(listener: TcpListener, state: Arc<ServerState>, seed: u64) {
    let mut rng = Rng::with_stream(0x5EED, seed);
    loop {
        if state.stopped() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nodelay(true).ok();
                if let Err(e) = handle_connection(stream, &state, &mut rng) {
                    // connection errors are per-client, not fatal
                    let _ = e;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn handle_connection(stream: TcpStream, state: &Arc<ServerState>, rng: &mut Rng) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if state.stopped() {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                let resp = match parse_request(&line) {
                    Ok(req) => state.handle(req, rng),
                    Err(e) => {
                        state.metrics.errors.inc();
                        Response::Error(e)
                    }
                };
                let mut out = encode_response(&resp);
                out.push('\n');
                writer.write_all(out.as_bytes())?;
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // idle keep-alive; re-check stop flag
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Applier: drains the feedback queue into the router (single writer).
fn applier_loop(state: Arc<ServerState>) {
    while let Some(verdict) = state.queue.pop() {
        if let Some(obs) = verdict.to_observation() {
            let mut router = state.router.write().unwrap();
            router.observe(obs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EagleParams;
    use crate::embedding::{BatcherOptions, EmbedService};

    // In-process handler tests that need no artifacts are below; full TCP
    // round-trips (with the PJRT embedder) live in rust/tests/server_e2e.rs.

    #[test]
    fn state_rejects_bad_feedback_models() {
        // Use a stats/ping-only state: embed handle requires artifacts, so
        // construct is deferred to e2e tests; here we exercise pure logic.
        // (Request::Stats and parse-level validation are covered in
        // protocol tests.)
        let req = parse_request(r#"{"op":"feedback","text":"t","model_a":"gpt-4","model_b":"gpt-4","score_a":1}"#).unwrap();
        match req {
            Request::Feedback { model_a, model_b, .. } => assert_eq!(model_a, model_b),
            _ => panic!(),
        }
    }

    #[test]
    fn server_struct_is_send() {
        fn assert_send<T: Send + Sync>() {}
        assert_send::<ServerState>();
        let _ = EagleParams::default();
        let _ = BatcherOptions::default();
        let _: Option<EmbedService> = None;
    }
}
