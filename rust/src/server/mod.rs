//! Serving front-end: a threaded TCP server speaking the newline-JSON
//! protocol, wired to the RCU snapshot router, the embedding service, and
//! the feedback pipeline.
//!
//! ```text
//!         TCP workers (N)           engine thread          applier thread
//! route:  parse (pipeline-drain) -> PJRT batch ----+
//!         -> snapshot.score_batch ------------------+--> reply
//! feedback: parse -> queue.push                  (async)
//!            applier: pop_batch -> writer.observe -> publish @ epoch
//! ```
//!
//! Route scoring is **lock-free with respect to feedback application**:
//! readers load an immutable [`ShardedSnapshot`] (per-shard RCU
//! snapshots + the shared global-ELO table) from the [`ShardedHandle`]
//! and score against it; the applier thread owns the [`ShardedRouter`]
//! (behind a `Mutex` shared only with the admin snapshot op), routes each
//! verdict to its hash shard, and every lane republishes at the
//! configured epoch cadence. A feedback storm can no longer stall route
//! reads — backpressure lands on the bounded [`FeedbackQueue`], and
//! snapshot staleness is bounded by [`crate::config::EpochParams`]. With
//! `[shards] count = 1` (the default) this is exactly the single-shard
//! RCU path; higher counts scatter-gather batched scoring across shards
//! with bit-identical results.
//!
//! Workers batch-drain: each connection handler pulls every pipelined
//! request already buffered and serves all route requests in it with one
//! embed round trip + one snapshot acquisition (`route_batch` gives
//! clients the same amortization explicitly).

pub mod client;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::{EpochParams, ShardParams};
use crate::coordinator::feedback::{ComparisonSampler, FeedbackQueue, Verdict};
use crate::coordinator::policy::BudgetPolicy;
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::router::EagleRouter;
use crate::coordinator::sharded::{ShardedHandle, ShardedRouter, ShardedSnapshot};
use crate::embedding::EmbedHandle;
use crate::metrics::Metrics;
use crate::util::Rng;
use crate::vectordb::flat::FlatStore;

use protocol::{encode_response, parse_request, Request, Response, RouteReply};

/// Max pipelined requests drained per connection read (worker batching).
const MAX_PIPELINE: usize = 32;

/// Max feedback records the applier folds in per writer-lock acquisition.
const APPLIER_BATCH: usize = 256;

/// Shared server state.
pub struct ServerState {
    /// Lock-free publication point for the route path (one ring per
    /// shard plus the shared global table).
    pub snapshots: ShardedHandle,
    /// Sharded ingest side. Locked by the applier thread and the admin
    /// snapshot op only — never by route reads.
    pub writer: Mutex<ShardedRouter>,
    pub registry: ModelRegistry,
    pub policy: BudgetPolicy,
    pub embed: EmbedHandle,
    pub metrics: Arc<Metrics>,
    pub sampler: ComparisonSampler,
    pub queue: FeedbackQueue,
    /// Where the admin `snapshot` op persists state (None = op disabled).
    pub snapshot_path: Option<std::path::PathBuf>,
    epoch_params: EpochParams,
    stop: AtomicBool,
}

impl ServerState {
    pub fn new(
        router: EagleRouter<FlatStore>,
        registry: ModelRegistry,
        embed: EmbedHandle,
        metrics: Arc<Metrics>,
    ) -> Self {
        Self::with_epoch(router, registry, embed, metrics, EpochParams::default())
    }

    /// Construct with an explicit snapshot-publication cadence (single
    /// shard).
    pub fn with_epoch(
        router: EagleRouter<FlatStore>,
        registry: ModelRegistry,
        embed: EmbedHandle,
        metrics: Arc<Metrics>,
        epoch_params: EpochParams,
    ) -> Self {
        Self::with_topology(
            router,
            registry,
            embed,
            metrics,
            epoch_params,
            ShardParams::default(),
        )
    }

    /// Construct with an explicit cadence and sharding topology. The
    /// corpus is hash-partitioned across `shard_params.count` shards;
    /// scoring is bit-identical at any count.
    pub fn with_topology(
        router: EagleRouter<FlatStore>,
        registry: ModelRegistry,
        embed: EmbedHandle,
        metrics: Arc<Metrics>,
        epoch_params: EpochParams,
        shard_params: ShardParams,
    ) -> Self {
        let writer = ShardedRouter::from_router(router, epoch_params.clone(), shard_params);
        let policy = BudgetPolicy::new(&registry);
        ServerState {
            snapshots: writer.handle(),
            writer: Mutex::new(writer),
            registry,
            policy,
            embed,
            metrics,
            sampler: ComparisonSampler::default(),
            queue: FeedbackQueue::new(4096),
            snapshot_path: None,
            epoch_params,
            stop: AtomicBool::new(false),
        }
    }

    /// Enable the admin `snapshot` op, persisting to `path`.
    pub fn with_snapshot_path(mut self, path: std::path::PathBuf) -> Self {
        self.snapshot_path = Some(path);
        self
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Force an immediate publish of everything ingested so far — every
    /// shard lane and the shared global table (tests / admin; the applier
    /// publishes on cadence by itself). Returns the highest shard epoch.
    pub fn force_publish(&self) -> u64 {
        self.writer.lock().unwrap().publish_all()
    }

    /// Route a slab of texts: one embed round trip, one snapshot
    /// acquisition, `texts.len()` scored decisions. `budgets` is parallel
    /// to `texts`.
    fn route_many(
        &self,
        texts: &[&str],
        budgets: &[f64],
        rng: &mut Rng,
    ) -> Result<Vec<RouteReply>, String> {
        debug_assert_eq!(texts.len(), budgets.len());
        let t0 = Instant::now();
        self.metrics.requests.add(texts.len() as u64);
        let embs = match self.embed.embed_many(texts) {
            Ok(e) => e,
            Err(e) => {
                self.metrics.errors.add(texts.len() as u64);
                return Err(format!("embed: {e}"));
            }
        };
        let snap: ShardedSnapshot = self.snapshots.load();
        let ratings = snap.global_ratings();
        let replies = snap
            .score_batch(&embs)
            .into_iter()
            .zip(budgets)
            .map(|(scores, &budget)| {
                let choice = self.policy.select(&scores, budget);
                let compare_with = self
                    .sampler
                    .pick_partner(rng, choice, ratings)
                    .map(|m| self.registry.entry(m).name.clone());
                RouteReply {
                    model: self.registry.entry(choice).name.clone(),
                    model_index: choice,
                    compare_with,
                    expected_cost: self.registry.entry(choice).expected_cost,
                }
            })
            .collect();
        // per-decision latency: the batch amortizes embed + snapshot load
        let per = t0.elapsed() / texts.len().max(1) as u32;
        for _ in 0..texts.len() {
            self.metrics.route_latency.record(per);
        }
        Ok(replies)
    }

    /// Handle one parsed request (shared by TCP handler and tests).
    pub fn handle(&self, req: Request, rng: &mut Rng) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Snapshot => match &self.snapshot_path {
                None => Response::Error("snapshot op disabled (no path configured)".into()),
                Some(path) => {
                    let mut writer = self.writer.lock().unwrap();
                    let entries = writer.store_len() as u64;
                    match writer.save_to(path) {
                        Ok(()) => Response::SnapshotSaved {
                            path: path.display().to_string(),
                            entries,
                        },
                        Err(e) => {
                            self.metrics.errors.inc();
                            Response::Error(format!("snapshot: {e}"))
                        }
                    }
                }
            },
            Request::Stats => Response::Stats {
                report: self.metrics.report(),
                requests: self.metrics.requests.get(),
                feedback: self.metrics.feedback.get(),
            },
            Request::Route { text, budget } => {
                match self.route_many(&[text.as_str()], &[budget], rng) {
                    Ok(mut replies) => {
                        let r = replies.pop().expect("one reply per text");
                        Response::Routed {
                            model: r.model,
                            model_index: r.model_index,
                            compare_with: r.compare_with,
                            expected_cost: r.expected_cost,
                        }
                    }
                    Err(e) => Response::Error(e),
                }
            }
            Request::RouteBatch { texts, budget } => {
                let refs: Vec<&str> = texts.iter().map(|t| t.as_str()).collect();
                let budgets = vec![budget; refs.len()];
                match self.route_many(&refs, &budgets, rng) {
                    Ok(replies) => Response::RoutedBatch(replies),
                    Err(e) => Response::Error(e),
                }
            }
            Request::Feedback { text, model_a, model_b, score_a } => {
                let (Some(a), Some(b)) =
                    (self.registry.index_of(&model_a), self.registry.index_of(&model_b))
                else {
                    self.metrics.errors.inc();
                    return Response::Error(format!(
                        "unknown model in feedback: {model_a} / {model_b}"
                    ));
                };
                if a == b {
                    self.metrics.errors.inc();
                    return Response::Error("feedback: model_a == model_b".into());
                }
                if ![0.0, 0.5, 1.0].contains(&score_a) {
                    self.metrics.errors.inc();
                    return Response::Error("feedback: score_a must be 0, 0.5 or 1".into());
                }
                // Embed synchronously (cheap relative to the round trip),
                // queue the router update for the applier thread.
                let emb = match self.embed.embed_one(&text) {
                    Ok(e) => e,
                    Err(e) => {
                        self.metrics.errors.inc();
                        return Response::Error(format!("embed: {e}"));
                    }
                };
                self.metrics.feedback.inc();
                self.queue.push(Verdict { embedding: emb, model_a: a, model_b: b, score_a });
                Response::FeedbackAccepted
            }
        }
    }

    /// Handle a pipelined batch of request lines, preserving order.
    /// All single `route` requests in the batch are served together
    /// through [`ServerState::route_many`].
    pub fn handle_lines(&self, lines: &[String], rng: &mut Rng) -> Vec<Response> {
        let parsed: Vec<Result<Request, String>> =
            lines.iter().map(|l| parse_request(l)).collect();
        let mut out: Vec<Option<Response>> = (0..lines.len()).map(|_| None).collect();

        // co-batch the single routes (2+ makes the amortization worth it)
        let routes: Vec<(usize, String, f64)> = parsed
            .iter()
            .enumerate()
            .filter_map(|(i, r)| match r {
                Ok(Request::Route { text, budget }) => Some((i, text.clone(), *budget)),
                _ => None,
            })
            .collect();
        if routes.len() >= 2 {
            let texts: Vec<&str> = routes.iter().map(|(_, t, _)| t.as_str()).collect();
            let budgets: Vec<f64> = routes.iter().map(|(_, _, b)| *b).collect();
            match self.route_many(&texts, &budgets, rng) {
                Ok(replies) => {
                    for ((i, _, _), r) in routes.iter().zip(replies) {
                        out[*i] = Some(Response::Routed {
                            model: r.model,
                            model_index: r.model_index,
                            compare_with: r.compare_with,
                            expected_cost: r.expected_cost,
                        });
                    }
                }
                Err(e) => {
                    for (i, _, _) in &routes {
                        out[*i] = Some(Response::Error(e.clone()));
                    }
                }
            }
        }

        for (i, req) in parsed.into_iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            out[i] = Some(match req {
                Ok(r) => self.handle(r, rng),
                Err(e) => {
                    self.metrics.errors.inc();
                    Response::Error(e)
                }
            });
        }
        out.into_iter().map(|r| r.expect("every line answered")).collect()
    }
}

/// The running server: worker threads + feedback applier.
pub struct Server {
    pub state: Arc<ServerState>,
    pub addr: std::net::SocketAddr,
    workers: Vec<std::thread::JoinHandle<()>>,
    applier: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on `addr` ("127.0.0.1:0" picks a free port).
    pub fn start(state: Arc<ServerState>, addr: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers.max(1) {
            let listener = listener.try_clone()?;
            let state = state.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("eagle-worker-{w}"))
                    .spawn(move || worker_loop(listener, state, w as u64))
                    .map_err(|e| anyhow!("spawn worker: {e}"))?,
            );
        }

        // feedback applier: single writer
        let applier_state = state.clone();
        let applier = std::thread::Builder::new()
            .name("eagle-feedback-applier".into())
            .spawn(move || applier_loop(applier_state))
            .map_err(|e| anyhow!("spawn applier: {e}"))?;

        Ok(Server { state, addr: local, workers: handles, applier: Some(applier) })
    }

    /// Signal shutdown and join all threads.
    pub fn shutdown(mut self) {
        self.state.stop();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(a) = self.applier.take() {
            let _ = a.join();
        }
    }
}

fn worker_loop(listener: TcpListener, state: Arc<ServerState>, seed: u64) {
    let mut rng = Rng::with_stream(0x5EED, seed);
    loop {
        if state.stopped() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nodelay(true).ok();
                if let Err(e) = handle_connection(stream, &state, &mut rng) {
                    // connection errors are per-client, not fatal
                    let _ = e;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn handle_connection(stream: TcpStream, state: &Arc<ServerState>, rng: &mut Rng) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut lines: Vec<String> = Vec::new();
    // Accumulates across read timeouts: a request line split over slow TCP
    // segments keeps its consumed prefix here instead of being dropped.
    let mut pending = String::new();
    loop {
        if state.stopped() {
            return Ok(());
        }
        lines.clear();
        match reader.read_line(&mut pending) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                lines.push(std::mem::take(&mut pending));
                // batch-drain: pull every complete pipelined line already
                // sitting in the read buffer (no extra syscalls, no
                // blocking) so co-batched routes share one embed dispatch
                while lines.len() < MAX_PIPELINE && reader.buffer().contains(&b'\n') {
                    let mut next = String::new();
                    match reader.read_line(&mut next) {
                        Ok(0) => break,
                        Ok(_) => lines.push(next),
                        Err(_) => {
                            // a line was consumed but is unreadable (e.g.
                            // invalid UTF-8): answer it with a parse error
                            // to keep one response per request line
                            lines.push(next);
                            break;
                        }
                    }
                }
                let mut out = String::new();
                for resp in state.handle_lines(&lines, rng) {
                    out.push_str(&encode_response(&resp));
                    out.push('\n');
                }
                writer.write_all(out.as_bytes())?;
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // idle keep-alive; any partial line stays in `pending`
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Applier: drains the feedback queue into the router (single writer).
/// Batched: one writer-lock acquisition folds in up to [`APPLIER_BATCH`]
/// records; the pop timeout doubles as the staleness beat that flushes a
/// pending epoch when feedback goes quiet.
fn applier_loop(state: Arc<ServerState>) {
    let beat = Duration::from_millis(state.epoch_params.publish_interval_ms.max(1));
    loop {
        match state.queue.pop_batch(APPLIER_BATCH, beat) {
            None => {
                // closed: flush anything ingested but not yet published
                let mut w = state.writer.lock().unwrap();
                if w.unpublished() > 0 {
                    w.publish_all();
                }
                return;
            }
            Some(batch) if batch.is_empty() => {
                // timeout beat: publish stale epochs if records pend
                let mut w = state.writer.lock().unwrap();
                w.maybe_publish_all();
            }
            Some(batch) => {
                let mut w = state.writer.lock().unwrap();
                for verdict in batch {
                    if let Some(obs) = verdict.to_observation() {
                        w.observe(obs);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EagleParams;
    use crate::embedding::{BatcherOptions, EmbedService};

    // In-process handler tests that need no artifacts are below; full TCP
    // round-trips (with the PJRT embedder) live in rust/tests/server_e2e.rs.

    #[test]
    fn state_rejects_bad_feedback_models() {
        // Use a stats/ping-only state: embed handle requires artifacts, so
        // construct is deferred to e2e tests; here we exercise pure logic.
        // (Request::Stats and parse-level validation are covered in
        // protocol tests.)
        let req = parse_request(r#"{"op":"feedback","text":"t","model_a":"gpt-4","model_b":"gpt-4","score_a":1}"#).unwrap();
        match req {
            Request::Feedback { model_a, model_b, .. } => assert_eq!(model_a, model_b),
            _ => panic!(),
        }
    }

    #[test]
    fn server_struct_is_send() {
        fn assert_send<T: Send + Sync>() {}
        assert_send::<ServerState>();
        let _ = EagleParams::default();
        let _ = BatcherOptions::default();
        let _: Option<EmbedService> = None;
    }
}
