//! Serving front-end: an event-looped TCP server speaking the
//! newline-JSON protocol, wired to the RCU snapshot router, the
//! embedding service, and the sharded feedback-ingest pipeline.
//!
//! ```text
//!  event loop (1 thread)           exec workers (N)      ingest pipeline (K+1 threads)
//!  accept / read / write ──units──► handle_lines:
//! route:   parse (co-batch) ──► PJRT batch ──► snapshot.score_batch ──► reply
//! feedback: validate ──► raw queue ──► dispatcher: batch-embed + global ELO
//!                                        ──► per-shard queue ──► lane applier
//!                                                                + publish @ epoch
//! ```
//!
//! Route scoring is **lock-free with respect to feedback application**:
//! readers load an immutable [`ShardedSnapshot`] (per-shard RCU snapshots
//! + the shared global-ELO table) from the [`ShardedHandle`] and score
//! against it. Feedback ingest is the sharded pipeline of
//! [`crate::coordinator::ingest`]: the request handler enqueues **raw
//! text** and returns; the dispatcher thread batch-embeds through the
//! same PJRT bucket path the route slabs use, folds the shared global
//! table in stream order, and routes each record to its hash shard, where
//! a dedicated applier thread owns the [`crate::coordinator::sharded::ShardLane`]
//! and republishes at the epoch cadence. A feedback storm can no longer
//! stall route reads — backpressure lands on the bounded ingest queues
//! (drops are counted in [`crate::coordinator::ingest::IngestMetrics`]),
//! and snapshot staleness is bounded by [`crate::config::EpochParams`].
//! With `[shards] count = 1` (the default) this is the single-shard RCU
//! path with one applier; higher counts scale both scatter-gather reads
//! and ingest with bit-identical scores.
//!
//! Connection fan-in is a readiness-polled event loop
//! ([`event_loop`]): one thread owns every socket, idle connections
//! cost zero wakeups, and the worker pool only ever executes complete
//! request batches — so `workers` idle keep-alive clients can no
//! longer starve the pool the way the old thread-per-connection design
//! allowed. Pipelined lines are dispatched as ordered units and served
//! through [`ServerState::handle_lines`], which co-batches the single
//! `route` requests in a unit into one embed round trip + one snapshot
//! acquisition (`route_batch` gives clients the same amortization
//! explicitly). Admission is explicit ([`Admission`]): a connection
//! cap, a global in-flight request budget, and an idle timeout, each
//! refusal counted by reason in [`shed::ShedMetrics`] and reported via
//! the `stats` op.

pub mod client;
mod event_loop;
pub mod protocol;
pub mod shed;
pub mod stats;

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{EpochParams, IvfPublishParams, QuantParams, Role, ShardParams};
use crate::coordinator::durable::{CompactorHandle, DurableOptions, DurableStore};
use crate::coordinator::feedback::{ComparisonSampler, RawVerdict};
use crate::coordinator::ingest::{IngestMetrics, IngestOptions, IngestPipeline, PersistTarget};
use crate::coordinator::policy::{approx_tokens, PolicySpec, RoutePolicy};
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::replica::{Follower, FollowerHandle, Promotion};
use crate::coordinator::router::EagleRouter;
use crate::coordinator::sharded::{ShardedHandle, ShardedRouter, ShardedSnapshot};
use crate::embedding::EmbedHandle;
use crate::metrics::Metrics;
use crate::util::Rng;
use crate::vectordb::flat::FlatStore;

use protocol::{parse_request, Request, Response, RouteReply};

/// Max pipelined requests per dispatch unit (worker co-batching).
const MAX_PIPELINE: usize = 32;

/// Admission-control knobs for the TCP front-end (`[server]` config).
/// Refusals are counted by reason in [`shed::ShedMetrics`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Admission {
    /// Max simultaneously open client connections; beyond the cap a new
    /// connection gets one load-shed error line and is closed.
    pub max_connections: usize,
    /// Max request lines executing across all connections; lines over
    /// the budget get an in-order load-shed error reply.
    pub max_inflight: usize,
    /// Close connections idle for this long, in milliseconds (0 = never).
    pub idle_timeout_ms: u64,
}

impl Default for Admission {
    fn default() -> Self {
        Admission { max_connections: 4096, max_inflight: 256, idle_timeout_ms: 30_000 }
    }
}

/// Everything configurable about the serving state in one place (epoch
/// cadence, sharding topology, IVF publication, background persistence).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub epoch: EpochParams,
    pub shards: ShardParams,
    /// IVF publication policy for every shard lane (threshold 0 = flat
    /// views only).
    pub ivf: IvfPublishParams,
    /// SQ8 publication policy (`[quant]`): quantized scan + exact rerank
    /// for flat publications on every shard lane. The `EAGLE_QUANT` env
    /// var (`1`/`0`) overrides `enable` at startup.
    pub quant: crate::config::QuantParams,
    /// Periodic persistence beat from the ingest dispatcher (0 = no
    /// beat; a durable store still appends + seals inline and
    /// checkpoints on flush/admin/shutdown).
    pub persist_interval_ms: u64,
    /// Durable segment-store directory (`[persist] dir`). When set, the
    /// server recovers from it at startup if it exists (otherwise
    /// bootstraps it from the starting router), appends every ingested
    /// record to its delta logs, and the admin `snapshot` op rides the
    /// store instead of writing a JSON blob.
    pub persist_dir: Option<std::path::PathBuf>,
    /// Durable-store seal threshold (`[persist] seal_bytes`).
    pub seal_bytes: usize,
    /// Durable-store fsync policy (`[persist] fsync`).
    pub fsync: bool,
    /// Seal segments in the mmap-friendly v2 layout and serve them
    /// zero-copy from the page cache (`[persist] mmap`).
    pub mmap: bool,
    /// Background segment-compaction beat in ms (`[persist]
    /// compact_interval_ms`; 0 = off).
    pub compact_interval_ms: u64,
    /// Grace window before compacted-away segment files are deleted
    /// (`[persist] gc_grace_ms`).
    pub gc_grace_ms: u64,
    /// Scoring-kernel backend choice (`[kernel] backend`): installed as
    /// the process default at startup; the `EAGLE_KERNEL` env var wins.
    pub kernel_backend: String,
    /// Admission control for the event-looped front-end (`[server]`
    /// `max_connections` / `max_inflight` / `idle_timeout_ms`).
    pub admission: Admission,
    /// Serving role (`[replica] role`, `EAGLE_ROLE`, `--role`): a
    /// `Leader` owns ingest + the durable store; a `Follower` tails the
    /// leader's store (which `persist_dir` must point at) read-only and
    /// rejects feedback/admin ops until promoted.
    pub role: Role,
    /// Follower tail-poll interval (`[replica] poll_ms`).
    pub replica_poll_ms: u64,
    /// Cap for the follower's exponential idle backoff (`[replica]
    /// backoff_max_ms`; at or below `poll_ms` = fixed-interval polling).
    pub replica_backoff_max_ms: u64,
}

impl Default for ServerOptions {
    fn default() -> Self {
        let durable = DurableOptions::default();
        let persist = crate::config::PersistParams::default();
        let replica = crate::config::ReplicaParams::default();
        ServerOptions {
            epoch: EpochParams::default(),
            shards: ShardParams::default(),
            ivf: IvfPublishParams::default(),
            quant: crate::config::QuantParams::default(),
            persist_interval_ms: 0,
            persist_dir: None,
            seal_bytes: durable.seal_bytes,
            fsync: durable.fsync,
            mmap: durable.mmap,
            compact_interval_ms: persist.compact_interval_ms,
            gc_grace_ms: persist.gc_grace_ms,
            kernel_backend: "auto".to_string(),
            admission: Admission::default(),
            role: Role::default(),
            replica_poll_ms: replica.poll_ms,
            replica_backoff_max_ms: replica.backoff_max_ms,
        }
    }
}

/// The role-dependent half of the server: a leader owns the ingest
/// pipeline; a follower owns the tail loop. Swapped under the state's
/// role lock by the `promote` op.
enum RoleState {
    Leader {
        /// The sharded ingest side: per-shard applier threads fed by a
        /// raw feedback queue; never touched by route reads.
        ingest: IngestPipeline,
    },
    Follower {
        /// The background tail loop replaying the leader's durable log.
        tail: FollowerHandle,
    },
}

/// Shared server state.
pub struct ServerState {
    /// Lock-free publication point for the route path (one ring per
    /// shard plus the shared global table). Stable across promotion:
    /// the promoted router is reassembled around the same rings.
    pub snapshots: ShardedHandle,
    /// Leader (ingest pipeline) or follower (tail loop); `promote`
    /// swaps this under the write lock. Route reads never touch it.
    role: RwLock<RoleState>,
    /// Ingest counters, stable across promotion (the promoted pipeline
    /// reuses this handle via
    /// [`IngestPipeline::start_with_metrics`]).
    ingest_metrics: Arc<IngestMetrics>,
    pub registry: ModelRegistry,
    pub policy: RoutePolicy,
    /// Policy applied to requests that don't pick one (v1 clients, bare
    /// v2 routes) — `[policy]` config.
    pub default_policy: PolicySpec,
    pub embed: EmbedHandle,
    pub metrics: Arc<Metrics>,
    pub sampler: ComparisonSampler,
    /// Where the admin `snapshot` op persists state as legacy JSON
    /// (None = op disabled unless a durable store is attached).
    pub snapshot_path: Option<std::path::PathBuf>,
    /// The durable segment store, when `[persist] dir` is configured —
    /// the admin `snapshot` op checkpoints it instead of writing JSON.
    /// `None` on a follower until promotion attaches the leader's store.
    durable: RwLock<Option<Arc<DurableStore>>>,
    /// Admission knobs the event loop enforces ([`ServerOptions`]).
    pub admission: Admission,
    /// Per-reason admission counters, appended to the `stats` report.
    pub shed: Arc<shed::ShedMetrics>,
    /// Build-time knobs the promotion path replays when it starts the
    /// ingest pipeline mid-flight.
    epoch: EpochParams,
    ivf: IvfPublishParams,
    /// `[quant]` with the `EAGLE_QUANT` override already resolved.
    quant: QuantParams,
    persist_interval_ms: u64,
    durable_opts: DurableOptions,
    replica_poll: Duration,
    replica_backoff_max: Duration,
    /// Background segment compactor + GC beat (leader with a durable
    /// store and `compact_interval_ms > 0`; spawned again on
    /// promotion). Dropping the handle stops the thread.
    compactor: Mutex<Option<CompactorHandle>>,
    compact_interval: Duration,
    gc_grace: Duration,
    stop: AtomicBool,
}

/// The one way to construct a [`ServerState`]: topology → options →
/// policy → build. Replaces the old `new` / `with_epoch` /
/// `with_topology` / `with_options` / `with_sharded` constructor sprawl.
///
/// ```no_run
/// # use eagle::server::ServerState;
/// # use eagle::coordinator::policy::PolicySpec;
/// # let (router, registry, embed, metrics) = todo!();
/// let state = ServerState::builder(router, registry, embed, metrics)
///     .epoch(Default::default())
///     .default_policy(PolicySpec::Budget { budget: 0.02 })
///     .build();
/// ```
///
/// Fine-grained setters (`epoch`, `shards`, `admission`, …) override the
/// option block, so call [`ServerBuilder::options`] first when mixing.
pub struct ServerBuilder {
    router: EagleRouter<FlatStore>,
    registry: ModelRegistry,
    embed: EmbedHandle,
    metrics: Arc<Metrics>,
    opts: ServerOptions,
    default_policy: PolicySpec,
    snapshot_path: Option<std::path::PathBuf>,
}

impl ServerBuilder {
    /// Snapshot-publication cadence (single shard unless
    /// [`ServerBuilder::shards`] raises the count).
    pub fn epoch(mut self, epoch: EpochParams) -> Self {
        self.opts.epoch = epoch;
        self
    }

    /// Sharding topology: the corpus is hash-partitioned across
    /// `shards.count` shards; scoring is bit-identical at any count.
    pub fn shards(mut self, shards: ShardParams) -> Self {
        self.opts.shards = shards;
        self
    }

    /// Replace the whole option block (config-driven start-up).
    pub fn options(mut self, opts: ServerOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Admission-control knobs for the event-looped front-end.
    pub fn admission(mut self, admission: Admission) -> Self {
        self.opts.admission = admission;
        self
    }

    /// Policy for requests that don't pick one (v1 clients, bare v2
    /// routes). Defaults to an unconstrained budget policy.
    pub fn default_policy(mut self, policy: PolicySpec) -> Self {
        self.default_policy = policy;
        self
    }

    /// Enable the admin `snapshot` op, persisting legacy JSON to `path`
    /// (a durable store supersedes this — the op checkpoints the store).
    pub fn snapshot_path(mut self, path: std::path::PathBuf) -> Self {
        self.snapshot_path = Some(path);
        self
    }

    /// Materialize the state. A leader resolves the durable store
    /// (recover an existing one, else bootstrap from the seed router),
    /// partitions the corpus, and starts the ingest pipeline threads
    /// (one dispatcher + one applier per shard). A follower instead
    /// attaches to the leader's store read-only (`persist_dir` is
    /// required, the seed router is discarded — the store is
    /// authoritative) and starts the tail loop.
    pub fn build(self) -> ServerState {
        let ServerBuilder {
            router,
            registry,
            embed,
            metrics,
            opts,
            default_policy,
            snapshot_path,
        } = self;
        if opts.role == Role::Follower {
            let dir = opts
                .persist_dir
                .as_deref()
                .expect("follower role requires [persist] dir (the leader's store)");
            let follower = Follower::open_with(dir, opts.epoch.clone(), opts.mmap)
                .expect("open leader store to follow");
            return ServerState::from_follower(follower, registry, embed, metrics, opts)
                .finish(default_policy, snapshot_path);
        }
        let durable_opts = DurableOptions {
            seal_bytes: opts.seal_bytes.max(1),
            fsync: opts.fsync,
            mmap: opts.mmap,
        };
        let (writer, durable) = match &opts.persist_dir {
            Some(dir) if DurableStore::exists(dir) => {
                // the store is authoritative: recover it and drop the
                // passed router without partitioning it first
                let (store, recovery) =
                    DurableStore::open(dir, durable_opts).expect("open durable store");
                let writer = recovery
                    .into_router(opts.epoch.clone())
                    .expect("recover durable store");
                (writer, Some(store))
            }
            Some(dir) => {
                let writer =
                    ShardedRouter::from_router(router, opts.epoch.clone(), opts.shards.clone());
                let store = DurableStore::create_from_router(dir, &writer, durable_opts)
                    .expect("create durable store");
                (writer, Some(store))
            }
            None => (
                ShardedRouter::from_router(router, opts.epoch.clone(), opts.shards.clone()),
                None,
            ),
        };
        ServerState::from_sharded(writer, durable, registry, embed, metrics, opts)
            .finish(default_policy, snapshot_path)
    }
}

impl ServerState {
    /// Start building a state: topology → options → policy →
    /// [`ServerBuilder::build`]. Defaults match `ServerOptions::default()`
    /// with an unconstrained default policy.
    pub fn builder(
        router: EagleRouter<FlatStore>,
        registry: ModelRegistry,
        embed: EmbedHandle,
        metrics: Arc<Metrics>,
    ) -> ServerBuilder {
        ServerBuilder {
            router,
            registry,
            embed,
            metrics,
            opts: ServerOptions::default(),
            default_policy: PolicySpec::unbounded(),
            snapshot_path: None,
        }
    }

    /// Wire a state around an explicit sharded writer (recovered or
    /// pre-partitioned): install the kernel backend, attach the durable
    /// sink, start the pipeline.
    fn from_sharded(
        mut writer: ShardedRouter,
        durable: Option<Arc<DurableStore>>,
        registry: ModelRegistry,
        embed: EmbedHandle,
        metrics: Arc<Metrics>,
        opts: ServerOptions,
    ) -> Self {
        // install the configured scoring-kernel default before the first
        // scan resolves the dispatch (EAGLE_KERNEL still wins; config
        // validation already rejected unknown names)
        if let Err(e) = crate::vectordb::kernel::configure(&opts.kernel_backend) {
            eprintln!("warning: [kernel] backend ignored: {e}");
        }
        let quant = resolved_quant(opts.quant);
        writer.set_ivf(opts.ivf.clone());
        writer.set_quant(quant);
        let snapshots = writer.handle();
        let ingest_metrics = Arc::new(IngestMetrics::new(writer.shard_count()));
        // the durable store always rides the pipeline (inline appends);
        // the interval only paces the checkpoint beat
        let persist = durable.as_ref().map(|store| PersistTarget {
            store: store.clone(),
            interval: Duration::from_millis(opts.persist_interval_ms),
        });
        let ingest = IngestPipeline::start_with_metrics(
            writer,
            Some(embed.clone()),
            IngestOptions { epoch: opts.epoch.clone(), persist, ..Default::default() },
            Some(ingest_metrics.clone()),
        );
        let compact_interval = Duration::from_millis(opts.compact_interval_ms);
        let gc_grace = Duration::from_millis(opts.gc_grace_ms);
        let compactor = durable
            .as_ref()
            .filter(|_| opts.compact_interval_ms > 0)
            .map(|store| CompactorHandle::spawn(store.clone(), compact_interval, gc_grace));
        let policy = RoutePolicy::new(&registry);
        ServerState {
            snapshots,
            role: RwLock::new(RoleState::Leader { ingest }),
            ingest_metrics,
            registry,
            policy,
            default_policy: PolicySpec::unbounded(),
            embed,
            metrics,
            sampler: ComparisonSampler::default(),
            snapshot_path: None,
            durable: RwLock::new(durable),
            admission: opts.admission,
            shed: Arc::new(shed::ShedMetrics::new()),
            epoch: opts.epoch,
            ivf: opts.ivf,
            quant,
            persist_interval_ms: opts.persist_interval_ms,
            durable_opts: DurableOptions {
                seal_bytes: opts.seal_bytes.max(1),
                fsync: opts.fsync,
                mmap: opts.mmap,
            },
            replica_poll: Duration::from_millis(opts.replica_poll_ms.max(1)),
            replica_backoff_max: Duration::from_millis(opts.replica_backoff_max_ms),
            compactor: Mutex::new(compactor),
            compact_interval,
            gc_grace,
            stop: AtomicBool::new(false),
        }
    }

    /// Wire a follower state: same kernel/quant resolution as the leader
    /// path, but the route path reads the follower's replica lanes and
    /// the role half is the tail loop, not a pipeline. The ingest metrics
    /// handle exists from the start (all zeros) so `stats` keeps one
    /// shape and promotion can hand it to the new pipeline.
    fn from_follower(
        follower: Follower,
        registry: ModelRegistry,
        embed: EmbedHandle,
        metrics: Arc<Metrics>,
        opts: ServerOptions,
    ) -> Self {
        if let Err(e) = crate::vectordb::kernel::configure(&opts.kernel_backend) {
            eprintln!("warning: [kernel] backend ignored: {e}");
        }
        let quant = resolved_quant(opts.quant);
        let shard_count = follower.meta().shards.count;
        let snapshots = follower.handle();
        let ingest_metrics = Arc::new(IngestMetrics::new(shard_count));
        let replica_poll = Duration::from_millis(opts.replica_poll_ms.max(1));
        let replica_backoff_max = Duration::from_millis(opts.replica_backoff_max_ms);
        let tail = FollowerHandle::spawn(follower, replica_poll, replica_backoff_max);
        let policy = RoutePolicy::new(&registry);
        ServerState {
            snapshots,
            role: RwLock::new(RoleState::Follower { tail }),
            ingest_metrics,
            registry,
            policy,
            default_policy: PolicySpec::unbounded(),
            embed,
            metrics,
            sampler: ComparisonSampler::default(),
            snapshot_path: None,
            durable: RwLock::new(None),
            admission: opts.admission,
            shed: Arc::new(shed::ShedMetrics::new()),
            epoch: opts.epoch,
            ivf: opts.ivf,
            quant,
            persist_interval_ms: opts.persist_interval_ms,
            durable_opts: DurableOptions {
                seal_bytes: opts.seal_bytes.max(1),
                fsync: opts.fsync,
                mmap: opts.mmap,
            },
            replica_poll,
            replica_backoff_max,
            compactor: Mutex::new(None),
            compact_interval: Duration::from_millis(opts.compact_interval_ms),
            gc_grace: Duration::from_millis(opts.gc_grace_ms),
            stop: AtomicBool::new(false),
        }
    }

    fn finish(
        mut self,
        default_policy: PolicySpec,
        snapshot_path: Option<std::path::PathBuf>,
    ) -> Self {
        self.default_policy = default_policy;
        self.snapshot_path = snapshot_path;
        self
    }

    /// The attached durable store, if `[persist] dir` is configured
    /// (`None` on a follower until promotion).
    pub fn durable_store(&self) -> Option<Arc<DurableStore>> {
        self.durable.read().unwrap().clone()
    }

    /// Ingest-side progress counters (queued/applied/dropped, per
    /// shard). Stable across promotion; all zeros while following.
    pub fn ingest_metrics(&self) -> &Arc<IngestMetrics> {
        &self.ingest_metrics
    }

    /// The current serving role (may flip Follower → Leader via the
    /// `promote` op).
    pub fn role(&self) -> Role {
        match &*self.role.read().unwrap() {
            RoleState::Leader { .. } => Role::Leader,
            RoleState::Follower { .. } => Role::Follower,
        }
    }

    /// Run `f` against the ingest pipeline, or `None` while following.
    fn with_leader<R>(&self, f: impl FnOnce(&IngestPipeline) -> R) -> Option<R> {
        match &*self.role.read().unwrap() {
            RoleState::Leader { ingest } => Some(f(ingest)),
            RoleState::Follower { .. } => None,
        }
    }

    /// The typed redirect error every mutating op gets on a follower.
    fn not_leader(&self, op: &str) -> Response {
        self.metrics.errors.inc();
        Response::NotLeader {
            message: format!("{op} requires the leader (this replica is a follower)"),
        }
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // join the compactor before the pipeline: a mid-merge publish
        // racing shutdown is harmless, but joining here keeps shutdown
        // deterministic
        drop(self.compactor.lock().unwrap().take());
        match &mut *self.role.write().unwrap() {
            // closes the intake, drains + publishes the tails, joins the
            // pipeline threads (idempotent)
            RoleState::Leader { ingest } => ingest.shutdown(),
            RoleState::Follower { tail } => {
                tail.stop();
            }
        }
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Barrier: apply and publish everything ingested so far — every
    /// shard lane and the shared global table (tests / admin; the
    /// appliers publish on cadence by themselves). Returns the highest
    /// shard epoch. On a follower this is just the current epoch — the
    /// tail loop publishes on its own cadence.
    pub fn force_publish(&self) -> u64 {
        self.with_leader(|ingest| ingest.flush());
        self.snapshots.shard_epochs().into_iter().max().unwrap_or(0)
    }

    /// The `promote` op: follower → leader. Stops the tail loop, takes
    /// the advisory lock (refused while the old leader is alive),
    /// repairs + attaches the durable store, and starts the ingest
    /// pipeline over the follower's own lanes — route readers never see
    /// a gap. Idempotent on a leader. On failure the tail loop restarts
    /// and the error is returned.
    fn promote(&self) -> Response {
        let mut role = self.role.write().unwrap();
        let RoleState::Follower { tail } = &mut *role else {
            return Response::Promoted { role: Role::Leader.as_str().to_string() };
        };
        let Some(follower) = tail.stop() else {
            self.metrics.errors.inc();
            return Response::Error("promote: tail loop already stopped".into());
        };
        match follower.promote(self.durable_opts.clone()) {
            Ok(Promotion { store, mut router }) => {
                router.set_ivf(self.ivf.clone());
                router.set_quant(self.quant);
                let persist = Some(PersistTarget {
                    store: store.clone(),
                    interval: Duration::from_millis(self.persist_interval_ms),
                });
                let ingest = IngestPipeline::start_with_metrics(
                    router,
                    Some(self.embed.clone()),
                    IngestOptions { epoch: self.epoch.clone(), persist, ..Default::default() },
                    Some(self.ingest_metrics.clone()),
                );
                if self.compact_interval > Duration::ZERO {
                    *self.compactor.lock().unwrap() = Some(CompactorHandle::spawn(
                        store.clone(),
                        self.compact_interval,
                        self.gc_grace,
                    ));
                }
                *self.durable.write().unwrap() = Some(store);
                *role = RoleState::Leader { ingest };
                Response::Promoted { role: Role::Leader.as_str().to_string() }
            }
            Err(e) => {
                self.metrics.errors.inc();
                let msg = format!("promote: {:#}", e.error);
                *role = RoleState::Follower {
                    tail: FollowerHandle::spawn(
                        e.follower,
                        self.replica_poll,
                        self.replica_backoff_max,
                    ),
                };
                Response::Error(msg)
            }
        }
    }

    /// Gather the versioned stats report — the one place every section
    /// (server, ingest, shed, kernel/quant, replica) is assembled; the
    /// `stats` op and the CLI both serialize from here.
    pub fn stats_report(&self) -> stats::StatsReport {
        let (role, replica) = match &*self.role.read().unwrap() {
            RoleState::Leader { .. } => (Role::Leader, None),
            RoleState::Follower { tail } => {
                let m = tail.metrics();
                (
                    Role::Follower,
                    Some(stats::ReplicaSection {
                        lag_frames: m.lag_frames(),
                        lag_bytes: m.lag_bytes(),
                        manifest_generation: m.manifest_generation(),
                        applied_records: m.applied_records.get(),
                        polls: m.polls.get(),
                        poll_ms_effective: m.effective_poll_ms(),
                        manifest_restarts: m.manifest_restarts.get(),
                    }),
                )
            }
        };
        let durable = self.durable.read().unwrap().as_ref().map(|store| {
            let c = store.compaction_stats();
            stats::DurableSection {
                segments: store.total_segments() as u64,
                generation: store.generation(),
                merges: c.merges.get(),
                upgrades: c.upgrades.get(),
                gc_files: c.gc_files.get(),
                errors: c.errors.get(),
                gc_pending: store.retired_pending() as u64,
            }
        });
        stats::StatsReport {
            version: stats::STATS_VERSION,
            role: role.as_str(),
            kernel: crate::vectordb::kernel::active().name(),
            quant: self.quant.enable,
            server: self.metrics.report(),
            ingest: self.ingest_metrics.report(),
            shed: self.shed.report(),
            replica,
            durable,
        }
    }

    /// Route a slab of texts: one embed round trip, one snapshot
    /// acquisition, `texts.len()` scored decisions. `specs` is parallel
    /// to `texts` ([`PolicySpec`] is `Copy`, so per-query policies ride
    /// the batch without allocating).
    fn route_many(
        &self,
        texts: &[&str],
        specs: &[PolicySpec],
        rng: &mut Rng,
    ) -> Result<Vec<RouteReply>, String> {
        debug_assert_eq!(texts.len(), specs.len());
        let t0 = Instant::now();
        self.metrics.requests.add(texts.len() as u64);
        let embs = match self.embed.embed_many(texts) {
            Ok(e) => e,
            Err(e) => {
                self.metrics.errors.add(texts.len() as u64);
                return Err(format!("embed: {e}"));
            }
        };
        let snap: ShardedSnapshot = self.snapshots.load();
        let ratings = snap.global_ratings();
        let replies = snap
            .score_batch(&embs)
            .into_iter()
            .zip(specs.iter().zip(texts))
            .map(|(scores, (&spec, text))| {
                let choice = self.policy.select_spec(&scores, spec, approx_tokens(text));
                let compare_with = self
                    .sampler
                    .pick_partner(rng, choice, ratings)
                    .map(|m| self.registry.entry(m).name.clone());
                RouteReply {
                    model: self.registry.entry(choice).name.clone(),
                    model_index: choice,
                    compare_with,
                    expected_cost: self.registry.entry(choice).expected_cost,
                }
            })
            .collect();
        // per-decision latency: the batch amortizes embed + snapshot load
        let per = t0.elapsed() / texts.len().max(1) as u32;
        for _ in 0..texts.len() {
            self.metrics.route_latency.record(per);
        }
        Ok(replies)
    }

    /// Handle one parsed request (shared by TCP handler and tests).
    pub fn handle(&self, req: Request, rng: &mut Rng) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Hello => Response::hello(self.role().as_str()),
            Request::Promote => self.promote(),
            Request::Snapshot => {
                if self.role() == Role::Follower {
                    return self.not_leader("snapshot");
                }
                match (self.durable_store(), &self.snapshot_path) {
                    (Some(store), _) => {
                        // the durable store rides the op: flush + fsync
                        // every delta log and advance the global
                        // checkpoint — O(unsynced records), not O(corpus)
                        if self.with_leader(|i| i.persist_now()) == Some(true) {
                            let entries = self.snapshots.load().store_len() as u64;
                            Response::SnapshotSaved {
                                path: store.dir().display().to_string(),
                                entries,
                            }
                        } else {
                            self.metrics.errors.inc();
                            Response::Error("snapshot: ingest pipeline is shut down".into())
                        }
                    }
                    (None, None) => {
                        Response::Error("snapshot op disabled (no path configured)".into())
                    }
                    (None, Some(path)) => {
                        // flush the pipeline so the persisted snapshot
                        // covers everything accepted before this op, then
                        // write the published state — no writer lane is
                        // ever locked
                        self.with_leader(|i| i.flush());
                        let snap = self.snapshots.load();
                        let entries = snap.store_len() as u64;
                        match snap.persist(path) {
                            Ok(()) => Response::SnapshotSaved {
                                path: path.display().to_string(),
                                entries,
                            },
                            Err(e) => {
                                self.metrics.errors.inc();
                                Response::Error(format!("snapshot: {e}"))
                            }
                        }
                    }
                }
            }
            Request::Stats => Response::Stats {
                report: self.stats_report().render(),
                requests: self.metrics.requests.get(),
                feedback: self.metrics.feedback.get(),
            },
            Request::Route { text, spec } => {
                let spec = spec.unwrap_or(self.default_policy);
                match self.route_many(&[text.as_str()], &[spec], rng) {
                    Ok(mut replies) => {
                        let r = replies.pop().expect("one reply per text");
                        Response::Routed {
                            model: r.model,
                            model_index: r.model_index,
                            compare_with: r.compare_with,
                            expected_cost: r.expected_cost,
                        }
                    }
                    Err(e) => Response::Error(e),
                }
            }
            Request::RouteBatch { texts, spec } => {
                let spec = spec.unwrap_or(self.default_policy);
                let refs: Vec<&str> = texts.iter().map(|t| t.as_str()).collect();
                let specs = vec![spec; refs.len()];
                match self.route_many(&refs, &specs, rng) {
                    Ok(replies) => Response::RoutedBatch(replies),
                    Err(e) => Response::Error(e),
                }
            }
            Request::Feedback { text, model_a, model_b, score_a } => {
                let (Some(a), Some(b)) =
                    (self.registry.index_of(&model_a), self.registry.index_of(&model_b))
                else {
                    self.metrics.errors.inc();
                    self.ingest_metrics.dropped_unknown_model.inc();
                    return Response::Error(format!(
                        "unknown model in feedback: {model_a} / {model_b}"
                    ));
                };
                if a == b {
                    self.metrics.errors.inc();
                    return Response::Error("feedback: model_a == model_b".into());
                }
                if ![0.0, 0.5, 1.0].contains(&score_a) {
                    self.metrics.errors.inc();
                    return Response::Error("feedback: score_a must be 0, 0.5 or 1".into());
                }
                // enqueue the raw text; the ingest pipeline embeds it on
                // the applier side (batched through the PJRT bucket path)
                let verdict = RawVerdict { text, model_a: a, model_b: b, score_a };
                match self.with_leader(|i| i.push_raw(verdict)) {
                    Some(true) => {
                        self.metrics.feedback.inc();
                        Response::FeedbackAccepted
                    }
                    Some(false) => {
                        self.metrics.errors.inc();
                        Response::Error("feedback dropped: ingest queue full".into())
                    }
                    None => self.not_leader("feedback"),
                }
            }
        }
    }

    /// Handle a pipelined batch of request lines, preserving order.
    /// All single `route` requests in the batch are served together
    /// through [`ServerState::route_many`].
    pub fn handle_lines(&self, lines: &[String], rng: &mut Rng) -> Vec<Response> {
        let parsed: Vec<Result<Request, String>> = lines.iter().map(|l| parse_request(l)).collect();
        let mut out: Vec<Option<Response>> = (0..lines.len()).map(|_| None).collect();

        // co-batch the single routes (2+ makes the amortization worth it);
        // per-query specs resolve against the server default here, so the
        // co-batched path and the one-off path pick identically
        let routes: Vec<(usize, String, PolicySpec)> = parsed
            .iter()
            .enumerate()
            .filter_map(|(i, r)| match r {
                Ok(Request::Route { text, spec }) => {
                    Some((i, text.clone(), spec.unwrap_or(self.default_policy)))
                }
                _ => None,
            })
            .collect();
        if routes.len() >= 2 {
            let texts: Vec<&str> = routes.iter().map(|(_, t, _)| t.as_str()).collect();
            let specs: Vec<PolicySpec> = routes.iter().map(|(_, _, s)| *s).collect();
            match self.route_many(&texts, &specs, rng) {
                Ok(replies) => {
                    for ((i, _, _), r) in routes.iter().zip(replies) {
                        out[*i] = Some(Response::Routed {
                            model: r.model,
                            model_index: r.model_index,
                            compare_with: r.compare_with,
                            expected_cost: r.expected_cost,
                        });
                    }
                }
                Err(e) => {
                    for (i, _, _) in &routes {
                        out[*i] = Some(Response::Error(e.clone()));
                    }
                }
            }
        }

        for (i, req) in parsed.into_iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            out[i] = Some(match req {
                Ok(r) => self.handle(r, rng),
                Err(e) => {
                    self.metrics.errors.inc();
                    Response::Error(e)
                }
            });
        }
        out.into_iter().map(|r| r.expect("every line answered")).collect()
    }
}

/// `[quant]` with the `EAGLE_QUANT` env override applied — CI's
/// quantized arm flips SQ8 publication on without a config edit,
/// mirroring `EAGLE_KERNEL` (the shared [`crate::config::env_override`]
/// rule: a malformed value warns and keeps the configured setting).
fn resolved_quant(configured: QuantParams) -> QuantParams {
    let enable = crate::config::env_override(
        "EAGLE_QUANT",
        "[quant] enable",
        configured.enable,
        |s| match s {
            "1" | "true" | "on" | "yes" => Ok(true),
            "0" | "false" | "off" | "no" => Ok(false),
            _ => Err(format!("bad value '{s}' (expected 1|0)")),
        },
    );
    QuantParams { enable, ..configured }
}

/// The running server: one event-loop thread owning every socket plus
/// an execution worker pool ([`event_loop`]). Feedback application
/// lives in the state's [`IngestPipeline`], not here.
pub struct Server {
    pub state: Arc<ServerState>,
    pub addr: std::net::SocketAddr,
    /// Writing a byte wakes the event loop out of its poll.
    wake: std::os::unix::net::UnixStream,
    loop_thread: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on `addr` ("127.0.0.1:0" picks a free
    /// port). `workers` sizes the execution pool; connection fan-in is
    /// the event loop, so idle connections hold no worker. Admission
    /// limits come from the state's [`Admission`].
    pub fn start(state: Arc<ServerState>, addr: &str, workers: usize) -> Result<Server> {
        let handles = event_loop::start(state.clone(), addr, workers)?;
        Ok(Server {
            state,
            addr: handles.addr,
            wake: handles.wake,
            loop_thread: Some(handles.loop_thread),
            workers: handles.workers,
        })
    }

    /// Signal shutdown and join all threads (including the ingest
    /// pipeline, which publishes everything already accepted).
    pub fn shutdown(mut self) {
        self.state.stop();
        // wake the event loop out of its poll; it drops the job sender
        // on exit, which drains the worker pool
        let _ = (&self.wake).write_all(&[1u8]);
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EagleParams;
    use crate::embedding::{BatcherOptions, EmbedService};

    // In-process handler tests that need no artifacts are below; full TCP
    // round-trips live in rust/tests/server_e2e.rs (hash-embedder-backed
    // tests run everywhere; PJRT ones skip without artifacts).

    #[test]
    fn state_rejects_bad_feedback_models() {
        // Use a stats/ping-only state: embed handle requires artifacts, so
        // construct is deferred to e2e tests; here we exercise pure logic.
        // (Request::Stats and parse-level validation are covered in
        // protocol tests.)
        let req = parse_request(r#"{"op":"feedback","text":"t","model_a":"gpt-4","model_b":"gpt-4","score_a":1}"#).unwrap();
        match req {
            Request::Feedback { model_a, model_b, .. } => assert_eq!(model_a, model_b),
            _ => panic!(),
        }
    }

    #[test]
    fn server_struct_is_send() {
        fn assert_send<T: Send + Sync>() {}
        assert_send::<ServerState>();
        let _ = EagleParams::default();
        let _ = BatcherOptions::default();
        let _: Option<EmbedService> = None;
    }

    #[test]
    fn server_options_default_matches_config_defaults() {
        let opts = ServerOptions::default();
        assert_eq!(opts.epoch, EpochParams::default());
        assert_eq!(opts.shards, ShardParams::default());
        assert_eq!(opts.ivf, IvfPublishParams::default());
        assert_eq!(opts.quant, crate::config::QuantParams::default());
        assert!(!opts.quant.enable, "quantization must be opt-in");
        assert_eq!(opts.persist_interval_ms, 0);
        assert!(opts.persist_dir.is_none());
        let durable = DurableOptions::default();
        assert_eq!(opts.seal_bytes, durable.seal_bytes);
        assert_eq!(opts.fsync, durable.fsync);
        assert_eq!(opts.mmap, durable.mmap);
        let persist = crate::config::PersistParams::default();
        assert_eq!(opts.seal_bytes, persist.seal_bytes);
        assert_eq!(opts.fsync, persist.fsync);
        assert_eq!(opts.mmap, persist.mmap);
        assert_eq!(opts.compact_interval_ms, persist.compact_interval_ms);
        assert_eq!(opts.gc_grace_ms, persist.gc_grace_ms);
        let server = crate::config::ServerParams::default();
        assert_eq!(opts.admission.max_connections, server.max_connections);
        assert_eq!(opts.admission.max_inflight, server.max_inflight);
        assert_eq!(opts.admission.idle_timeout_ms, server.idle_timeout_ms);
        assert_eq!(opts.admission, Admission::default());
        let replica = crate::config::ReplicaParams::default();
        assert_eq!(opts.role, Role::Leader);
        assert_eq!(opts.role.as_str(), replica.role);
        assert_eq!(opts.replica_poll_ms, replica.poll_ms);
        assert_eq!(opts.replica_backoff_max_ms, replica.backoff_max_ms);
    }
}
