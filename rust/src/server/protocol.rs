//! Wire protocol: newline-delimited JSON over TCP.
//!
//! Requests (client -> server), one JSON object per line:
//!
//! ```json
//! {"op":"route","text":"...","budget":0.02}
//! {"op":"route_batch","texts":["...","..."],"budget":0.02}
//! {"op":"feedback","text":"...","model_a":"gpt-4","model_b":"claude-v2","score_a":1.0}
//! {"op":"stats"}
//! {"op":"ping"}
//! ```
//!
//! Responses mirror the request with `"ok":true` or carry
//! `{"ok":false,"error":"..."}`.

use crate::json::{self, Value};

/// Largest accepted `route_batch` request (also the cap on server-side
/// pipelined batching); keeps one request from monopolizing the embedder.
pub const MAX_ROUTE_BATCH: usize = 256;

/// Parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Route { text: String, budget: f64 },
    /// Batched routing: all texts share one budget; one embed round trip
    /// and one snapshot acquisition serve the whole batch.
    RouteBatch { texts: Vec<String>, budget: f64 },
    Feedback { text: String, model_a: String, model_b: String, score_a: f64 },
    Stats,
    Ping,
    /// Admin: persist router state to the server-configured snapshot path.
    Snapshot,
}

/// One routed decision (shared by single and batch responses).
#[derive(Debug, Clone, PartialEq)]
pub struct RouteReply {
    pub model: String,
    pub model_index: usize,
    /// Optional comparison partner (paper workflow step 5).
    pub compare_with: Option<String>,
    /// Expected $ cost of the chosen model.
    pub expected_cost: f64,
}

/// Server response payload.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Routed {
        model: String,
        model_index: usize,
        /// Optional comparison partner (paper workflow step 5).
        compare_with: Option<String>,
        /// Expected $ cost of the chosen model.
        expected_cost: f64,
    },
    /// One decision per text of a `route_batch`, in request order.
    RoutedBatch(Vec<RouteReply>),
    FeedbackAccepted,
    Stats { report: String, requests: u64, feedback: u64 },
    Pong,
    /// Snapshot written: path + number of stored prompts.
    SnapshotSaved { path: String, entries: u64 },
    Error(String),
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = json::parse(line.trim()).map_err(|e| format!("bad json: {e}"))?;
    match v.get("op").as_str() {
        Some("route") => {
            let text = v
                .get("text")
                .as_str()
                .ok_or("route: missing text")?
                .to_string();
            let budget = v.get("budget").as_f64().ok_or("route: missing budget")?;
            if !budget.is_finite() || budget < 0.0 {
                return Err("route: budget must be a non-negative number".into());
            }
            Ok(Request::Route { text, budget })
        }
        Some("route_batch") => {
            let texts: Vec<String> = v
                .get("texts")
                .as_arr()
                .ok_or("route_batch: missing texts")?
                .iter()
                .map(|t| t.as_str().map(|s| s.to_string()))
                .collect::<Option<_>>()
                .ok_or("route_batch: texts must be strings")?;
            if texts.is_empty() {
                return Err("route_batch: texts must be non-empty".into());
            }
            if texts.len() > MAX_ROUTE_BATCH {
                return Err(format!("route_batch: at most {MAX_ROUTE_BATCH} texts"));
            }
            let budget = v.get("budget").as_f64().ok_or("route_batch: missing budget")?;
            if !budget.is_finite() || budget < 0.0 {
                return Err("route_batch: budget must be a non-negative number".into());
            }
            Ok(Request::RouteBatch { texts, budget })
        }
        Some("feedback") => Ok(Request::Feedback {
            text: v.get("text").as_str().ok_or("feedback: missing text")?.to_string(),
            model_a: v
                .get("model_a")
                .as_str()
                .ok_or("feedback: missing model_a")?
                .to_string(),
            model_b: v
                .get("model_b")
                .as_str()
                .ok_or("feedback: missing model_b")?
                .to_string(),
            score_a: v.get("score_a").as_f64().ok_or("feedback: missing score_a")?,
        }),
        Some("stats") => Ok(Request::Stats),
        Some("ping") => Ok(Request::Ping),
        Some("snapshot") => Ok(Request::Snapshot),
        Some(op) => Err(format!("unknown op '{op}'")),
        None => Err("missing op".into()),
    }
}

/// Serialize a response to one line (no trailing newline).
pub fn encode_response(r: &Response) -> String {
    match r {
        Response::Routed { model, model_index, compare_with, expected_cost } => {
            let mut fields = vec![
                ("ok", Value::Bool(true)),
                ("model", json::str_v(model)),
                ("model_index", json::num(*model_index as f64)),
                ("expected_cost", json::num(*expected_cost)),
            ];
            if let Some(c) = compare_with {
                fields.push(("compare_with", json::str_v(c)));
            }
            json::obj(fields).to_json()
        }
        Response::RoutedBatch(replies) => {
            let items: Vec<Value> = replies
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("model", json::str_v(&r.model)),
                        ("model_index", json::num(r.model_index as f64)),
                        ("expected_cost", json::num(r.expected_cost)),
                    ];
                    if let Some(c) = &r.compare_with {
                        fields.push(("compare_with", json::str_v(c)));
                    }
                    json::obj(fields)
                })
                .collect();
            json::obj(vec![("ok", Value::Bool(true)), ("batch", Value::Arr(items))]).to_json()
        }
        Response::FeedbackAccepted => {
            json::obj(vec![("ok", Value::Bool(true)), ("accepted", Value::Bool(true))]).to_json()
        }
        Response::Stats { report, requests, feedback } => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("report", json::str_v(report)),
            ("requests", json::num(*requests as f64)),
            ("feedback", json::num(*feedback as f64)),
        ])
        .to_json(),
        Response::Pong => {
            json::obj(vec![("ok", Value::Bool(true)), ("pong", Value::Bool(true))]).to_json()
        }
        Response::SnapshotSaved { path, entries } => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("snapshot", json::str_v(path)),
            ("entries", json::num(*entries as f64)),
        ])
        .to_json(),
        Response::Error(msg) => {
            json::obj(vec![("ok", Value::Bool(false)), ("error", json::str_v(msg))]).to_json()
        }
    }
}

/// Parse a response line (client side).
pub fn parse_response(line: &str) -> Result<Response, String> {
    let v = json::parse(line.trim()).map_err(|e| format!("bad json: {e}"))?;
    if v.get("ok").as_bool() != Some(true) {
        return Ok(Response::Error(
            v.get("error").as_str().unwrap_or("unknown error").to_string(),
        ));
    }
    if v.get("pong").as_bool() == Some(true) {
        return Ok(Response::Pong);
    }
    if v.get("accepted").as_bool() == Some(true) {
        return Ok(Response::FeedbackAccepted);
    }
    if let Some(items) = v.get("batch").as_arr() {
        let replies = items
            .iter()
            .map(|r| {
                Ok(RouteReply {
                    model: r.get("model").as_str().ok_or("batch item: missing model")?.to_string(),
                    model_index: r
                        .get("model_index")
                        .as_usize()
                        .ok_or("batch item: missing model_index")?,
                    compare_with: r.get("compare_with").as_str().map(|s| s.to_string()),
                    expected_cost: r.get("expected_cost").as_f64().unwrap_or(0.0),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        return Ok(Response::RoutedBatch(replies));
    }
    if let Some(path) = v.get("snapshot").as_str() {
        return Ok(Response::SnapshotSaved {
            path: path.to_string(),
            entries: v.get("entries").as_f64().unwrap_or(0.0) as u64,
        });
    }
    if let Some(model) = v.get("model").as_str() {
        return Ok(Response::Routed {
            model: model.to_string(),
            model_index: v.get("model_index").as_usize().ok_or("missing model_index")?,
            compare_with: v.get("compare_with").as_str().map(|s| s.to_string()),
            expected_cost: v.get("expected_cost").as_f64().unwrap_or(0.0),
        });
    }
    if !v.get("report").is_null() {
        return Ok(Response::Stats {
            report: v.get("report").as_str().unwrap_or("").to_string(),
            requests: v.get("requests").as_f64().unwrap_or(0.0) as u64,
            feedback: v.get("feedback").as_f64().unwrap_or(0.0) as u64,
        });
    }
    Err("unrecognized response".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_route() {
        let r = parse_request(r#"{"op":"route","text":"hi","budget":0.5}"#).unwrap();
        assert_eq!(r, Request::Route { text: "hi".into(), budget: 0.5 });
    }

    #[test]
    fn parse_feedback() {
        let r = parse_request(
            r#"{"op":"feedback","text":"q","model_a":"a","model_b":"b","score_a":0.5}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Feedback {
                text: "q".into(),
                model_a: "a".into(),
                model_b: "b".into(),
                score_a: 0.5
            }
        );
    }

    #[test]
    fn parse_route_batch() {
        let r = parse_request(r#"{"op":"route_batch","texts":["a","b"],"budget":0.1}"#).unwrap();
        assert_eq!(
            r,
            Request::RouteBatch { texts: vec!["a".into(), "b".into()], budget: 0.1 }
        );
        assert!(parse_request(r#"{"op":"route_batch","texts":[],"budget":0.1}"#).is_err());
        assert!(parse_request(r#"{"op":"route_batch","texts":[1],"budget":0.1}"#).is_err());
        assert!(parse_request(r#"{"op":"route_batch","budget":0.1}"#).is_err());
        assert!(parse_request(r#"{"op":"route_batch","texts":["a"],"budget":-1}"#).is_err());
    }

    #[test]
    fn response_roundtrip_routed_batch() {
        let r = Response::RoutedBatch(vec![
            RouteReply {
                model: "gpt-4".into(),
                model_index: 0,
                compare_with: Some("claude-v2".into()),
                expected_cost: 0.03,
            },
            RouteReply {
                model: "mistral-7b-chat".into(),
                model_index: 3,
                compare_with: None,
                expected_cost: 0.0004,
            },
        ]);
        assert_eq!(parse_response(&encode_response(&r)).unwrap(), r);
    }

    #[test]
    fn parse_snapshot_op() {
        assert_eq!(parse_request(r#"{"op":"snapshot"}"#).unwrap(), Request::Snapshot);
    }

    #[test]
    fn parse_rejects_bad_requests() {
        assert!(parse_request("{}").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"route","text":"x"}"#).is_err());
        assert!(parse_request(r#"{"op":"route","text":"x","budget":-1}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn response_roundtrip_routed() {
        let r = Response::Routed {
            model: "gpt-4".into(),
            model_index: 0,
            compare_with: Some("claude-v2".into()),
            expected_cost: 0.03,
        };
        assert_eq!(parse_response(&encode_response(&r)).unwrap(), r);
        let r2 = Response::Routed {
            model: "gpt-4".into(),
            model_index: 0,
            compare_with: None,
            expected_cost: 0.03,
        };
        assert_eq!(parse_response(&encode_response(&r2)).unwrap(), r2);
    }

    #[test]
    fn response_roundtrip_others() {
        for r in [
            Response::FeedbackAccepted,
            Response::Pong,
            Response::Stats { report: "r".into(), requests: 5, feedback: 2 },
            Response::SnapshotSaved { path: "/tmp/x.json".into(), entries: 42 },
            Response::Error("boom".into()),
        ] {
            assert_eq!(parse_response(&encode_response(&r)).unwrap(), r);
        }
    }

    #[test]
    fn unicode_text_survives() {
        let line = encode_response(&Response::Error("caf\u{e9} \u{1F600}".into()));
        match parse_response(&line).unwrap() {
            Response::Error(e) => assert_eq!(e, "caf\u{e9} \u{1F600}"),
            _ => panic!(),
        }
    }
}
