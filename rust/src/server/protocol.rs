//! Wire protocol: newline-delimited JSON over TCP, versioned.
//!
//! Requests (client -> server), one JSON object per line:
//!
//! ```json
//! {"op":"route","text":"...","budget":0.02}
//! {"v":2,"op":"hello"}
//! {"v":2,"op":"route","text":"...","policy":"cost_aware","budget":0.02}
//! {"v":2,"op":"route","text":"...","policy":"threshold","threshold":0.6}
//! {"v":2,"op":"route_batch","texts":["...","..."],"budget":0.02}
//! {"op":"feedback","text":"...","model_a":"gpt-4","model_b":"claude-v2","score_a":1.0}
//! {"op":"stats"}
//! {"op":"ping"}
//! ```
//!
//! Responses mirror the request with `"ok":true` or carry
//! `{"ok":false,"error":"..."}`.
//!
//! ## Versioning rules
//!
//! - **No `v` field, or `v:1`** — protocol v1, the PR 6 wire format,
//!   parsed *leniently*: unknown fields are ignored, `budget` is
//!   required on routes. v1 clients keep working bit-identically.
//! - **`v:2`** — parsed *strictly*: unknown fields are rejected (so a
//!   misspelled knob fails loudly instead of silently routing with the
//!   default), `budget` becomes optional (`0`/absent means the server's
//!   configured default policy), and routes may carry a `policy` name
//!   (`budget`, `cost_aware`, `threshold`) plus its knobs.
//! - **Any other `v`** — rejected with an error naming the supported
//!   versions. Clients discover capabilities with the `hello` op, which
//!   reports the version, op list, policy list and batch cap.
//!
//! New fields are only ever *added* to responses, never renamed or
//! removed, so a v1 client parsing a v2 server's replies stays correct.

use crate::coordinator::policy::PolicySpec;
use crate::json::{self, Value};

/// Current (maximum) protocol version.
pub const PROTOCOL_VERSION: u32 = 2;

/// Largest accepted `route_batch` request (also the cap on server-side
/// pipelined batching); keeps one request from monopolizing the embedder.
pub const MAX_ROUTE_BATCH: usize = 256;

/// Op names advertised by `hello`, in stable order.
pub const OPS: &[&str] =
    &["hello", "route", "route_batch", "feedback", "stats", "ping", "snapshot", "promote"];

/// Policy names advertised by `hello`, in stable order.
pub const POLICIES: &[&str] = &["budget", "cost_aware", "threshold"];

/// Parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// `spec: None` means "use the server's configured default policy"
    /// (only expressible in protocol v2).
    Route { text: String, spec: Option<PolicySpec> },
    /// Batched routing: all texts share one policy spec; one embed round
    /// trip and one snapshot acquisition serve the whole batch.
    RouteBatch { texts: Vec<String>, spec: Option<PolicySpec> },
    Feedback { text: String, model_a: String, model_b: String, score_a: f64 },
    Stats,
    Ping,
    /// Admin: persist router state to the server-configured snapshot path.
    Snapshot,
    /// Capability discovery (v2): version, ops, policies, batch cap, role.
    Hello,
    /// Admin (v2): promote a follower replica to leader (idempotent on a
    /// leader).
    Promote,
}

/// One routed decision (shared by single and batch responses).
#[derive(Debug, Clone, PartialEq)]
pub struct RouteReply {
    pub model: String,
    pub model_index: usize,
    /// Optional comparison partner (paper workflow step 5).
    pub compare_with: Option<String>,
    /// Expected $ cost of the chosen model.
    pub expected_cost: f64,
}

/// Server response payload.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Routed {
        model: String,
        model_index: usize,
        /// Optional comparison partner (paper workflow step 5).
        compare_with: Option<String>,
        /// Expected $ cost of the chosen model.
        expected_cost: f64,
    },
    /// One decision per text of a `route_batch`, in request order.
    RoutedBatch(Vec<RouteReply>),
    FeedbackAccepted,
    Stats { report: String, requests: u64, feedback: u64 },
    Pong,
    /// Snapshot written: path + number of stored prompts.
    SnapshotSaved { path: String, entries: u64 },
    /// Capability report for `hello`.
    Hello {
        version: u32,
        ops: Vec<String>,
        policies: Vec<String>,
        max_route_batch: usize,
        /// Serving role: `"leader"` or `"follower"` (absent from pre-
        /// replication servers, which clients read as `"leader"`).
        role: String,
    },
    /// `promote` succeeded (or the server already was the leader).
    Promoted { role: String },
    /// Typed redirect: the op needs the leader and this replica is a
    /// follower. On the wire it is an error object with a `not_leader`
    /// marker, so v1/v2 clients that only know `error` still fail clean.
    NotLeader { message: String },
    Error(String),
}

impl Response {
    /// The server's capability report.
    pub fn hello(role: &str) -> Response {
        Response::Hello {
            version: PROTOCOL_VERSION,
            ops: OPS.iter().map(|s| s.to_string()).collect(),
            policies: POLICIES.iter().map(|s| s.to_string()).collect(),
            max_route_batch: MAX_ROUTE_BATCH,
            role: role.to_string(),
        }
    }
}

/// Parse one request line, dispatching on the `v` field per the
/// versioning rules in the module docs.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = json::parse(line.trim()).map_err(|e| format!("bad json: {e}"))?;
    let version = v.get("v");
    if version.is_null() {
        return parse_request_v1(&v);
    }
    match version.as_f64() {
        Some(x) if x == 1.0 => parse_request_v1(&v),
        Some(x) if x == 2.0 => parse_request_v2(&v),
        Some(x) => Err(format!("unsupported protocol version {x} (supported: 1, 2)")),
        None => Err("v must be a number".into()),
    }
}

/// The PR 6 wire format, bit-identical: lenient about unknown fields,
/// `budget` required on routes, no per-query policy choice.
fn parse_request_v1(v: &Value) -> Result<Request, String> {
    match v.get("op").as_str() {
        Some("route") => {
            let text = v
                .get("text")
                .as_str()
                .ok_or("route: missing text")?
                .to_string();
            let budget = v.get("budget").as_f64().ok_or("route: missing budget")?;
            if !budget.is_finite() || budget < 0.0 {
                return Err("route: budget must be a non-negative number".into());
            }
            Ok(Request::Route { text, spec: Some(PolicySpec::Budget { budget }) })
        }
        Some("route_batch") => {
            let texts = parse_texts(v)?;
            let budget = v.get("budget").as_f64().ok_or("route_batch: missing budget")?;
            if !budget.is_finite() || budget < 0.0 {
                return Err("route_batch: budget must be a non-negative number".into());
            }
            Ok(Request::RouteBatch { texts, spec: Some(PolicySpec::Budget { budget }) })
        }
        Some("feedback") => parse_feedback_fields(v),
        Some("stats") => Ok(Request::Stats),
        Some("ping") => Ok(Request::Ping),
        Some("snapshot") => Ok(Request::Snapshot),
        Some(op) => Err(format!("unknown op '{op}'")),
        None => Err("missing op".into()),
    }
}

/// Protocol v2: strict field validation, optional per-query policy.
fn parse_request_v2(v: &Value) -> Result<Request, String> {
    match v.get("op").as_str() {
        Some("route") => {
            check_fields(v, "route", &["v", "op", "text", "budget", "policy", "threshold"])?;
            let text = v
                .get("text")
                .as_str()
                .ok_or("route: missing text")?
                .to_string();
            Ok(Request::Route { text, spec: parse_spec(v, "route")? })
        }
        Some("route_batch") => {
            check_fields(
                v,
                "route_batch",
                &["v", "op", "texts", "budget", "policy", "threshold"],
            )?;
            let texts = parse_texts(v)?;
            Ok(Request::RouteBatch { texts, spec: parse_spec(v, "route_batch")? })
        }
        Some("feedback") => {
            check_fields(v, "feedback", &["v", "op", "text", "model_a", "model_b", "score_a"])?;
            parse_feedback_fields(v)
        }
        Some("stats") => check_fields(v, "stats", &["v", "op"]).map(|_| Request::Stats),
        Some("ping") => check_fields(v, "ping", &["v", "op"]).map(|_| Request::Ping),
        Some("snapshot") => check_fields(v, "snapshot", &["v", "op"]).map(|_| Request::Snapshot),
        Some("hello") => check_fields(v, "hello", &["v", "op"]).map(|_| Request::Hello),
        Some("promote") => check_fields(v, "promote", &["v", "op"]).map(|_| Request::Promote),
        Some(op) => Err(format!("unknown op '{op}'")),
        None => Err("missing op".into()),
    }
}

/// Strict v2 field check: any key outside `allowed` is an error, so a
/// misspelled knob can't silently fall back to defaults.
fn check_fields(v: &Value, op: &str, allowed: &[&str]) -> Result<(), String> {
    let obj = v.as_obj().ok_or("request must be a json object")?;
    for k in obj.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(format!("{op}: unknown field '{k}'"));
        }
    }
    Ok(())
}

/// v2 policy fields -> spec. Absent policy *and* budget means "server
/// default" (`None`); a bare budget selects the budget policy; the
/// threshold policy requires its `threshold` knob.
fn parse_spec(v: &Value, op: &str) -> Result<Option<PolicySpec>, String> {
    let policy = v.get("policy");
    let budget_field = v.get("budget");
    let threshold_field = v.get("threshold");
    if policy.is_null() && budget_field.is_null() && threshold_field.is_null() {
        return Ok(None);
    }
    let mode = match policy.as_str() {
        Some(m) => m,
        None if policy.is_null() => "budget",
        None => return Err(format!("{op}: policy must be a string")),
    };
    let budget = match budget_field.as_f64() {
        Some(b) => {
            if !b.is_finite() || b < 0.0 {
                return Err(format!("{op}: budget must be a non-negative number"));
            }
            b
        }
        None if budget_field.is_null() => 0.0, // 0 == unconstrained
        None => return Err(format!("{op}: budget must be a non-negative number")),
    };
    let threshold = match threshold_field.as_f64() {
        Some(t) => {
            if mode != "threshold" {
                return Err(format!("{op}: threshold requires policy \"threshold\""));
            }
            t
        }
        None if threshold_field.is_null() => {
            if mode == "threshold" {
                return Err(format!("{op}: policy \"threshold\" requires a threshold"));
            }
            0.0
        }
        None => return Err(format!("{op}: threshold must be a number")),
    };
    PolicySpec::from_mode(mode, budget, threshold)
        .map(Some)
        .map_err(|e| format!("{op}: {e}"))
}

fn parse_texts(v: &Value) -> Result<Vec<String>, String> {
    let texts: Vec<String> = v
        .get("texts")
        .as_arr()
        .ok_or("route_batch: missing texts")?
        .iter()
        .map(|t| t.as_str().map(|s| s.to_string()))
        .collect::<Option<_>>()
        .ok_or("route_batch: texts must be strings")?;
    if texts.is_empty() {
        return Err("route_batch: texts must be non-empty".into());
    }
    if texts.len() > MAX_ROUTE_BATCH {
        return Err(format!("route_batch: at most {MAX_ROUTE_BATCH} texts"));
    }
    Ok(texts)
}

fn parse_feedback_fields(v: &Value) -> Result<Request, String> {
    Ok(Request::Feedback {
        text: v.get("text").as_str().ok_or("feedback: missing text")?.to_string(),
        model_a: v
            .get("model_a")
            .as_str()
            .ok_or("feedback: missing model_a")?
            .to_string(),
        model_b: v
            .get("model_b")
            .as_str()
            .ok_or("feedback: missing model_b")?
            .to_string(),
        score_a: v.get("score_a").as_f64().ok_or("feedback: missing score_a")?,
    })
}

/// Serialize a request to one line (client side, no trailing newline).
/// Emits v1 shapes for plain budget routes (any server understands them)
/// and v2 shapes whenever a v2-only construct is used.
pub fn encode_request(r: &Request) -> String {
    match r {
        Request::Route { text, spec } => {
            let mut fields = vec![("op", json::str_v("route")), ("text", json::str_v(text))];
            push_spec_fields(&mut fields, spec);
            json::obj(fields).to_json()
        }
        Request::RouteBatch { texts, spec } => {
            let items: Vec<Value> = texts.iter().map(|t| json::str_v(t)).collect();
            let mut fields =
                vec![("op", json::str_v("route_batch")), ("texts", Value::Arr(items))];
            push_spec_fields(&mut fields, spec);
            json::obj(fields).to_json()
        }
        Request::Feedback { text, model_a, model_b, score_a } => json::obj(vec![
            ("op", json::str_v("feedback")),
            ("text", json::str_v(text)),
            ("model_a", json::str_v(model_a)),
            ("model_b", json::str_v(model_b)),
            ("score_a", json::num(*score_a)),
        ])
        .to_json(),
        Request::Stats => json::obj(vec![("op", json::str_v("stats"))]).to_json(),
        Request::Ping => json::obj(vec![("op", json::str_v("ping"))]).to_json(),
        Request::Snapshot => json::obj(vec![("op", json::str_v("snapshot"))]).to_json(),
        Request::Hello => {
            json::obj(vec![("v", json::num(2.0)), ("op", json::str_v("hello"))]).to_json()
        }
        Request::Promote => {
            json::obj(vec![("v", json::num(2.0)), ("op", json::str_v("promote"))]).to_json()
        }
    }
}

/// Emit the wire fields for a policy spec. Finite-budget `Budget` specs
/// use the v1 shape; everything else needs v2.
fn push_spec_fields(fields: &mut Vec<(&str, Value)>, spec: &Option<PolicySpec>) {
    match spec {
        None => fields.insert(0, ("v", json::num(2.0))),
        Some(PolicySpec::Budget { budget }) if budget.is_finite() => {
            fields.push(("budget", json::num(*budget)));
        }
        Some(PolicySpec::Budget { .. }) => {
            // unbounded budget: v2's "budget 0 == unconstrained"
            fields.insert(0, ("v", json::num(2.0)));
            fields.push(("budget", json::num(0.0)));
        }
        Some(PolicySpec::CostAware { budget }) => {
            fields.insert(0, ("v", json::num(2.0)));
            if budget.is_finite() {
                fields.push(("budget", json::num(*budget)));
            }
            fields.push(("policy", json::str_v("cost_aware")));
        }
        Some(PolicySpec::Threshold { threshold }) => {
            fields.insert(0, ("v", json::num(2.0)));
            fields.push(("policy", json::str_v("threshold")));
            fields.push(("threshold", json::num(*threshold)));
        }
    }
}

/// Serialize a response to one line (no trailing newline).
pub fn encode_response(r: &Response) -> String {
    match r {
        Response::Routed { model, model_index, compare_with, expected_cost } => {
            let mut fields = vec![
                ("ok", Value::Bool(true)),
                ("model", json::str_v(model)),
                ("model_index", json::num(*model_index as f64)),
                ("expected_cost", json::num(*expected_cost)),
            ];
            if let Some(c) = compare_with {
                fields.push(("compare_with", json::str_v(c)));
            }
            json::obj(fields).to_json()
        }
        Response::RoutedBatch(replies) => {
            let items: Vec<Value> = replies
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("model", json::str_v(&r.model)),
                        ("model_index", json::num(r.model_index as f64)),
                        ("expected_cost", json::num(r.expected_cost)),
                    ];
                    if let Some(c) = &r.compare_with {
                        fields.push(("compare_with", json::str_v(c)));
                    }
                    json::obj(fields)
                })
                .collect();
            json::obj(vec![("ok", Value::Bool(true)), ("batch", Value::Arr(items))]).to_json()
        }
        Response::FeedbackAccepted => {
            json::obj(vec![("ok", Value::Bool(true)), ("accepted", Value::Bool(true))]).to_json()
        }
        Response::Stats { report, requests, feedback } => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("report", json::str_v(report)),
            ("requests", json::num(*requests as f64)),
            ("feedback", json::num(*feedback as f64)),
        ])
        .to_json(),
        Response::Pong => {
            json::obj(vec![("ok", Value::Bool(true)), ("pong", Value::Bool(true))]).to_json()
        }
        Response::SnapshotSaved { path, entries } => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("snapshot", json::str_v(path)),
            ("entries", json::num(*entries as f64)),
        ])
        .to_json(),
        Response::Hello { version, ops, policies, max_route_batch, role } => {
            let hello = json::obj(vec![
                ("version", json::num(*version as f64)),
                ("ops", Value::Arr(ops.iter().map(|s| json::str_v(s)).collect())),
                (
                    "policies",
                    Value::Arr(policies.iter().map(|s| json::str_v(s)).collect()),
                ),
                ("max_route_batch", json::num(*max_route_batch as f64)),
                ("role", json::str_v(role)),
            ]);
            json::obj(vec![("ok", Value::Bool(true)), ("hello", hello)]).to_json()
        }
        Response::Promoted { role } => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("promoted", Value::Bool(true)),
            ("role", json::str_v(role)),
        ])
        .to_json(),
        Response::NotLeader { message } => json::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", json::str_v(message)),
            ("not_leader", Value::Bool(true)),
        ])
        .to_json(),
        Response::Error(msg) => {
            json::obj(vec![("ok", Value::Bool(false)), ("error", json::str_v(msg))]).to_json()
        }
    }
}

/// Parse a response line (client side).
pub fn parse_response(line: &str) -> Result<Response, String> {
    let v = json::parse(line.trim()).map_err(|e| format!("bad json: {e}"))?;
    if v.get("ok").as_bool() != Some(true) {
        let message = v.get("error").as_str().unwrap_or("unknown error").to_string();
        if v.get("not_leader").as_bool() == Some(true) {
            return Ok(Response::NotLeader { message });
        }
        return Ok(Response::Error(message));
    }
    if v.get("pong").as_bool() == Some(true) {
        return Ok(Response::Pong);
    }
    if v.get("promoted").as_bool() == Some(true) {
        return Ok(Response::Promoted {
            role: v.get("role").as_str().unwrap_or("leader").to_string(),
        });
    }
    if v.get("accepted").as_bool() == Some(true) {
        return Ok(Response::FeedbackAccepted);
    }
    let hello = v.get("hello");
    if !hello.is_null() {
        let names = |key: &str| -> Result<Vec<String>, String> {
            hello
                .get(key)
                .as_arr()
                .ok_or(format!("hello: missing {key}"))?
                .iter()
                .map(|s| s.as_str().map(|s| s.to_string()))
                .collect::<Option<_>>()
                .ok_or(format!("hello: {key} must be strings"))
        };
        return Ok(Response::Hello {
            version: hello.get("version").as_usize().ok_or("hello: missing version")? as u32,
            ops: names("ops")?,
            policies: names("policies")?,
            max_route_batch: hello
                .get("max_route_batch")
                .as_usize()
                .ok_or("hello: missing max_route_batch")?,
            // pre-replication servers don't send a role: they are leaders
            role: hello.get("role").as_str().unwrap_or("leader").to_string(),
        });
    }
    if let Some(items) = v.get("batch").as_arr() {
        let replies = items
            .iter()
            .map(|r| {
                Ok(RouteReply {
                    model: r.get("model").as_str().ok_or("batch item: missing model")?.to_string(),
                    model_index: r
                        .get("model_index")
                        .as_usize()
                        .ok_or("batch item: missing model_index")?,
                    compare_with: r.get("compare_with").as_str().map(|s| s.to_string()),
                    expected_cost: r.get("expected_cost").as_f64().unwrap_or(0.0),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        return Ok(Response::RoutedBatch(replies));
    }
    if let Some(path) = v.get("snapshot").as_str() {
        return Ok(Response::SnapshotSaved {
            path: path.to_string(),
            entries: v.get("entries").as_f64().unwrap_or(0.0) as u64,
        });
    }
    if let Some(model) = v.get("model").as_str() {
        return Ok(Response::Routed {
            model: model.to_string(),
            model_index: v.get("model_index").as_usize().ok_or("missing model_index")?,
            compare_with: v.get("compare_with").as_str().map(|s| s.to_string()),
            expected_cost: v.get("expected_cost").as_f64().unwrap_or(0.0),
        });
    }
    if !v.get("report").is_null() {
        return Ok(Response::Stats {
            report: v.get("report").as_str().unwrap_or("").to_string(),
            requests: v.get("requests").as_f64().unwrap_or(0.0) as u64,
            feedback: v.get("feedback").as_f64().unwrap_or(0.0) as u64,
        });
    }
    Err("unrecognized response".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn budget_spec(b: f64) -> Option<PolicySpec> {
        Some(PolicySpec::Budget { budget: b })
    }

    #[test]
    fn parse_route() {
        let r = parse_request(r#"{"op":"route","text":"hi","budget":0.5}"#).unwrap();
        assert_eq!(r, Request::Route { text: "hi".into(), spec: budget_spec(0.5) });
    }

    #[test]
    fn v1_explicit_matches_bare() {
        // {"v":1,...} and no-v parse identically, lenient both ways
        let bare = parse_request(r#"{"op":"route","text":"hi","budget":0.5,"extra":1}"#).unwrap();
        let tagged =
            parse_request(r#"{"v":1,"op":"route","text":"hi","budget":0.5,"extra":1}"#).unwrap();
        assert_eq!(bare, tagged);
        assert_eq!(bare, Request::Route { text: "hi".into(), spec: budget_spec(0.5) });
    }

    #[test]
    fn v1_requires_budget_and_rejects_v2_constructs_leniently() {
        // v1 has no policy field: it is ignored (lenient), budget still rules
        let r = parse_request(r#"{"op":"route","text":"x","budget":1.0,"policy":"threshold"}"#)
            .unwrap();
        assert_eq!(r, Request::Route { text: "x".into(), spec: budget_spec(1.0) });
        assert!(parse_request(r#"{"op":"route","text":"x"}"#).is_err());
    }

    #[test]
    fn v2_route_policy_forms() {
        // bare budget: budget policy
        let r = parse_request(r#"{"v":2,"op":"route","text":"x","budget":0.5}"#).unwrap();
        assert_eq!(r, Request::Route { text: "x".into(), spec: budget_spec(0.5) });
        // no knobs at all: server default
        let r = parse_request(r#"{"v":2,"op":"route","text":"x"}"#).unwrap();
        assert_eq!(r, Request::Route { text: "x".into(), spec: None });
        // budget 0 == unconstrained
        let r = parse_request(r#"{"v":2,"op":"route","text":"x","budget":0}"#).unwrap();
        assert_eq!(
            r,
            Request::Route { text: "x".into(), spec: budget_spec(f64::INFINITY) }
        );
        // cost_aware
        let r = parse_request(
            r#"{"v":2,"op":"route","text":"x","policy":"cost_aware","budget":0.02}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Route {
                text: "x".into(),
                spec: Some(PolicySpec::CostAware { budget: 0.02 })
            }
        );
        // threshold
        let r = parse_request(
            r#"{"v":2,"op":"route","text":"x","policy":"threshold","threshold":0.6}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Route {
                text: "x".into(),
                spec: Some(PolicySpec::Threshold { threshold: 0.6 })
            }
        );
    }

    #[test]
    fn v2_rejects_bad_policy_shapes() {
        // threshold policy without its knob
        assert!(parse_request(r#"{"v":2,"op":"route","text":"x","policy":"threshold"}"#).is_err());
        // threshold knob without the policy
        assert!(parse_request(r#"{"v":2,"op":"route","text":"x","threshold":0.5}"#).is_err());
        // out-of-range threshold
        assert!(parse_request(
            r#"{"v":2,"op":"route","text":"x","policy":"threshold","threshold":1.5}"#
        )
        .is_err());
        // unknown policy name
        assert!(parse_request(r#"{"v":2,"op":"route","text":"x","policy":"nope"}"#).is_err());
        // non-string policy
        assert!(parse_request(r#"{"v":2,"op":"route","text":"x","policy":7}"#).is_err());
        // negative budget
        assert!(parse_request(r#"{"v":2,"op":"route","text":"x","budget":-1}"#).is_err());
    }

    #[test]
    fn v2_rejects_unknown_fields_v1_ignores_them() {
        let strict = parse_request(r#"{"v":2,"op":"route","text":"x","bugdet":0.5}"#);
        let err = strict.unwrap_err();
        assert!(err.contains("unknown field 'bugdet'"), "{err}");
        assert!(parse_request(r#"{"op":"route","text":"x","budget":0.5,"bugdet":9}"#).is_ok());
        // strictness covers every v2 op
        assert!(parse_request(r#"{"v":2,"op":"ping","extra":1}"#).is_err());
        assert!(parse_request(r#"{"v":2,"op":"stats","extra":1}"#).is_err());
        assert!(parse_request(r#"{"v":2,"op":"hello","extra":1}"#).is_err());
        let over =
            r#"{"v":2,"op":"feedback","text":"q","model_a":"a","model_b":"b","score_a":1,"x":1}"#;
        assert!(parse_request(over).is_err());
    }

    #[test]
    fn unsupported_versions_rejected() {
        let err = parse_request(r#"{"v":3,"op":"ping"}"#).unwrap_err();
        assert!(err.contains("unsupported protocol version 3"), "{err}");
        assert!(err.contains("supported: 1, 2"), "{err}");
        assert!(parse_request(r#"{"v":0,"op":"ping"}"#).is_err());
        assert!(parse_request(r#"{"v":"two","op":"ping"}"#).is_err());
    }

    #[test]
    fn hello_op_and_response_roundtrip() {
        assert_eq!(parse_request(r#"{"v":2,"op":"hello"}"#).unwrap(), Request::Hello);
        // hello is a v2 construct: v1 rejects it with its usual error
        let err = parse_request(r#"{"op":"hello"}"#).unwrap_err();
        assert_eq!(err, "unknown op 'hello'");

        let h = Response::hello("leader");
        let line = encode_response(&h);
        assert_eq!(parse_response(&line).unwrap(), h);
        match parse_response(&line).unwrap() {
            Response::Hello { version, ops, policies, max_route_batch, role } => {
                assert_eq!(version, PROTOCOL_VERSION);
                assert!(ops.iter().any(|o| o == "route"));
                assert!(ops.iter().any(|o| o == "promote"));
                assert_eq!(policies, vec!["budget", "cost_aware", "threshold"]);
                assert_eq!(max_route_batch, MAX_ROUTE_BATCH);
                assert_eq!(role, "leader");
            }
            other => panic!("{other:?}"),
        }
        // a pre-replication server's hello (no role field) reads as leader
        let legacy = r#"{"ok":true,"hello":{"version":2,"ops":["route"],"policies":[],"max_route_batch":4}}"#;
        match parse_response(legacy).unwrap() {
            Response::Hello { role, .. } => assert_eq!(role, "leader"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn promote_op_and_replica_responses_roundtrip() {
        // promote is a v2 construct, like hello
        assert_eq!(parse_request(r#"{"v":2,"op":"promote"}"#).unwrap(), Request::Promote);
        assert_eq!(parse_request(r#"{"op":"promote"}"#).unwrap_err(), "unknown op 'promote'");
        assert!(parse_request(r#"{"v":2,"op":"promote","extra":1}"#).is_err());
        let line = encode_request(&Request::Promote);
        assert!(line.contains("\"v\":2"), "{line}");
        assert_eq!(parse_request(&line).unwrap(), Request::Promote);

        for r in [
            Response::Promoted { role: "leader".into() },
            Response::NotLeader { message: "feedback requires the leader".into() },
            Response::hello("follower"),
        ] {
            assert_eq!(parse_response(&encode_response(&r)).unwrap(), r);
        }
        // NotLeader is a plain error object to clients that don't know
        // the marker: ok=false + error text
        let line = encode_response(&Response::NotLeader { message: "nope".into() });
        assert!(line.contains("\"ok\":false"), "{line}");
        assert!(line.contains("\"error\":\"nope\""), "{line}");
    }

    #[test]
    fn encode_request_speaks_oldest_possible_version() {
        // plain budget routes stay v1 on the wire: any server accepts them
        let line = encode_request(&Request::Route { text: "x".into(), spec: budget_spec(0.5) });
        assert!(!line.contains("\"v\""), "{line}");
        assert_eq!(parse_request(&line).unwrap(), Request::Route {
            text: "x".into(),
            spec: budget_spec(0.5),
        });
        // v2-only constructs get the v tag and roundtrip
        for req in [
            Request::Route { text: "x".into(), spec: None },
            Request::Route { text: "x".into(), spec: Some(PolicySpec::CostAware { budget: 0.1 }) },
            Request::Route {
                text: "x".into(),
                spec: Some(PolicySpec::Threshold { threshold: 0.7 }),
            },
            Request::Route { text: "x".into(), spec: budget_spec(f64::INFINITY) },
            Request::RouteBatch { texts: vec!["a".into()], spec: None },
            Request::Hello,
        ] {
            let line = encode_request(&req);
            assert!(line.contains("\"v\":2"), "{line}");
            assert_eq!(parse_request(&line).unwrap(), req, "{line}");
        }
        // v1 ops roundtrip through their classic shapes
        for req in [
            Request::Feedback {
                text: "q".into(),
                model_a: "a".into(),
                model_b: "b".into(),
                score_a: 1.0,
            },
            Request::Stats,
            Request::Ping,
            Request::Snapshot,
            Request::RouteBatch { texts: vec!["a".into(), "b".into()], spec: budget_spec(0.1) },
        ] {
            assert_eq!(parse_request(&encode_request(&req)).unwrap(), req);
        }
    }

    #[test]
    fn parse_feedback() {
        let r = parse_request(
            r#"{"op":"feedback","text":"q","model_a":"a","model_b":"b","score_a":0.5}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Feedback {
                text: "q".into(),
                model_a: "a".into(),
                model_b: "b".into(),
                score_a: 0.5
            }
        );
    }

    #[test]
    fn parse_route_batch() {
        let r = parse_request(r#"{"op":"route_batch","texts":["a","b"],"budget":0.1}"#).unwrap();
        assert_eq!(
            r,
            Request::RouteBatch { texts: vec!["a".into(), "b".into()], spec: budget_spec(0.1) }
        );
        assert!(parse_request(r#"{"op":"route_batch","texts":[],"budget":0.1}"#).is_err());
        assert!(parse_request(r#"{"op":"route_batch","texts":[1],"budget":0.1}"#).is_err());
        assert!(parse_request(r#"{"op":"route_batch","budget":0.1}"#).is_err());
        assert!(parse_request(r#"{"op":"route_batch","texts":["a"],"budget":-1}"#).is_err());
        // v2: per-batch policy, same strictness as route
        let r = parse_request(
            r#"{"v":2,"op":"route_batch","texts":["a"],"policy":"cost_aware","budget":0.3}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::RouteBatch {
                texts: vec!["a".into()],
                spec: Some(PolicySpec::CostAware { budget: 0.3 })
            }
        );
        assert!(parse_request(r#"{"v":2,"op":"route_batch","texts":["a"],"txets":[]}"#).is_err());
    }

    #[test]
    fn response_roundtrip_routed_batch() {
        let r = Response::RoutedBatch(vec![
            RouteReply {
                model: "gpt-4".into(),
                model_index: 0,
                compare_with: Some("claude-v2".into()),
                expected_cost: 0.03,
            },
            RouteReply {
                model: "mistral-7b-chat".into(),
                model_index: 3,
                compare_with: None,
                expected_cost: 0.0004,
            },
        ]);
        assert_eq!(parse_response(&encode_response(&r)).unwrap(), r);
    }

    #[test]
    fn parse_snapshot_op() {
        assert_eq!(parse_request(r#"{"op":"snapshot"}"#).unwrap(), Request::Snapshot);
    }

    #[test]
    fn parse_rejects_bad_requests() {
        assert!(parse_request("{}").is_err());
        assert!(parse_request(r#"{"op":"nope"}"#).is_err());
        assert!(parse_request(r#"{"op":"route","text":"x"}"#).is_err());
        assert!(parse_request(r#"{"op":"route","text":"x","budget":-1}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn response_roundtrip_routed() {
        let r = Response::Routed {
            model: "gpt-4".into(),
            model_index: 0,
            compare_with: Some("claude-v2".into()),
            expected_cost: 0.03,
        };
        assert_eq!(parse_response(&encode_response(&r)).unwrap(), r);
        let r2 = Response::Routed {
            model: "gpt-4".into(),
            model_index: 0,
            compare_with: None,
            expected_cost: 0.03,
        };
        assert_eq!(parse_response(&encode_response(&r2)).unwrap(), r2);
    }

    #[test]
    fn response_roundtrip_others() {
        for r in [
            Response::FeedbackAccepted,
            Response::Pong,
            Response::Stats { report: "r".into(), requests: 5, feedback: 2 },
            Response::SnapshotSaved { path: "/tmp/x.json".into(), entries: 42 },
            Response::Error("boom".into()),
        ] {
            assert_eq!(parse_response(&encode_response(&r)).unwrap(), r);
        }
    }

    #[test]
    fn malformed_hello_response_rejected() {
        // client-direction strictness: a garbled capability report is an
        // error, not a silently-defaulted Hello
        assert!(parse_response(r#"{"ok":true,"hello":{"version":2}}"#).is_err());
        let bad =
            r#"{"ok":true,"hello":{"version":2,"ops":[1],"policies":[],"max_route_batch":4}}"#;
        assert!(parse_response(bad).is_err());
    }

    #[test]
    fn unicode_text_survives() {
        let line = encode_response(&Response::Error("caf\u{e9} \u{1F600}".into()));
        match parse_response(&line).unwrap() {
            Response::Error(e) => assert_eq!(e, "caf\u{e9} \u{1F600}"),
            _ => panic!(),
        }
    }
}
