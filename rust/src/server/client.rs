//! Blocking client for the Eagle serving protocol (examples + load gen).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{encode_response, parse_response, Response, RouteReply};
use crate::json::{self, Value};

/// A routed decision as seen by the client.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteDecision {
    pub model: String,
    pub model_index: usize,
    pub compare_with: Option<String>,
    pub expected_cost: f64,
}

/// One TCP connection to an Eagle server.
pub struct EagleClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl EagleClient {
    pub fn connect(addr: &str) -> Result<EagleClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let writer = stream.try_clone()?;
        Ok(EagleClient { reader: BufReader::new(stream), writer })
    }

    fn call(&mut self, request_json: String) -> Result<Response> {
        let mut line = request_json;
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            bail!("server closed connection");
        }
        parse_response(&resp).map_err(|e| anyhow!("{e}"))
    }

    /// Route a query under a budget.
    pub fn route(&mut self, text: &str, budget: f64) -> Result<RouteDecision> {
        let req = json::obj(vec![
            ("op", json::str_v("route")),
            ("text", json::str_v(text)),
            ("budget", json::num(budget)),
        ])
        .to_json();
        match self.call(req)? {
            Response::Routed { model, model_index, compare_with, expected_cost } => {
                Ok(RouteDecision { model, model_index, compare_with, expected_cost })
            }
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Route a batch of queries under one budget: a single round trip,
    /// one embed dispatch and one snapshot acquisition server-side.
    pub fn route_batch(&mut self, texts: &[&str], budget: f64) -> Result<Vec<RouteDecision>> {
        let req = json::obj(vec![
            ("op", json::str_v("route_batch")),
            (
                "texts",
                Value::Arr(texts.iter().map(|t| json::str_v(t)).collect()),
            ),
            ("budget", json::num(budget)),
        ])
        .to_json();
        match self.call(req)? {
            Response::RoutedBatch(replies) => Ok(replies
                .into_iter()
                .map(|r: RouteReply| RouteDecision {
                    model: r.model,
                    model_index: r.model_index,
                    compare_with: r.compare_with,
                    expected_cost: r.expected_cost,
                })
                .collect()),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Submit a pairwise feedback verdict (score_a: 1 / 0.5 / 0).
    pub fn feedback(
        &mut self,
        text: &str,
        model_a: &str,
        model_b: &str,
        score_a: f64,
    ) -> Result<()> {
        let req = json::obj(vec![
            ("op", json::str_v("feedback")),
            ("text", json::str_v(text)),
            ("model_a", json::str_v(model_a)),
            ("model_b", json::str_v(model_b)),
            ("score_a", json::num(score_a)),
        ])
        .to_json();
        match self.call(req)? {
            Response::FeedbackAccepted => Ok(()),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Fetch the server's metrics report.
    pub fn stats(&mut self) -> Result<(String, u64, u64)> {
        let req = json::obj(vec![("op", json::str_v("stats"))]).to_json();
        match self.call(req)? {
            Response::Stats { report, requests, feedback } => Ok((report, requests, feedback)),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Ask the server to persist its router state (admin op).
    pub fn snapshot(&mut self) -> Result<(String, u64)> {
        let req = json::obj(vec![("op", json::str_v("snapshot"))]).to_json();
        match self.call(req)? {
            Response::SnapshotSaved { path, entries } => Ok((path, entries)),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        let req = json::obj(vec![("op", json::str_v("ping"))]).to_json();
        match self.call(req)? {
            Response::Pong => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }
}

// Silence unused-import warning for Value used in doc contexts.
#[allow(unused)]
fn _encode_sanity(r: &Response) -> (String, Value) {
    (encode_response(r), Value::Null)
}

#[cfg(test)]
mod tests {
    // Full client/server round-trips live in rust/tests/server_e2e.rs
    // (they need built artifacts for the embedder).
}
