//! Blocking client for the Eagle serving protocol (examples + load gen).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{
    encode_request, encode_response, parse_response, Request, Response, RouteReply,
};
use crate::coordinator::policy::PolicySpec;
use crate::json::{self, Value};

/// A server's advertised capabilities (the v2 `hello` op).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerHello {
    pub version: u32,
    pub ops: Vec<String>,
    pub policies: Vec<String>,
    pub max_route_batch: usize,
    /// `"leader"` or `"follower"` (pre-replication servers read as
    /// `"leader"`).
    pub role: String,
}

/// A routed decision as seen by the client.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteDecision {
    pub model: String,
    pub model_index: usize,
    pub compare_with: Option<String>,
    pub expected_cost: f64,
}

/// One TCP connection to an Eagle server.
pub struct EagleClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl EagleClient {
    pub fn connect(addr: &str) -> Result<EagleClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let writer = stream.try_clone()?;
        Ok(EagleClient { reader: BufReader::new(stream), writer })
    }

    fn call(&mut self, request_json: String) -> Result<Response> {
        let mut line = request_json;
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            bail!("server closed connection");
        }
        parse_response(&resp).map_err(|e| anyhow!("{e}"))
    }

    /// Negotiate capabilities (the v2 `hello` op). Pre-v2 servers reply
    /// with an error, which surfaces here — callers can fall back to the
    /// v1 surface (`route` with a plain budget).
    pub fn hello(&mut self) -> Result<ServerHello> {
        match self.call(encode_request(&Request::Hello))? {
            Response::Hello { version, ops, policies, max_route_batch, role } => {
                Ok(ServerHello { version, ops, policies, max_route_batch, role })
            }
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Promote a follower replica to leader (admin op; idempotent on a
    /// leader). Returns the server's role after the op.
    pub fn promote(&mut self) -> Result<String> {
        match self.call(encode_request(&Request::Promote))? {
            Response::Promoted { role } => Ok(role),
            Response::NotLeader { message } | Response::Error(message) => {
                bail!("server error: {message}")
            }
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Route a query under a budget (v1 wire shape — works against any
    /// server version).
    pub fn route(&mut self, text: &str, budget: f64) -> Result<RouteDecision> {
        self.route_with(text, Some(PolicySpec::Budget { budget }))
    }

    /// Route a query under an explicit policy (`None` = the server's
    /// default). Non-budget specs need a v2 server.
    pub fn route_with(
        &mut self,
        text: &str,
        spec: Option<PolicySpec>,
    ) -> Result<RouteDecision> {
        let req = Request::Route { text: text.to_string(), spec };
        match self.call(encode_request(&req))? {
            Response::Routed { model, model_index, compare_with, expected_cost } => {
                Ok(RouteDecision { model, model_index, compare_with, expected_cost })
            }
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Route a batch of queries under one budget: a single round trip,
    /// one embed dispatch and one snapshot acquisition server-side.
    pub fn route_batch(&mut self, texts: &[&str], budget: f64) -> Result<Vec<RouteDecision>> {
        self.route_batch_with(texts, Some(PolicySpec::Budget { budget }))
    }

    /// Batch variant of [`EagleClient::route_with`].
    pub fn route_batch_with(
        &mut self,
        texts: &[&str],
        spec: Option<PolicySpec>,
    ) -> Result<Vec<RouteDecision>> {
        let req = Request::RouteBatch {
            texts: texts.iter().map(|t| t.to_string()).collect(),
            spec,
        };
        match self.call(encode_request(&req))? {
            Response::RoutedBatch(replies) => Ok(replies
                .into_iter()
                .map(|r: RouteReply| RouteDecision {
                    model: r.model,
                    model_index: r.model_index,
                    compare_with: r.compare_with,
                    expected_cost: r.expected_cost,
                })
                .collect()),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Submit a pairwise feedback verdict (score_a: 1 / 0.5 / 0).
    pub fn feedback(
        &mut self,
        text: &str,
        model_a: &str,
        model_b: &str,
        score_a: f64,
    ) -> Result<()> {
        let req = json::obj(vec![
            ("op", json::str_v("feedback")),
            ("text", json::str_v(text)),
            ("model_a", json::str_v(model_a)),
            ("model_b", json::str_v(model_b)),
            ("score_a", json::num(score_a)),
        ])
        .to_json();
        match self.call(req)? {
            Response::FeedbackAccepted => Ok(()),
            Response::NotLeader { message } => bail!("not the leader: {message}"),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Fetch the server's metrics report.
    pub fn stats(&mut self) -> Result<(String, u64, u64)> {
        let req = json::obj(vec![("op", json::str_v("stats"))]).to_json();
        match self.call(req)? {
            Response::Stats { report, requests, feedback } => Ok((report, requests, feedback)),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Ask the server to persist its router state (admin op).
    pub fn snapshot(&mut self) -> Result<(String, u64)> {
        let req = json::obj(vec![("op", json::str_v("snapshot"))]).to_json();
        match self.call(req)? {
            Response::SnapshotSaved { path, entries } => Ok((path, entries)),
            Response::NotLeader { message } => bail!("not the leader: {message}"),
            Response::Error(e) => bail!("server error: {e}"),
            other => bail!("unexpected response: {other:?}"),
        }
    }

    /// Liveness check.
    pub fn ping(&mut self) -> Result<()> {
        let req = json::obj(vec![("op", json::str_v("ping"))]).to_json();
        match self.call(req)? {
            Response::Pong => Ok(()),
            other => bail!("unexpected response: {other:?}"),
        }
    }
}

// Silence unused-import warning for Value used in doc contexts.
#[allow(unused)]
fn _encode_sanity(r: &Response) -> (String, Value) {
    (encode_response(r), Value::Null)
}

#[cfg(test)]
mod tests {
    // Full client/server round-trips live in rust/tests/server_e2e.rs
    // (they need built artifacts for the embedder).
}
