//! The versioned stats report: every section the `stats` op exposes,
//! gathered in one struct and serialized from one place.
//!
//! The op had accreted ad-hoc sections (request metrics, ingest, shed —
//! each formatted at its own call site); replication adds a `replica`
//! section, and bolting on another `format!` would have made four. A
//! [`StatsReport`] is assembled by
//! [`super::ServerState::stats_report`] and rendered by
//! [`StatsReport::render`]; nothing else concatenates report text.
//!
//! The rendered text is versioned ([`STATS_VERSION`], the leading
//! `stats: v1 ...` line) and append-only: existing section lines keep
//! their exact shape (`route_latency`, `ingest:`, `server: shed(` are
//! parsed by tests and dashboards), new sections get new lines and new
//! fields land at the end of their line.

/// Version stamp of the rendered report layout. Bump when an existing
/// line changes shape; adding lines is compatible.
pub const STATS_VERSION: u32 = 1;

/// Replication state as seen by a follower's tail loop
/// ([`crate::coordinator::replica::ReplicaMetrics`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaSection {
    /// Decoded records still waiting for a contiguous gid run before the
    /// global fold.
    pub lag_frames: u64,
    /// Unconsumed log-tail bytes after the last poll.
    pub lag_bytes: u64,
    /// Generation of the last manifest swap the follower has seen.
    pub manifest_generation: u64,
    /// Records applied via the tail so far.
    pub applied_records: u64,
    /// Tail polls completed.
    pub polls: u64,
    /// Current tail sleep in ms (base interval, backed off while idle).
    pub poll_ms_effective: u64,
    /// Segment passes abandoned because the leader's GC deleted a
    /// manifest-named file mid-tail.
    pub manifest_restarts: u64,
}

/// Durable-store segment lifecycle, as seen by a leader that owns one
/// ([`crate::coordinator::durable::CompactionStats`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DurableSection {
    /// Sealed segment files across all shards right now.
    pub segments: u64,
    /// Manifest generation (bumps on every seal/checkpoint/compaction).
    pub generation: u64,
    /// Binary-counter merges the compactor has published.
    pub merges: u64,
    /// v1 → v2 format upgrades the compactor has published.
    pub upgrades: u64,
    /// Superseded segment files deleted after their GC grace window.
    pub gc_files: u64,
    /// Compaction passes abandoned on an error (logged, non-fatal).
    pub errors: u64,
    /// Retired files still inside the grace window.
    pub gc_pending: u64,
}

/// Everything the `stats` op reports, in one place.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsReport {
    pub version: u32,
    /// `"leader"` or `"follower"`.
    pub role: &'static str,
    /// Resolved scoring-kernel backend name.
    pub kernel: &'static str,
    /// SQ8 publication enabled (post-`EAGLE_QUANT` resolution).
    pub quant: bool,
    /// Request metrics ([`crate::metrics::Metrics::report`]).
    pub server: String,
    /// Ingest progress
    /// ([`crate::coordinator::ingest::IngestMetrics::report`]).
    pub ingest: String,
    /// Admission refusals ([`super::shed::ShedMetrics::report`]).
    pub shed: String,
    /// Present on followers only.
    pub replica: Option<ReplicaSection>,
    /// Present on leaders with a durable store attached.
    pub durable: Option<DurableSection>,
}

impl StatsReport {
    /// Render the wire text: a versioned header line, the classic
    /// sections in their original order and shape, then the replica line
    /// when following and the durable line when a store is attached.
    pub fn render(&self) -> String {
        let mut out = format!(
            "stats: v{} role={} kernel={} quant={}\n{}\n{}\n{}",
            self.version, self.role, self.kernel, self.quant, self.server, self.ingest, self.shed,
        );
        if let Some(r) = &self.replica {
            out.push_str(&format!(
                "\nreplica: role={} lag_frames={} lag_bytes={} manifest_generation={} \
                 applied={} polls={} poll_ms_effective={} manifest_restarts={}",
                self.role,
                r.lag_frames,
                r.lag_bytes,
                r.manifest_generation,
                r.applied_records,
                r.polls,
                r.poll_ms_effective,
                r.manifest_restarts,
            ));
        }
        if let Some(d) = &self.durable {
            out.push_str(&format!(
                "\ndurable: segments={} generation={} merges={} upgrades={} gc_files={} \
                 gc_pending={} compact_errors={}",
                d.segments,
                d.generation,
                d.merges,
                d.upgrades,
                d.gc_files,
                d.gc_pending,
                d.errors,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(replica: Option<ReplicaSection>) -> StatsReport {
        StatsReport {
            version: STATS_VERSION,
            role: if replica.is_some() { "follower" } else { "leader" },
            kernel: "portable",
            quant: false,
            server: "requests=1 feedback=0 errors=0\nroute_latency: n=0".into(),
            ingest: "ingest: queued=0 folded_global=0 applied=0".into(),
            shed: "server: shed(conn_limit=0 inflight=0) closed(idle=0 oversize=0)".into(),
            replica,
            durable: None,
        }
    }

    #[test]
    fn render_keeps_classic_section_shapes() {
        let text = report(None).render();
        assert!(text.starts_with("stats: v1 role=leader kernel=portable quant=false\n"), "{text}");
        // the substrings the e2e suite and dashboards grep for
        assert!(text.contains("route_latency"), "{text}");
        assert!(text.contains("ingest:"), "{text}");
        assert!(text.contains("server: shed("), "{text}");
        assert!(!text.contains("replica:"), "{text}");
        assert!(!text.contains("durable:"), "{text}");
    }

    #[test]
    fn render_appends_replica_section_on_followers() {
        let text = report(Some(ReplicaSection {
            lag_frames: 3,
            lag_bytes: 128,
            manifest_generation: 7,
            applied_records: 42,
            polls: 9,
            poll_ms_effective: 400,
            manifest_restarts: 1,
        }))
        .render();
        assert!(text.contains("role=follower"), "{text}");
        // frozen prefix (parsed by dashboards), new fields appended at
        // the end of the line
        assert!(
            text.contains(
                "replica: role=follower lag_frames=3 lag_bytes=128 manifest_generation=7 \
                 applied=42 polls=9 poll_ms_effective=400 manifest_restarts=1"
            ),
            "{text}"
        );
    }

    #[test]
    fn render_appends_durable_section_when_store_attached() {
        let mut r = report(None);
        r.durable = Some(DurableSection {
            segments: 12,
            generation: 34,
            merges: 5,
            upgrades: 2,
            gc_files: 8,
            errors: 0,
            gc_pending: 1,
        });
        let text = r.render();
        assert!(
            text.contains(
                "durable: segments=12 generation=34 merges=5 upgrades=2 gc_files=8 \
                 gc_pending=1 compact_errors=0"
            ),
            "{text}"
        );
    }
}
