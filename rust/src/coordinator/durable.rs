//! Segment-granular durable persistence with crash recovery: the
//! log-structured on-disk counterpart of the in-memory
//! [`crate::vectordb::view::SegmentStore`].
//!
//! The legacy `[persist]` path serialized the *entire* corpus as one JSON
//! blob every beat — an O(corpus) rewrite that erases Eagle's incremental
//! -update win at production scale. This store makes durability cost
//! proportional to what changed:
//!
//! ```text
//! <dir>/
//!   MANIFEST.json            atomically swapped (tmp + rename): the live
//!                            segment set + delta log per shard, plus the
//!                            global-ELO checkpoint
//!   shard-0/
//!     seg-00000001.seg       immutable sealed segments — written exactly
//!     seg-00000003.seg       once (at seal time), never rewritten
//!     delta-00000004.log     append-only delta log for the active tail
//!   shard-1/ ...
//! ```
//!
//! - Every ingested record is **appended** to its shard's delta log as one
//!   checksummed frame `(global id, comparisons, embedding)`; the persist
//!   beat is "flush + fsync the logs", O(records since last beat).
//! - When a shard's unsealed tail reaches `seal_bytes`, the lane **seals**:
//!   the tail becomes an immutable segment file (written once), a fresh
//!   empty log is created, and the manifest swaps atomically to reference
//!   the new segment + log. Shard lanes seal independently — one shard's
//!   seal never rewrites another shard's data.
//! - The **global-ELO checkpoint** in the manifest stores the *full*
//!   resumable table state ([`crate::elo::GlobalEloState`]) plus the
//!   number of records folded into it (`folded_gid`). It is only advanced
//!   after a flush barrier proves every folded record is durable, so
//!   recovery can never double-fold or fold lost records.
//!
//! ## Recovery
//!
//! [`DurableStore::open`] reads the manifest, loads every sealed segment
//! (hard error on corruption — segments are written once and fsynced),
//! replays the delta logs (a torn final write — short frame or checksum
//! mismatch — truncates the log to the last full record), and rebuilds a
//! [`ShardedRouter`] bit-identical to the pre-restart writer state: the
//! stores and id maps come straight from the records, and the global table
//! resumes from the checkpoint then refolds every durable record with
//! `gid >= folded_gid` in global arrival order — the exact fold order the
//! dispatcher used originally. `rust/tests/durable_recovery.rs`
//! property-tests `recover(persist(state)) ≡ state` for K ∈ {1, 4},
//! including after a torn tail write.
//!
//! Replay itself lives in [`CatchUp`], an *incremental* catch-up API:
//! [`Recovery::resume`] feeds everything recovered from disk through
//! [`CatchUp::apply_sealed_segment`] / [`CatchUp::apply_delta_frame`] and
//! crash recovery simply [`CatchUp::finish`]es immediately. A follower
//! replica ([`crate::coordinator::replica`]) keeps the same `CatchUp` open
//! and applies frames as the leader writes them — one replay path, so the
//! follower's rebuilt state is bit-identical to what crash recovery would
//! produce from the same bytes. The manifest also carries a monotonically
//! increasing `generation` counter (bumped on every swap) so a tailing
//! follower can cheaply report how current its view of the manifest is.
//!
//! ## Crash windows at seal time
//!
//! A seal performs: (1) write segment (tmp + rename + fsync), (2) create
//! the fresh log, (3) swap the manifest. A crash before (3) leaves the old
//! manifest referencing the old log, which still holds every record — the
//! orphan segment/log files are swept on the next open. A crash after (3)
//! is the committed state. The manifest swap is a single atomic rename,
//! so recovery always sees one consistent cut.

use std::collections::{BTreeMap, HashSet};
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{EagleParams, EpochParams, ShardParams};
use crate::elo::{Comparison, GlobalElo, GlobalEloState, Outcome};
use crate::json::{self, Value};
use crate::vectordb::view::{SegmentStore, Slab};
use crate::vectordb::{Feedback, ReadIndex};

use super::router::{EagleRouter, Observation};
use super::sharded::{GlobalLane, IdBlocks, ShardLane, ShardedHandle, ShardedRouter};
use super::snapshot::RouterWriter;

pub(crate) const MANIFEST: &str = "MANIFEST.json";
pub(crate) const LOCK: &str = "LOCK";
/// 1.0 → 1.1: segment entries gained additive `format` / `first_gid` /
/// `last_gid` fields (v2 mmap segments + compaction). Older readers bail
/// on 1.1 manifests with a clear "newer than supported" error; 1.1 readers
/// accept 1.0 manifests (absent fields default to format 1 / unknown).
const MANIFEST_VERSION: f64 = 1.1;
/// Segment file header magic ("EAGS"); shared by both formats.
const SEG_MAGIC: u32 = 0x4541_4753;
/// Format 1: 16-byte header + concatenated delta-log frames (decode-only).
const SEG_VERSION: u32 = 1;
const SEG_HEADER_BYTES: usize = 16;
/// Format 2: fixed layout, mmap-able. 64-byte header, then gids, cmp
/// prefix sums, comparisons, zero pad to a 64-byte boundary, then the
/// embedding slab as contiguous little-endian f32 bits. See
/// [`write_segment_v2`] for exact offsets.
const SEG_VERSION_V2: u32 = 2;
const SEG2_HEADER_BYTES: usize = 64;
/// The embedding slab starts on a multiple of this (a page-aligned mmap
/// base therefore yields an aligned `&[f32]` view).
const SEG2_SLAB_ALIGN: usize = 64;

/// Tuning for a [`DurableStore`].
#[derive(Debug, Clone)]
pub struct DurableOptions {
    /// Unsealed delta-log bytes per shard that trigger sealing a segment.
    pub seal_bytes: usize,
    /// fsync logs on the persist beat and segments/manifest at seal.
    /// Disabling trades crash-durability of the last beat for speed
    /// (tests, benches); the format stays identical.
    pub fsync: bool,
    /// Seal new segments in the mmap-able v2 layout and map sealed
    /// segments read-only on recovery/tail instead of decoding them.
    /// Either setting reads both formats; disabling only changes what new
    /// seals write and forces the buffered decode path on open.
    pub mmap: bool,
}

impl Default for DurableOptions {
    fn default() -> Self {
        DurableOptions { seal_bytes: 4 << 20, fsync: true, mmap: true }
    }
}

/// Immutable identity of a store: everything recovery needs to rebuild
/// the router shell before replaying records.
#[derive(Debug, Clone)]
pub struct StoreMeta {
    pub params: EagleParams,
    pub n_models: usize,
    pub dim: usize,
    pub shards: ShardParams,
}

/// One sealed segment as named by the manifest.
#[derive(Debug, Clone)]
pub(crate) struct SegmentEntry {
    pub(crate) file: String,
    pub(crate) records: usize,
    /// Segment file format (1 = framed, 2 = mmap-able fixed layout).
    /// Absent on pre-1.1 manifests → 1.
    pub(crate) format: u32,
    /// Gid range held by the segment (inclusive). Recorded at seal /
    /// compaction time; `None` on entries carried over from pre-1.1
    /// manifests, where the range is only known after decoding.
    pub(crate) first_gid: Option<u32>,
    pub(crate) last_gid: Option<u32>,
}

/// One shard lane's durable state as named by the manifest.
#[derive(Debug, Clone)]
pub(crate) struct LaneManifest {
    pub(crate) segments: Vec<SegmentEntry>,
    /// Relative path of the live delta log.
    pub(crate) log: String,
    /// Monotone file-id allocator for this lane's segment/log names.
    pub(crate) next_file_id: u64,
}

/// The manifest's global-ELO checkpoint: full table state + the number of
/// records (== next gid at capture time) folded into it.
#[derive(Debug, Clone)]
pub(crate) struct GlobalCheckpoint {
    pub(crate) folded_gid: u32,
    pub(crate) state: GlobalEloState,
}

#[derive(Debug, Clone)]
pub(crate) struct ManifestState {
    /// Monotone swap counter: bumped on every manifest write after the
    /// first, so a tailing follower can report manifest currency without
    /// diffing the segment lists.
    pub(crate) generation: u64,
    pub(crate) global: GlobalCheckpoint,
    pub(crate) lanes: Vec<LaneManifest>,
}

/// The shared durable store: owns the directory and the manifest. Lane
/// writers ([`DurableStore::lane_writer`]) append independently; manifest
/// swaps (seals, checkpoints) serialize on one mutex — both are rare
/// relative to appends.
pub struct DurableStore {
    dir: PathBuf,
    meta: StoreMeta,
    opts: DurableOptions,
    manifest: Mutex<ManifestState>,
    /// Files superseded by a compaction swap, waiting out the GC grace
    /// window so a tailing follower mid-read never sees one vanish
    /// without first getting the restart-from-manifest signal.
    retired: Mutex<Vec<(std::time::Instant, PathBuf)>>,
    compaction: CompactionStats,
}

/// Background compaction / GC counters (surfaced in the `stats` op).
#[derive(Debug, Default)]
pub struct CompactionStats {
    /// Adjacent segment pairs merged into one v2 segment.
    pub merges: crate::metrics::Counter,
    /// Solo v1 segments rewritten in the v2 layout.
    pub upgrades: crate::metrics::Counter,
    /// Superseded files deleted after the grace window.
    pub gc_files: crate::metrics::Counter,
    /// Compaction passes that failed (kept for retry next tick).
    pub errors: crate::metrics::Counter,
}

/// Everything recovered from disk by [`DurableStore::open`], ready to be
/// turned back into a live [`ShardedRouter`]. Sealed segments are held as
/// *descriptors*, not decoded records: [`Recovery::resume`] streams them
/// through [`CatchUp`] one file at a time, so recovery's transient memory
/// high-water mark is O(largest segment), never O(corpus).
pub struct Recovery {
    pub meta: StoreMeta,
    /// Records folded into the checkpointed global table.
    pub folded_gid: u32,
    /// The checkpointed global-ELO state (resume point for refolding).
    pub global: GlobalEloState,
    pub lanes: Vec<RecoveredLane>,
    /// Bytes dropped from delta-log tails because the final write was
    /// torn (0 on a clean shutdown).
    pub torn_bytes: u64,
    dir: PathBuf,
    opts: DurableOptions,
}

/// One shard's recovered durable state.
pub struct RecoveredLane {
    /// Sealed segment descriptors in manifest order; loaded lazily by
    /// [`Recovery::resume`].
    pub(crate) segments: Vec<SegmentEntry>,
    /// The delta-log tail (records not yet sealed; bounded by
    /// `seal_bytes`).
    pub tail: Vec<(u32, Observation)>,
}

/// Transient-memory accounting for one [`Recovery::resume_reporting`]
/// pass: decoded/mapped segment buffers live one at a time, so the peak
/// tracks the largest segment plus the already-recovered log tails — the
/// streaming-recovery invariant `rust/tests/durable_recovery.rs` asserts.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecoveryFootprint {
    /// Largest transient resident footprint seen while applying segments
    /// (decoded buffers + log tails still awaiting application).
    pub peak_resident_bytes: usize,
    /// Transient footprint of the largest single segment.
    pub largest_segment_bytes: usize,
    /// Sum of every segment's transient footprint (what a non-streaming
    /// recovery would have held alive at once).
    pub total_segment_bytes: usize,
}

impl DurableStore {
    /// True when `dir` holds a durable store (manifest present).
    pub fn exists(dir: &Path) -> bool {
        dir.join(MANIFEST).is_file()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    pub fn options(&self) -> &DurableOptions {
        &self.opts
    }

    /// Sealed-segment count per shard (diagnostics / tests).
    pub fn segment_counts(&self) -> Vec<usize> {
        let m = self.manifest.lock().unwrap();
        m.lanes.iter().map(|l| l.segments.len()).collect()
    }

    /// Total sealed-segment files across all shards (diagnostics).
    pub fn total_segments(&self) -> usize {
        self.segment_counts().iter().sum()
    }

    /// Current manifest generation (diagnostics / stats).
    pub fn generation(&self) -> u64 {
        self.manifest.lock().unwrap().generation
    }

    /// Compaction / GC counters (the `stats` op renders these).
    pub fn compaction_stats(&self) -> &CompactionStats {
        &self.compaction
    }

    /// Files retired by compaction still waiting out the GC grace window.
    pub fn retired_pending(&self) -> usize {
        self.retired.lock().unwrap().len()
    }

    /// Create an empty store at `dir` (fails if a manifest already
    /// exists — open that instead).
    pub fn create(dir: &Path, meta: StoreMeta, opts: DurableOptions) -> Result<Arc<DurableStore>> {
        Self::create_with(dir, meta, opts, |_| Ok(Vec::new()), GlobalCheckpoint::empty)
    }

    /// Create a store at `dir` seeded with an existing router's full
    /// corpus (migration from the legacy single-JSON snapshot, or any
    /// pre-fit history): each non-empty shard lands as one initial sealed
    /// segment, and the global checkpoint captures the router's table.
    pub fn create_from_router(
        dir: &Path,
        router: &ShardedRouter,
        opts: DurableOptions,
    ) -> Result<Arc<DurableStore>> {
        let meta = StoreMeta {
            params: router.params().clone(),
            n_models: router.n_models(),
            dim: router.dim(),
            shards: router.shard_params().clone(),
        };
        let lanes = router.lanes_ref();
        Self::create_with(
            dir,
            meta,
            opts,
            |shard| {
                let lane = &lanes[shard];
                let store = lane.writer().router().store();
                let ids = lane.ids_ref();
                if store.is_empty() {
                    return Ok(Vec::new());
                }
                let mut frames = Vec::new();
                for local in 0..store.len() {
                    encode_frame(
                        &mut frames,
                        ids.get(local),
                        &store.feedback(local as u32).comparisons,
                        store.vector(local as u32),
                    );
                }
                Ok(vec![BootSegment {
                    frames,
                    records: store.len(),
                    first_gid: Some(ids.get(0)),
                    last_gid: Some(ids.get(store.len() - 1)),
                }])
            },
            || GlobalCheckpoint {
                folded_gid: router.next_global_id(),
                state: router.global_elo().export_state(),
            },
        )
    }

    /// Shared creation path: lay out shard dirs, write any bootstrap
    /// segments, create empty logs, swap in the first manifest.
    fn create_with<F, G>(
        dir: &Path,
        meta: StoreMeta,
        opts: DurableOptions,
        mut bootstrap: F,
        checkpoint: G,
    ) -> Result<Arc<DurableStore>>
    where
        F: FnMut(usize) -> Result<Vec<BootSegment>>,
        G: FnOnce() -> GlobalCheckpoint,
    {
        if Self::exists(dir) {
            bail!("durable store already exists at {}", dir.display());
        }
        fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        acquire_lock(dir)?;
        let mut lanes = Vec::with_capacity(meta.shards.count);
        for shard in 0..meta.shards.count {
            let shard_dir = dir.join(format!("shard-{shard}"));
            fs::create_dir_all(&shard_dir)
                .with_context(|| format!("creating {}", shard_dir.display()))?;
            let mut next_file_id = 1u64;
            let mut segments = Vec::new();
            for boot in bootstrap(shard)? {
                let file = format!("shard-{shard}/seg-{next_file_id:08}.seg");
                let format = seal_segment_file(
                    &dir.join(&file),
                    meta.dim,
                    meta.n_models,
                    boot.records,
                    &boot.frames,
                    &opts,
                )?;
                segments.push(SegmentEntry {
                    file,
                    records: boot.records,
                    format,
                    first_gid: boot.first_gid,
                    last_gid: boot.last_gid,
                });
                next_file_id += 1;
            }
            let log = format!("shard-{shard}/delta-{next_file_id:08}.log");
            File::create(dir.join(&log)).with_context(|| format!("creating {log}"))?;
            if opts.fsync {
                fsync_dir(&shard_dir);
            }
            lanes.push(LaneManifest { segments, log, next_file_id: next_file_id + 1 });
        }
        let state = ManifestState { generation: 0, global: checkpoint(), lanes };
        let store = DurableStore {
            dir: dir.to_path_buf(),
            meta,
            opts,
            manifest: Mutex::new(state),
            retired: Mutex::new(Vec::new()),
            compaction: CompactionStats::default(),
        };
        store.write_manifest(&store.manifest.lock().unwrap())?;
        Ok(Arc::new(store))
    }

    /// Open an existing store and recover everything durable: manifest +
    /// delta-log replay (truncating a torn final write). Sealed segments
    /// are *not* read here — [`Recovery::resume`] streams them through
    /// catch-up one at a time (mapping v2 segments read-only when
    /// `opts.mmap`), so open→serving is O(segment count + log tail), not
    /// O(corpus). Orphan files from a crashed seal or compaction are
    /// swept.
    pub fn open(dir: &Path, opts: DurableOptions) -> Result<(Arc<DurableStore>, Recovery)> {
        let path = dir.join(MANIFEST);
        let text =
            fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        // take the advisory lock before any mutation (log truncation,
        // orphan sweep)
        acquire_lock(dir)?;
        let (meta, state) = parse_manifest(&text)?;
        let mut referenced: HashSet<PathBuf> = HashSet::new();
        let mut lanes = Vec::with_capacity(state.lanes.len());
        let mut torn_bytes = 0u64;
        for (shard, lane) in state.lanes.iter().enumerate() {
            for seg in &lane.segments {
                let seg_path = dir.join(&seg.file);
                if !seg_path.is_file() {
                    bail!("shard {shard}: manifest references missing segment {}", seg.file);
                }
                referenced.insert(seg_path);
            }
            let log_path = dir.join(&lane.log);
            referenced.insert(log_path.clone());
            let replay = recover_log(&log_path, meta.dim, meta.n_models)
                .with_context(|| format!("delta log {}", lane.log))?;
            torn_bytes += replay.lost;
            lanes.push(RecoveredLane { segments: lane.segments.clone(), tail: replay.records });
        }
        sweep_orphans(dir, state.lanes.len(), &referenced);
        let recovery = Recovery {
            meta: meta.clone(),
            folded_gid: state.global.folded_gid,
            global: state.global.state.clone(),
            lanes,
            torn_bytes,
            dir: dir.to_path_buf(),
            opts: opts.clone(),
        };
        let store = Arc::new(DurableStore {
            dir: dir.to_path_buf(),
            meta,
            opts,
            manifest: Mutex::new(state),
            retired: Mutex::new(Vec::new()),
            compaction: CompactionStats::default(),
        });
        Ok((store, recovery))
    }

    /// One appending writer for a shard lane (the lane's applier thread
    /// owns it). Reloads the live log's validated tail so sealing keeps
    /// working across restarts.
    pub fn lane_writer(self: &Arc<Self>, shard: usize) -> Result<DurableLaneWriter> {
        let log_rel = {
            let m = self.manifest.lock().unwrap();
            m.lanes
                .get(shard)
                .ok_or_else(|| anyhow!("shard {shard} out of range"))?
                .log
                .clone()
        };
        let path = self.dir.join(&log_rel);
        let replay = recover_log(&path, self.meta.dim, self.meta.n_models)
            .with_context(|| format!("delta log {log_rel}"))?;
        let log = OpenOptions::new()
            .append(true)
            .create(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        let unsealed_first_gid = replay.records.first().map(|(gid, _)| *gid);
        let unsealed_last_gid = replay.records.last().map(|(gid, _)| *gid);
        Ok(DurableLaneWriter {
            store: self.clone(),
            shard,
            log: BufWriter::new(log),
            unsealed: replay.bytes,
            unsealed_records: replay.records.len(),
            unsealed_first_gid,
            unsealed_last_gid,
            appended_bytes: 0,
        })
    }

    /// Advance the global-ELO checkpoint. Call only after every record
    /// with `gid < folded_gid` is durably synced (the ingest pipeline's
    /// persist beat runs a flush barrier through every lane first).
    pub fn checkpoint_global(&self, folded_gid: u32, state: GlobalEloState) -> Result<()> {
        let mut m = self.manifest.lock().unwrap();
        let mut staged = m.clone();
        staged.generation += 1;
        staged.global = GlobalCheckpoint { folded_gid, state };
        self.write_manifest(&staged)?;
        *m = staged;
        Ok(())
    }

    /// Wrap an already-recovered directory without re-reading it. The
    /// replica promotion path holds the advisory lock, has repaired the
    /// delta logs, and carries the live-parsed manifest — going through
    /// [`DurableStore::open`] would redundantly re-read every sealed
    /// segment it has already applied.
    pub(crate) fn attach(
        dir: &Path,
        meta: StoreMeta,
        opts: DurableOptions,
        state: ManifestState,
    ) -> Arc<DurableStore> {
        Arc::new(DurableStore {
            dir: dir.to_path_buf(),
            meta,
            opts,
            manifest: Mutex::new(state),
            retired: Mutex::new(Vec::new()),
            compaction: CompactionStats::default(),
        })
    }

    /// Serialize + atomically swap the manifest file.
    fn write_manifest(&self, state: &ManifestState) -> Result<()> {
        let text = manifest_json(&self.meta, state);
        write_atomic(&self.dir.join(MANIFEST), text.as_bytes(), self.opts.fsync)
    }
}

impl Drop for DurableStore {
    /// Release the advisory lock if this process still owns it (a
    /// SIGKILLed owner leaves the file behind; [`acquire_lock`] treats a
    /// dead owner pid as released).
    fn drop(&mut self) {
        let path = self.dir.join(LOCK);
        if let Ok(text) = fs::read_to_string(&path) {
            if text.trim().parse::<u32>().ok() == Some(std::process::id()) {
                let _ = fs::remove_file(&path);
            }
        }
    }
}

// ---- background compaction + GC -----------------------------------------
//
// Sealing writes one small segment file per `seal_bytes` of ingest, so the
// file count — and with it restart cost and directory pressure — grows
// linearly forever. The compactor merges adjacent sealed segments
// binary-counter style, mirroring the in-memory `SegmentStore` policy: a
// merge fires whenever a segment is at least as large (in records) as its
// left neighbor, so the steady-state per-shard file count stays
// O(log(corpus / seal_bytes)) and every record is rewritten O(log n)
// times total. Merged output is always written in the v2 layout; when
// nothing is mergeable the compactor instead upgrades one legacy v1
// segment per pass, so old stores migrate to mmap-able files by
// themselves.
//
// A merge never mutates a published file: it writes the merged segment via
// tmp + rename + fsync, then swaps the manifest (generation + 1) to
// reference it, then *retires* the superseded files into a grace queue.
// [`DurableStore::gc_retired`] deletes them only after the grace window —
// long enough for a tailing follower to observe the new manifest — and a
// follower that still loses the race gets a typed restart-from-manifest
// signal ([`load_segment`] returning `Ok(None)`), never a crash. Files
// retired but not yet GC'd when the process exits are unreferenced by the
// manifest and get swept as orphans on the next open.

impl DurableStore {
    /// One compaction pass: repeatedly run single steps across all shards
    /// until a full sweep does nothing (merges cascade like binary-counter
    /// carries). Returns the number of merge/upgrade operations performed.
    /// Errors are counted and retried on a later pass, never fatal.
    pub fn compact_once(self: &Arc<Self>) -> usize {
        let mut ops = 0;
        loop {
            let mut progressed = false;
            for shard in 0..self.meta.shards.count {
                match self.compact_shard_step(shard) {
                    Ok(true) => {
                        progressed = true;
                        ops += 1;
                    }
                    Ok(false) => {}
                    Err(_) => self.compaction.errors.inc(),
                }
            }
            if !progressed {
                return ops;
            }
        }
    }

    /// Merge the rightmost adjacent segment pair whose right member has
    /// grown at least as large as its left neighbor; with nothing to
    /// merge, upgrade the leftmost legacy v1 segment to the v2 layout
    /// (only when this store writes v2, i.e. `opts.mmap`). Returns whether
    /// any work was done.
    fn compact_shard_step(self: &Arc<Self>, shard: usize) -> Result<bool> {
        // Pick the work item and reserve a file id under the manifest
        // lock, then do the heavy IO unlocked. The in-memory id bump is
        // crash-safe: an unpublished merged file is swept as an orphan,
        // and concurrent seals allocate past the reservation.
        let (left, right, merged_rel) = {
            let mut m = self.manifest.lock().unwrap();
            let lane = &mut m.lanes[shard];
            let segs = &lane.segments;
            let mut pick = None;
            for i in (0..segs.len().saturating_sub(1)).rev() {
                if segs[i + 1].records >= segs[i].records {
                    pick = Some(i);
                    break;
                }
            }
            let pick = match pick {
                Some(i) => i,
                None => {
                    if !self.opts.mmap {
                        return Ok(false);
                    }
                    match segs.iter().position(|s| s.format != SEG_VERSION_V2) {
                        Some(i) => {
                            let rel =
                                format!("shard-{shard}/seg-{:08}.seg", lane.next_file_id);
                            lane.next_file_id += 1;
                            let entry = segs[i].clone();
                            drop(m);
                            return self.upgrade_segment(shard, entry, rel).map(|()| true);
                        }
                        None => return Ok(false),
                    }
                }
            };
            let rel = format!("shard-{shard}/seg-{:08}.seg", lane.next_file_id);
            lane.next_file_id += 1;
            (segs[pick].clone(), segs[pick + 1].clone(), rel)
        };
        let dim = self.meta.dim;
        let n_models = self.meta.n_models;
        // Full verification on both inputs (including the embedding-slab
        // checksum — the buffered load path always checks it): a merge
        // must never launder latent corruption into a fresh checksum.
        let (mut gids, mut feedbacks, mut floats) =
            load_columns(&self.dir.join(&left.file), dim, n_models, &left)?;
        let (rg, rf, rx) = load_columns(&self.dir.join(&right.file), dim, n_models, &right)?;
        if let (Some(&last), Some(&first)) = (gids.last(), rg.first()) {
            if first <= last {
                bail!(
                    "shard {shard}: adjacent segments {} / {} have non-monotone gids",
                    left.file,
                    right.file
                );
            }
        }
        gids.extend_from_slice(&rg);
        feedbacks.extend(rf);
        floats.extend_from_slice(&rx);
        let merged = SegmentEntry {
            file: merged_rel,
            records: gids.len(),
            format: SEG_VERSION_V2,
            first_gid: gids.first().copied(),
            last_gid: gids.last().copied(),
        };
        write_segment_v2(
            &self.dir.join(&merged.file),
            dim,
            &gids,
            &feedbacks,
            &floats,
            self.opts.fsync,
        )?;
        self.publish_replacement(shard, &[&left.file, &right.file], merged)?;
        self.compaction.merges.inc();
        Ok(true)
    }

    /// Rewrite one v1 segment in the v2 layout under a fresh file name and
    /// swap it into the manifest (the migration path for pre-mmap stores).
    fn upgrade_segment(
        self: &Arc<Self>,
        shard: usize,
        entry: SegmentEntry,
        rel: String,
    ) -> Result<()> {
        let dim = self.meta.dim;
        let (gids, feedbacks, floats) =
            load_columns(&self.dir.join(&entry.file), dim, self.meta.n_models, &entry)?;
        let upgraded = SegmentEntry {
            file: rel,
            records: gids.len(),
            format: SEG_VERSION_V2,
            first_gid: gids.first().copied(),
            last_gid: gids.last().copied(),
        };
        write_segment_v2(
            &self.dir.join(&upgraded.file),
            dim,
            &gids,
            &feedbacks,
            &floats,
            self.opts.fsync,
        )?;
        self.publish_replacement(shard, &[&entry.file], upgraded)?;
        self.compaction.upgrades.inc();
        Ok(())
    }

    /// Swap `replacement` in for the (adjacent) run of entries named by
    /// `old` and retire their files into the GC grace queue. The entries
    /// are re-located by name under the lock: seals only append and this
    /// compactor is the only remover, so the run is still present and
    /// adjacent.
    fn publish_replacement(
        &self,
        shard: usize,
        old: &[&str],
        replacement: SegmentEntry,
    ) -> Result<()> {
        let mut m = self.manifest.lock().unwrap();
        let mut staged = m.clone();
        staged.generation += 1;
        let segs = &mut staged.lanes[shard].segments;
        let at = segs
            .iter()
            .position(|s| s.file == old[0])
            .ok_or_else(|| anyhow!("segment {} vanished from the manifest", old[0]))?;
        for (k, name) in old.iter().enumerate() {
            if segs.get(at + k).map(|s| s.file.as_str()) != Some(*name) {
                bail!("segment run {:?} no longer adjacent in the manifest", old);
            }
        }
        segs[at] = replacement;
        for _ in 1..old.len() {
            segs.remove(at + 1);
        }
        self.write_manifest(&staged)?;
        *m = staged;
        drop(m);
        let now = Instant::now();
        let mut retired = self.retired.lock().unwrap();
        retired.extend(old.iter().map(|name| (now, self.dir.join(name))));
        Ok(())
    }

    /// Delete retired files older than `grace`. Returns how many were
    /// deleted. Readers that mapped a deleted segment keep a valid view
    /// (POSIX keeps the pages until the last unmap); a follower opening
    /// one late gets the restart-from-manifest signal instead of an error.
    pub fn gc_retired(&self, grace: Duration) -> usize {
        let now = Instant::now();
        let mut deleted = 0usize;
        let mut retired = self.retired.lock().unwrap();
        retired.retain(|(when, path)| {
            if now.duration_since(*when) >= grace {
                let _ = fs::remove_file(path);
                deleted += 1;
                false
            } else {
                true
            }
        });
        drop(retired);
        self.compaction.gc_files.add(deleted as u64);
        deleted
    }
}

/// Load one segment fully verified and return it as merge-ready columns.
fn load_columns(
    path: &Path,
    dim: usize,
    n_models: usize,
    entry: &SegmentEntry,
) -> Result<(Vec<u32>, Vec<Feedback>, Vec<f32>)> {
    let seg = load_segment(path, dim, n_models, entry, false)?
        .ok_or_else(|| anyhow!("segment {} missing", path.display()))?;
    Ok(match seg {
        LoadedSegment::Decoded(records) => {
            let mut gids = Vec::with_capacity(records.len());
            let mut feedbacks = Vec::with_capacity(records.len());
            let mut floats = Vec::with_capacity(records.len() * dim);
            for (gid, obs) in records {
                gids.push(gid);
                floats.extend_from_slice(&obs.embedding);
                feedbacks.push(Feedback { comparisons: obs.comparisons });
            }
            (gids, feedbacks, floats)
        }
        LoadedSegment::Mapped(block) => {
            let floats = block.slab.as_f32s().to_vec();
            (block.gids, block.feedbacks, floats)
        }
    })
}

/// Owns the background compaction thread: one merge-until-quiescent pass
/// plus a GC sweep per tick. Dropping the handle (or calling
/// [`CompactorHandle::stop`]) stops the thread promptly — the sleep is
/// chunked so shutdown never waits out a full interval.
pub struct CompactorHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl CompactorHandle {
    pub fn spawn(
        store: Arc<DurableStore>,
        interval: Duration,
        grace: Duration,
    ) -> CompactorHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("eagle-compactor".into())
            .spawn(move || {
                while !flag.load(Ordering::Acquire) {
                    store.compact_once();
                    store.gc_retired(grace);
                    let step =
                        Duration::from_millis(25).min(interval.max(Duration::from_millis(1)));
                    let mut slept = Duration::ZERO;
                    while slept < interval && !flag.load(Ordering::Acquire) {
                        std::thread::sleep(step);
                        slept += step;
                    }
                }
            })
            .expect("spawning compactor thread");
        CompactorHandle { stop, thread: Some(thread) }
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for CompactorHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The per-shard appending side: owned by one applier thread. Appends are
/// buffered; [`DurableLaneWriter::sync`] (the persist beat / flush
/// barrier) flushes + fsyncs; crossing `seal_bytes` seals the tail into
/// an immutable segment and swaps the manifest.
pub struct DurableLaneWriter {
    store: Arc<DurableStore>,
    shard: usize,
    log: BufWriter<File>,
    /// Encoded frames not yet sealed into a segment (mirrors the live
    /// log's contents past the last seal; bounded by `seal_bytes`).
    unsealed: Vec<u8>,
    unsealed_records: usize,
    /// Gid range of the unsealed tail — becomes the manifest entry's
    /// range at seal time.
    unsealed_first_gid: Option<u32>,
    unsealed_last_gid: Option<u32>,
    /// Delta bytes appended by this writer since construction
    /// (diagnostics; the persist-cost bench reads it).
    appended_bytes: u64,
}

impl DurableLaneWriter {
    /// Append one record to the delta log (buffered; durable after the
    /// next [`DurableLaneWriter::sync`] or seal). Seals when the unsealed
    /// tail crosses the seal threshold.
    pub fn append(&mut self, gid: u32, obs: &Observation) -> Result<()> {
        let start = self.unsealed.len();
        encode_frame(&mut self.unsealed, gid, &obs.comparisons, &obs.embedding);
        self.log
            .write_all(&self.unsealed[start..])
            .context("appending to delta log")?;
        self.appended_bytes += (self.unsealed.len() - start) as u64;
        self.unsealed_records += 1;
        if self.unsealed_first_gid.is_none() {
            self.unsealed_first_gid = Some(gid);
        }
        self.unsealed_last_gid = Some(gid);
        if self.unsealed.len() >= self.store.opts.seal_bytes {
            self.seal()?;
        }
        Ok(())
    }

    /// Flush + fsync the delta log: everything appended so far is durable
    /// when this returns. This is the whole cost of a persist beat —
    /// O(bytes since the last sync), never O(corpus).
    pub fn sync(&mut self) -> Result<()> {
        self.log.flush().context("flushing delta log")?;
        if self.store.opts.fsync {
            self.log.get_ref().sync_data().context("fsync delta log")?;
        }
        Ok(())
    }

    pub fn appended_bytes(&self) -> u64 {
        self.appended_bytes
    }

    pub fn unsealed_records(&self) -> usize {
        self.unsealed_records
    }

    /// Seal the unsealed tail into an immutable segment file (written
    /// exactly once), start a fresh delta log, and atomically swap the
    /// manifest to the new live set. See the module docs for the crash
    /// windows.
    pub fn seal(&mut self) -> Result<()> {
        if self.unsealed_records == 0 {
            return Ok(());
        }
        self.log.flush().context("flushing delta log before seal")?;
        let store = self.store.clone();
        let mut m = store.manifest.lock().unwrap();
        let mut staged = m.clone();
        staged.generation += 1;
        let lane = &mut staged.lanes[self.shard];
        let seg_rel = format!("shard-{}/seg-{:08}.seg", self.shard, lane.next_file_id);
        let log_rel = format!("shard-{}/delta-{:08}.log", self.shard, lane.next_file_id + 1);
        lane.next_file_id += 2;
        let format = seal_segment_file(
            &store.dir.join(&seg_rel),
            store.meta.dim,
            store.meta.n_models,
            self.unsealed_records,
            &self.unsealed,
            &store.opts,
        )?;
        let new_log = File::create(store.dir.join(&log_rel))
            .with_context(|| format!("creating {log_rel}"))?;
        if store.opts.fsync {
            fsync_dir(&store.dir.join(format!("shard-{}", self.shard)));
        }
        lane.segments.push(SegmentEntry {
            file: seg_rel,
            records: self.unsealed_records,
            format,
            first_gid: self.unsealed_first_gid,
            last_gid: self.unsealed_last_gid,
        });
        let old_log_rel = std::mem::replace(&mut lane.log, log_rel);
        store.write_manifest(&staged)?;
        *m = staged;
        drop(m);
        // committed: retire the writer onto the fresh log; the old log is
        // garbage (its records live in the sealed segment now)
        self.log = BufWriter::new(new_log);
        self.unsealed.clear();
        self.unsealed_records = 0;
        self.unsealed_first_gid = None;
        self.unsealed_last_gid = None;
        let _ = fs::remove_file(store.dir.join(&old_log_rel));
        Ok(())
    }
}

/// One bootstrap segment for [`DurableStore::create_with`]: pre-encoded
/// frames plus the gid range they cover.
struct BootSegment {
    frames: Vec<u8>,
    records: usize,
    first_gid: Option<u32>,
    last_gid: Option<u32>,
}

/// Write one sealed segment from encoded frame bytes, choosing the format
/// from `opts.mmap`: v2 (fixed mmap-able layout; the frames are decoded
/// once, bounded by `seal_bytes`) or v1 (the frames verbatim behind a
/// 16-byte header). Returns the format written.
fn seal_segment_file(
    path: &Path,
    dim: usize,
    n_models: usize,
    records: usize,
    frames: &[u8],
    opts: &DurableOptions,
) -> Result<u32> {
    if !opts.mmap {
        write_segment(path, dim, records, frames, opts.fsync)?;
        return Ok(SEG_VERSION);
    }
    let (decoded, valid) = scan_frames(frames, dim, n_models);
    if decoded.len() != records || valid != frames.len() {
        bail!(
            "unsealed tail corrupt at seal: {} of {records} records decoded",
            decoded.len()
        );
    }
    let mut gids = Vec::with_capacity(records);
    let mut feedbacks = Vec::with_capacity(records);
    let mut floats = Vec::with_capacity(records * dim);
    for (gid, obs) in decoded {
        gids.push(gid);
        floats.extend_from_slice(&obs.embedding);
        feedbacks.push(crate::vectordb::Feedback { comparisons: obs.comparisons });
    }
    write_segment_v2(path, dim, &gids, &feedbacks, &floats, opts.fsync)?;
    Ok(SEG_VERSION_V2)
}

impl GlobalCheckpoint {
    fn empty() -> GlobalCheckpoint {
        GlobalCheckpoint {
            folded_gid: 0,
            state: GlobalEloState {
                last_iterate: Vec::new(),
                rating_sum: Vec::new(),
                samples: 0,
                history_len: 0,
            },
        }
    }
}

impl Recovery {
    /// Durable records recovered across all shards (from manifest record
    /// counts + log tails — no segment is read to answer this).
    pub fn total_records(&self) -> usize {
        self.lanes
            .iter()
            .map(|l| l.tail.len() + l.segments.iter().map(|s| s.records).sum::<usize>())
            .sum()
    }

    /// Load and fully decode one lane's records in durable order
    /// (segments then tail). Diagnostics/tests only — the recovery path
    /// itself streams via [`Recovery::resume`] and never materializes a
    /// whole lane.
    pub fn lane_records(&self, shard: usize) -> Result<Vec<(u32, Observation)>> {
        let lane = &self.lanes[shard];
        let mut out = Vec::new();
        for entry in &lane.segments {
            let seg = load_segment(
                &self.dir.join(&entry.file),
                self.meta.dim,
                self.meta.n_models,
                entry,
                false,
            )?
            .ok_or_else(|| anyhow!("segment {} missing", entry.file))?;
            seg.into_records(self.meta.dim, &mut out);
        }
        out.extend(lane.tail.iter().map(|(gid, obs)| (*gid, obs.clone())));
        Ok(out)
    }

    /// Begin incremental catch-up from this recovery's checkpoint and
    /// feed every durable record through it. Crash recovery is the
    /// degenerate "follower that already has everything" case:
    /// `resume(..)` followed by [`CatchUp::finish`]. The replica tail
    /// ([`crate::coordinator::replica`]) keeps the returned [`CatchUp`]
    /// open instead and applies frames as the leader writes them.
    pub fn resume(self, cadence: EpochParams) -> Result<CatchUp> {
        self.resume_reporting(cadence).map(|(catchup, _)| catchup)
    }

    /// [`Recovery::resume`], also reporting the transient-memory
    /// footprint of the pass. Segments are loaded, applied, and dropped
    /// strictly one at a time: with mmap enabled a v2 segment contributes
    /// only its side arrays (the embedding slab stays in the page cache
    /// behind a zero-copy view), and even the frame-decode fallback never
    /// holds more than one segment's records alive.
    pub fn resume_reporting(self, cadence: EpochParams) -> Result<(CatchUp, RecoveryFootprint)> {
        if self.lanes.len() != self.meta.shards.count {
            bail!(
                "manifest lane count {} != shard count {}",
                self.lanes.len(),
                self.meta.shards.count
            );
        }
        let (dim, n_models) = (self.meta.dim, self.meta.n_models);
        let tails_resident: usize = self.lanes.iter().map(|l| tail_resident_bytes(&l.tail)).sum();
        let mut fp = RecoveryFootprint {
            peak_resident_bytes: tails_resident,
            ..RecoveryFootprint::default()
        };
        let mut catchup = CatchUp::begin(self.meta, self.folded_gid, self.global, cadence);
        for (shard, lane) in self.lanes.into_iter().enumerate() {
            let mut prev_gid: Option<u32> = None;
            for entry in lane.segments {
                let seg =
                    load_segment(&self.dir.join(&entry.file), dim, n_models, &entry, self.opts.mmap)
                        .with_context(|| format!("segment {}", entry.file))?
                        .ok_or_else(|| anyhow!("segment {} missing", entry.file))?;
                if let Some(first) = seg.first_gid() {
                    if prev_gid.is_some_and(|prev| first <= prev) {
                        bail!("shard {shard}: non-monotone gid {first} in durable records");
                    }
                }
                prev_gid = seg.last_gid().or(prev_gid);
                let resident = seg.resident_bytes();
                fp.largest_segment_bytes = fp.largest_segment_bytes.max(resident);
                fp.total_segment_bytes += resident;
                fp.peak_resident_bytes = fp.peak_resident_bytes.max(tails_resident + resident);
                catchup.apply_loaded_segment(shard, seg);
            }
            for (gid, obs) in lane.tail {
                if prev_gid.is_some_and(|prev| gid <= prev) {
                    bail!("shard {shard}: non-monotone gid {gid} in durable records");
                }
                prev_gid = Some(gid);
                catchup.apply_delta_frame(shard, gid, obs);
            }
        }
        Ok((catchup, fp))
    }

    /// Rebuild the live [`ShardedRouter`] in one shot: resume catch-up
    /// from the checkpoint, replay every durable record, finish. The
    /// stores and id maps come straight from the records and the global
    /// table refolds every record with `gid >= folded_gid` in global
    /// arrival order — bit-identical to the pre-restart writer.
    pub fn into_router(self, cadence: EpochParams) -> Result<ShardedRouter> {
        Ok(self.resume(cadence)?.finish())
    }
}

/// Transient bytes a decoded log tail holds (embeddings + comparisons).
fn tail_resident_bytes(tail: &[(u32, Observation)]) -> usize {
    tail.iter()
        .map(|(_, obs)| obs.embedding.len() * 4 + obs.comparisons.len() * 9 + 32)
        .sum()
}

/// Incremental replay of the durable record stream — the single code path
/// shared by crash recovery ([`Recovery::resume`]) and the follower tail
/// loop ([`crate::coordinator::replica`]).
///
/// Records are applied to live [`ShardLane`]s exactly as the ingest
/// appliers apply fresh verdicts, so the rebuilt state is the live state.
/// Comparisons fold into the global table strictly in ascending-gid
/// order: the stream interleaves shard lanes, so an out-of-order arrival
/// (one lane's log read before another's) waits in a pending buffer until
/// the gid sequence is contiguous. [`CatchUp::finish`] folds whatever is
/// still pending in ascending order — a permanent gap is a torn-away
/// record, exactly the case single-shot crash recovery skips over.
pub struct CatchUp {
    meta: StoreMeta,
    global: GlobalLane,
    lanes: Vec<ShardLane>,
    /// Highest gid applied per lane: replays of a just-sealed segment
    /// overlap the already-tailed log, so stale gids are skipped.
    last_gid: Vec<Option<u32>>,
    /// Comparisons awaiting a contiguous gid run, keyed by gid.
    pending: BTreeMap<u32, Vec<Comparison>>,
    /// Next gid to fold into the global table.
    fold_next: u32,
    /// Next unassigned global arrival id implied by everything applied.
    next_id: u32,
}

impl CatchUp {
    /// Start catch-up from a checkpoint: empty lanes, the global table
    /// resumed from `global` (uniform when the checkpoint is empty), the
    /// fold frontier at `folded_gid`.
    pub fn begin(
        meta: StoreMeta,
        folded_gid: u32,
        global: GlobalEloState,
        cadence: EpochParams,
    ) -> CatchUp {
        let elo = if global.last_iterate.is_empty() {
            GlobalElo::new(meta.n_models, meta.params.k_factor)
        } else {
            GlobalElo::from_state(global, meta.params.k_factor)
        };
        let lanes: Vec<ShardLane> = (0..meta.shards.count)
            .map(|_| {
                ShardLane::with_ids(
                    RouterWriter::from_segment_router(
                        EagleRouter::new(
                            meta.params.clone(),
                            meta.n_models,
                            SegmentStore::new(meta.dim),
                        ),
                        cadence.clone(),
                    ),
                    IdBlocks::new(),
                )
            })
            .collect();
        CatchUp {
            global: GlobalLane::from_elo(elo, cadence),
            lanes,
            last_gid: vec![None; meta.shards.count],
            pending: BTreeMap::new(),
            fold_next: folded_gid,
            next_id: folded_gid,
            meta,
        }
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    /// The next unassigned global arrival id (max applied gid + 1).
    pub fn next_global_id(&self) -> u32 {
        self.next_id
    }

    /// Records applied to shard lanes across all segments and tails.
    pub fn applied_records(&self) -> usize {
        self.lanes.iter().map(|l| l.writer().router().store().len()).sum()
    }

    /// Comparisons decoded but still waiting for a contiguous gid run
    /// before folding into the global table (tail-lag diagnostics).
    pub fn pending_folds(&self) -> usize {
        self.pending.len()
    }

    /// Highest gid applied to one shard lane so far. This is the
    /// follower's tail cursor: manifest segments whose `last_gid` sits at
    /// or below the frontier are already applied and are skipped without
    /// opening the file, which is what makes tailing robust against the
    /// compactor rewriting the segment list underneath it.
    pub fn lane_frontier(&self, shard: usize) -> Option<u32> {
        self.last_gid[shard]
    }

    /// Apply one sealed segment's records (ascending gid); already-applied
    /// gids — the segment overlaps the log it was sealed from — are
    /// skipped.
    pub fn apply_sealed_segment(&mut self, shard: usize, records: Vec<(u32, Observation)>) {
        for (gid, obs) in records {
            self.apply_delta_frame(shard, gid, obs);
        }
    }

    /// Apply one loaded segment file. Decoded (v1) segments replay
    /// per-record; mapped (v2) segments take the bulk path — the lane
    /// store adopts the embedding slab as one zero-copy sealed block
    /// while comparisons fold per-record, which is bit-identical to the
    /// per-record replay (scan order and fold order are unchanged; only
    /// where the floats live differs). If the segment overlaps records
    /// this catch-up already applied (a compacted segment re-covering a
    /// tailed range), the overlap forces the per-record path so the
    /// stale-gid dedup can skip them.
    pub(crate) fn apply_loaded_segment(&mut self, shard: usize, seg: LoadedSegment) {
        let block = match seg {
            LoadedSegment::Decoded(records) => {
                self.apply_sealed_segment(shard, records);
                return;
            }
            LoadedSegment::Mapped(block) => block,
        };
        let overlaps = match (block.gids.first(), self.last_gid[shard]) {
            (Some(&first), Some(prev)) => first <= prev,
            _ => false,
        };
        if overlaps || block.gids.is_empty() {
            let dim = self.meta.dim;
            let mut records = Vec::with_capacity(block.gids.len());
            block.into_records(dim, &mut records);
            self.apply_sealed_segment(shard, records);
            return;
        }
        for (i, &gid) in block.gids.iter().enumerate() {
            self.next_id = self.next_id.max(gid + 1);
            if gid >= self.fold_next {
                self.pending.insert(gid, block.feedbacks[i].comparisons.clone());
                while let Some(cmps) = self.pending.remove(&self.fold_next) {
                    self.global.apply(&cmps);
                    self.fold_next += 1;
                }
            }
        }
        self.last_gid[shard] = block.gids.last().copied();
        self.lanes[shard].apply_block(&block.gids, block.slab, block.feedbacks);
    }

    /// Apply one delta-log frame. Returns false when the record was
    /// already applied (stale gid for its lane).
    pub fn apply_delta_frame(&mut self, shard: usize, gid: u32, obs: Observation) -> bool {
        if self.last_gid[shard].is_some_and(|prev| gid <= prev) {
            return false;
        }
        self.last_gid[shard] = Some(gid);
        self.next_id = self.next_id.max(gid + 1);
        if gid >= self.fold_next {
            self.pending.insert(gid, obs.comparisons.clone());
            while let Some(cmps) = self.pending.remove(&self.fold_next) {
                self.global.apply(&cmps);
                self.fold_next += 1;
            }
        }
        self.lanes[shard].apply(gid, obs);
        true
    }

    /// Publish whichever lanes (and the global table) have tripped their
    /// epoch cadence — the follower tail loop's staleness beat.
    pub fn maybe_publish_all(&mut self) {
        self.global.maybe_publish();
        for lane in &mut self.lanes {
            lane.maybe_publish();
        }
    }

    /// Publish every lane and the global table unconditionally.
    pub fn publish_all(&mut self) {
        self.global.publish();
        for lane in &mut self.lanes {
            lane.publish();
        }
    }

    /// Reader handle over the lanes being caught up: a follower serves
    /// the scatter-gather route path from the same rings the tail loop is
    /// filling, and the handle stays valid across [`CatchUp::finish`] /
    /// promotion (the rings are shared, not rebuilt).
    pub fn handle(&self) -> ShardedHandle {
        super::sharded::handle_of(
            self.meta.params.clone(),
            self.meta.dim,
            &self.global,
            &self.lanes,
        )
    }

    /// Fold any still-pending comparisons in ascending gid order (gaps
    /// are torn-away records — the same skip crash recovery performs),
    /// publish everything, and assemble the live router around the same
    /// lanes and rings.
    pub fn finish(mut self) -> ShardedRouter {
        for cmps in std::mem::take(&mut self.pending).into_values() {
            self.global.apply(&cmps);
        }
        self.publish_all();
        ShardedRouter::from_lanes(
            self.meta.params,
            self.meta.n_models,
            self.meta.dim,
            self.meta.shards,
            self.global,
            self.lanes,
            self.next_id,
        )
    }
}

// ---- record framing ----------------------------------------------------
//
// One frame: [payload_len: u32 LE][checksum: u32 LE][payload], where
// payload = gid u32 | n_cmps u32 | n_cmps x (a u32, b u32, outcome u8) |
// dim x f32 bit patterns, all LE. The checksum covers the payload; a
// short or checksum-failing frame at a log's end is a torn write.

/// FNV-1a 64 folded to 32 bits — torn-write detection, not cryptography.
fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    (h ^ (h >> 32)) as u32
}

fn outcome_byte(o: Outcome) -> u8 {
    match o {
        Outcome::WinA => 0,
        Outcome::WinB => 1,
        Outcome::Draw => 2,
    }
}

fn outcome_of(b: u8) -> Option<Outcome> {
    match b {
        0 => Some(Outcome::WinA),
        1 => Some(Outcome::WinB),
        2 => Some(Outcome::Draw),
        _ => None,
    }
}

/// Append one encoded frame to `out`.
fn encode_frame(out: &mut Vec<u8>, gid: u32, comparisons: &[Comparison], embedding: &[f32]) {
    let payload_len = 8 + comparisons.len() * 9 + embedding.len() * 4;
    out.reserve(8 + payload_len);
    let start = out.len();
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(&[0u8; 4]); // checksum backpatched below
    out.extend_from_slice(&gid.to_le_bytes());
    out.extend_from_slice(&(comparisons.len() as u32).to_le_bytes());
    for c in comparisons {
        out.extend_from_slice(&(c.a as u32).to_le_bytes());
        out.extend_from_slice(&(c.b as u32).to_le_bytes());
        out.push(outcome_byte(c.outcome));
    }
    for &x in embedding {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    let crc = checksum(&out[start + 8..]);
    out[start + 4..start + 8].copy_from_slice(&crc.to_le_bytes());
}

/// One scan step over framed bytes.
enum Frame {
    Record { next: usize, gid: u32, obs: Observation },
    /// Ran off the end mid-frame (torn final write).
    Truncated,
    /// Structurally invalid or checksum-failing frame.
    Corrupt,
}

fn u32_at(bytes: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap())
}

/// Decode the frame starting at `pos`.
fn decode_frame(bytes: &[u8], pos: usize, dim: usize, n_models: usize) -> Frame {
    if bytes.len() - pos < 8 {
        return Frame::Truncated;
    }
    let payload_len = u32_at(bytes, pos) as usize;
    let emb_bytes = dim * 4;
    if payload_len < 8 + emb_bytes || (payload_len - 8 - emb_bytes) % 9 != 0 {
        // implausible frame length
        return Frame::Corrupt;
    }
    if bytes.len() - pos - 8 < payload_len {
        return Frame::Truncated;
    }
    let crc = u32_at(bytes, pos + 4);
    let payload = &bytes[pos + 8..pos + 8 + payload_len];
    if checksum(payload) != crc {
        // checksum mismatch
        return Frame::Corrupt;
    }
    let gid = u32_at(payload, 0);
    let n_cmps = u32_at(payload, 4) as usize;
    if 8 + n_cmps * 9 + emb_bytes != payload_len {
        // comparison count disagrees with frame length
        return Frame::Corrupt;
    }
    let mut comparisons = Vec::with_capacity(n_cmps);
    let mut at = 8;
    for _ in 0..n_cmps {
        let a = u32_at(payload, at) as usize;
        let b = u32_at(payload, at + 4) as usize;
        let Some(outcome) = outcome_of(payload[at + 8]) else {
            // bad outcome byte
            return Frame::Corrupt;
        };
        if a >= n_models || b >= n_models {
            // model index out of range
            return Frame::Corrupt;
        }
        comparisons.push(Comparison { a, b, outcome });
        at += 9;
    }
    let mut embedding = Vec::with_capacity(dim);
    for _ in 0..dim {
        embedding.push(f32::from_bits(u32_at(payload, at)));
        at += 4;
    }
    Frame::Record {
        next: pos + 8 + payload_len,
        gid,
        obs: Observation { embedding, comparisons },
    }
}

/// Scan framed bytes, returning the decoded records and the byte length
/// of the valid prefix (anything past it is a torn/corrupt tail).
///
/// This is the *read-only* scan: the follower tail uses it directly on a
/// live leader's log (never [`recover_log`], which truncates).
pub(crate) fn scan_frames(
    bytes: &[u8],
    dim: usize,
    n_models: usize,
) -> (Vec<(u32, Observation)>, usize) {
    let mut records = Vec::new();
    let mut pos = 0;
    while pos < bytes.len() {
        match decode_frame(bytes, pos, dim, n_models) {
            Frame::Record { next, gid, obs } => {
                records.push((gid, obs));
                pos = next;
            }
            Frame::Truncated | Frame::Corrupt => break,
        }
    }
    (records, pos)
}

// ---- file IO -----------------------------------------------------------

/// tmp + rename (+ fsync file and directory when `fsync`): the write is
/// atomic — readers see either the old file or the complete new one.
fn write_atomic(path: &Path, bytes: &[u8], fsync: bool) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("writing {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        if fsync {
            f.sync_data().with_context(|| format!("fsync {}", tmp.display()))?;
        }
    }
    fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
    if fsync {
        if let Some(parent) = path.parent() {
            fsync_dir(parent);
        }
    }
    Ok(())
}

/// Best-effort directory fsync (makes renames/creates durable on linux;
/// a no-op where directories cannot be opened).
fn fsync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Advisory single-writer guard: a `LOCK` file holding the owner pid.
/// A *different, still-running* process holding the lock refuses the
/// open — two live servers appending to one store would interleave
/// conflicting gid sequences and corrupt it. A dead owner (crash — the
/// recovery case) or the same process (restart-in-process, tests) takes
/// the lock over. Liveness is checked via `/proc/<pid>`; where that is
/// unavailable the owner is assumed dead, keeping recovery possible.
pub(crate) fn acquire_lock(dir: &Path) -> Result<()> {
    let path = dir.join(LOCK);
    let my_pid = std::process::id();
    if let Ok(text) = fs::read_to_string(&path) {
        if let Ok(pid) = text.trim().parse::<u32>() {
            if pid != my_pid
                && Path::new("/proc").is_dir()
                && Path::new(&format!("/proc/{pid}")).is_dir()
            {
                bail!(
                    "durable store {} is in use by live process {pid} \
                     (delete {LOCK} only if that pid is not an eagle server)",
                    dir.display()
                );
            }
        }
    }
    fs::write(&path, my_pid.to_string()).with_context(|| format!("writing {}", path.display()))
}

/// Write one immutable segment file: header + pre-encoded frames.
fn write_segment(
    path: &Path,
    dim: usize,
    records: usize,
    frames: &[u8],
    fsync: bool,
) -> Result<()> {
    let mut bytes = Vec::with_capacity(SEG_HEADER_BYTES + frames.len());
    bytes.extend_from_slice(&SEG_MAGIC.to_le_bytes());
    bytes.extend_from_slice(&SEG_VERSION.to_le_bytes());
    bytes.extend_from_slice(&(dim as u32).to_le_bytes());
    bytes.extend_from_slice(&(records as u32).to_le_bytes());
    bytes.extend_from_slice(frames);
    write_atomic(path, &bytes, fsync)
}

/// Read + fully validate one sealed segment. Segments are written once
/// and fsynced before the manifest references them, so any damage is a
/// hard error, never a silent truncation.
pub(crate) fn read_segment(
    path: &Path,
    dim: usize,
    n_models: usize,
    expect_records: usize,
) -> Result<Vec<(u32, Observation)>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < SEG_HEADER_BYTES {
        bail!("segment shorter than its header");
    }
    if u32_at(&bytes, 0) != SEG_MAGIC {
        bail!("bad segment magic");
    }
    if u32_at(&bytes, 4) != SEG_VERSION {
        bail!("unsupported segment version {}", u32_at(&bytes, 4));
    }
    if u32_at(&bytes, 8) as usize != dim {
        bail!("segment dim {} != store dim {dim}", u32_at(&bytes, 8));
    }
    let count = u32_at(&bytes, 12) as usize;
    if count != expect_records {
        bail!("segment holds {count} records, manifest says {expect_records}");
    }
    let (records, valid) = scan_frames(&bytes[SEG_HEADER_BYTES..], dim, n_models);
    if records.len() != count || SEG_HEADER_BYTES + valid != bytes.len() {
        bail!(
            "segment corrupt: {} of {count} records decoded cleanly",
            records.len()
        );
    }
    Ok(records)
}

// ---- segment format v2 (mmap-able fixed layout) --------------------------
//
// byte offset │ contents
// ────────────┼────────────────────────────────────────────────────────────
//           0 │ magic u32 ("EAGS")
//           4 │ version u32 = 2
//           8 │ dim u32
//          12 │ records u32
//          16 │ n_cmps u64 (total comparisons across all records)
//          24 │ gids_crc u32 (over the gid array)
//          28 │ cmps_crc u32 (over prefix sums + comparison bytes)
//          32 │ emb_crc u32 (over the embedding slab; verified at write
//             │ and on the buffered-decode path — the mmap path skips it
//             │ so open stays O(1) in slab bytes)
//          36 │ header_crc u32 (over bytes 0..36)
//          40 │ zero pad to 64
//          64 │ gids: records × u32 LE, strictly ascending
//             │ cmp prefix sums: (records + 1) × u32 LE
//             │ comparisons: n_cmps × (a u32, b u32, outcome u8)
//             │ zero pad to the next 64-byte boundary
//     emb_off │ embedding slab: records × dim × f32 LE bit patterns
//
// The slab's 64-byte file alignment plus a page-aligned mmap base makes
// the mapped `&[f32]` view alignment-safe; [`crate::mmap::SlabRef`]
// re-checks at construction.

/// One segment file loaded for replay.
pub(crate) enum LoadedSegment {
    /// Fully decoded records (v1 files, or any buffered fallback that
    /// went through per-record decode).
    Decoded(Vec<(u32, Observation)>),
    /// A v2 segment: decoded side arrays + the embedding slab as a
    /// zero-copy mapped view (or an owned buffer on the fallback path).
    Mapped(MappedSegment),
}

pub(crate) struct MappedSegment {
    pub(crate) gids: Vec<u32>,
    pub(crate) feedbacks: Vec<crate::vectordb::Feedback>,
    pub(crate) slab: Slab,
}

impl LoadedSegment {
    pub(crate) fn first_gid(&self) -> Option<u32> {
        match self {
            LoadedSegment::Decoded(records) => records.first().map(|(gid, _)| *gid),
            LoadedSegment::Mapped(block) => block.gids.first().copied(),
        }
    }

    pub(crate) fn last_gid(&self) -> Option<u32> {
        match self {
            LoadedSegment::Decoded(records) => records.last().map(|(gid, _)| *gid),
            LoadedSegment::Mapped(block) => block.gids.last().copied(),
        }
    }

    pub(crate) fn records(&self) -> usize {
        match self {
            LoadedSegment::Decoded(records) => records.len(),
            LoadedSegment::Mapped(block) => block.gids.len(),
        }
    }

    /// Transient heap bytes this loaded segment holds (a mapped slab
    /// counts zero — its pages belong to the page cache).
    pub(crate) fn resident_bytes(&self) -> usize {
        match self {
            LoadedSegment::Decoded(records) => records
                .iter()
                .map(|(_, obs)| obs.embedding.len() * 4 + obs.comparisons.len() * 9 + 32)
                .sum(),
            LoadedSegment::Mapped(block) => {
                let sides = block.gids.len() * 4
                    + block.feedbacks.iter().map(|f| f.comparisons.len() * 9 + 24).sum::<usize>();
                match &block.slab {
                    Slab::Owned(v) => sides + v.len() * 4,
                    Slab::Mapped(_) => sides,
                }
            }
        }
    }

    /// Materialize as decoded records (per-record fallback / diagnostics).
    pub(crate) fn into_records(self, dim: usize, out: &mut Vec<(u32, Observation)>) {
        match self {
            LoadedSegment::Decoded(records) => out.extend(records),
            LoadedSegment::Mapped(block) => block.into_records(dim, out),
        }
    }
}

impl MappedSegment {
    fn into_records(self, dim: usize, out: &mut Vec<(u32, Observation)>) {
        let floats = self.slab.as_f32s();
        for (i, (gid, fb)) in self.gids.iter().zip(self.feedbacks).enumerate() {
            out.push((
                *gid,
                Observation {
                    embedding: floats[i * dim..(i + 1) * dim].to_vec(),
                    comparisons: fb.comparisons,
                },
            ));
        }
    }
}

/// Write one v2 segment file (layout above) via the same atomic
/// tmp + rename (+ fsync) protocol as every other durable artifact.
fn write_segment_v2(
    path: &Path,
    dim: usize,
    gids: &[u32],
    feedbacks: &[crate::vectordb::Feedback],
    floats: &[f32],
    fsync: bool,
) -> Result<()> {
    let records = gids.len();
    assert_eq!(feedbacks.len(), records);
    assert_eq!(floats.len(), records * dim);
    let n_cmps: usize = feedbacks.iter().map(|f| f.comparisons.len()).sum();
    let side_len = records * 4 + (records + 1) * 4 + n_cmps * 9;
    let emb_off = next_multiple(SEG2_HEADER_BYTES + side_len, SEG2_SLAB_ALIGN);
    let mut bytes = Vec::with_capacity(emb_off + floats.len() * 4);
    bytes.resize(SEG2_HEADER_BYTES, 0);
    for gid in gids {
        bytes.extend_from_slice(&gid.to_le_bytes());
    }
    let offs_start = bytes.len();
    let mut running = 0u32;
    bytes.extend_from_slice(&running.to_le_bytes());
    for fb in feedbacks {
        running += fb.comparisons.len() as u32;
        bytes.extend_from_slice(&running.to_le_bytes());
    }
    for fb in feedbacks {
        for c in &fb.comparisons {
            bytes.extend_from_slice(&(c.a as u32).to_le_bytes());
            bytes.extend_from_slice(&(c.b as u32).to_le_bytes());
            bytes.push(outcome_byte(c.outcome));
        }
    }
    let cmps_end = bytes.len();
    bytes.resize(emb_off, 0);
    for &x in floats {
        bytes.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    let gids_crc = checksum(&bytes[SEG2_HEADER_BYTES..offs_start]);
    let cmps_crc = checksum(&bytes[offs_start..cmps_end]);
    let emb_crc = checksum(&bytes[emb_off..]);
    bytes[0..4].copy_from_slice(&SEG_MAGIC.to_le_bytes());
    bytes[4..8].copy_from_slice(&SEG_VERSION_V2.to_le_bytes());
    bytes[8..12].copy_from_slice(&(dim as u32).to_le_bytes());
    bytes[12..16].copy_from_slice(&(records as u32).to_le_bytes());
    bytes[16..24].copy_from_slice(&(n_cmps as u64).to_le_bytes());
    bytes[24..28].copy_from_slice(&gids_crc.to_le_bytes());
    bytes[28..32].copy_from_slice(&cmps_crc.to_le_bytes());
    bytes[32..36].copy_from_slice(&emb_crc.to_le_bytes());
    let header_crc = checksum(&bytes[0..36]);
    bytes[36..40].copy_from_slice(&header_crc.to_le_bytes());
    write_atomic(path, &bytes, fsync)
}

fn next_multiple(n: usize, align: usize) -> usize {
    n.div_ceil(align) * align
}

/// Parse + validate a v2 segment's header and side arrays from its full
/// byte image. Returns the decoded side arrays plus the slab's byte
/// offset and checksum; the caller decides whether to verify the slab
/// (buffered path) or trust the write-time checksum (mmap path).
#[allow(clippy::type_complexity)]
fn parse_v2(
    bytes: &[u8],
    dim: usize,
    n_models: usize,
    expect_records: usize,
) -> Result<(Vec<u32>, Vec<crate::vectordb::Feedback>, usize, u32)> {
    if bytes.len() < SEG2_HEADER_BYTES {
        bail!("v2 segment shorter than its header");
    }
    if u32_at(bytes, 0) != SEG_MAGIC {
        bail!("bad segment magic");
    }
    if u32_at(bytes, 4) != SEG_VERSION_V2 {
        bail!("unsupported segment version {}", u32_at(bytes, 4));
    }
    if checksum(&bytes[0..36]) != u32_at(bytes, 36) {
        bail!("v2 segment header checksum mismatch");
    }
    if u32_at(bytes, 8) as usize != dim {
        bail!("segment dim {} != store dim {dim}", u32_at(bytes, 8));
    }
    let records = u32_at(bytes, 12) as usize;
    if records != expect_records {
        bail!("segment holds {records} records, manifest says {expect_records}");
    }
    let n_cmps = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let gids_off = SEG2_HEADER_BYTES;
    let offs_off = gids_off + records * 4;
    let cmps_off = offs_off + (records + 1) * 4;
    let cmps_end = cmps_off + n_cmps * 9;
    let emb_off = next_multiple(cmps_end, SEG2_SLAB_ALIGN);
    if bytes.len() != emb_off + records * dim * 4 {
        bail!(
            "v2 segment length {} != expected {}",
            bytes.len(),
            emb_off + records * dim * 4
        );
    }
    if checksum(&bytes[gids_off..offs_off]) != u32_at(bytes, 24) {
        bail!("v2 segment gid array checksum mismatch");
    }
    if checksum(&bytes[offs_off..cmps_end]) != u32_at(bytes, 28) {
        bail!("v2 segment comparison array checksum mismatch");
    }
    let mut gids = Vec::with_capacity(records);
    for i in 0..records {
        let gid = u32_at(bytes, gids_off + i * 4);
        if gids.last().is_some_and(|&prev| gid <= prev) {
            bail!("v2 segment gids not strictly ascending");
        }
        gids.push(gid);
    }
    let mut offs = Vec::with_capacity(records + 1);
    for i in 0..=records {
        offs.push(u32_at(bytes, offs_off + i * 4) as usize);
    }
    if offs[0] != 0 || offs[records] != n_cmps || offs.windows(2).any(|w| w[0] > w[1]) {
        bail!("v2 segment comparison prefix sums inconsistent");
    }
    let mut feedbacks = Vec::with_capacity(records);
    for i in 0..records {
        let mut comparisons = Vec::with_capacity(offs[i + 1] - offs[i]);
        for c in offs[i]..offs[i + 1] {
            let at = cmps_off + c * 9;
            let a = u32_at(bytes, at) as usize;
            let b = u32_at(bytes, at + 4) as usize;
            let Some(outcome) = outcome_of(bytes[at + 8]) else {
                bail!("v2 segment holds an invalid outcome byte");
            };
            if a >= n_models || b >= n_models {
                bail!("v2 segment comparison model index out of range");
            }
            comparisons.push(Comparison { a, b, outcome });
        }
        feedbacks.push(crate::vectordb::Feedback { comparisons });
    }
    Ok((gids, feedbacks, emb_off, u32_at(bytes, 32)))
}

/// Load one sealed segment for replay. Returns `Ok(None)` when the file
/// no longer exists — the typed "restart from the manifest" signal a
/// tailing follower gets when the leader's GC deleted a segment it was
/// about to read (never a hard crash). Crash recovery and the compactor
/// treat `None` as a hard error instead: the manifest they hold is
/// current, so a missing file is real damage.
///
/// v2 segments are mapped read-only when `use_mmap` holds (little-endian
/// unix hosts): side arrays decode eagerly, the embedding slab is served
/// from the page cache behind a zero-copy view. Everywhere else the file
/// is read + fully verified, including the slab checksum.
pub(crate) fn load_segment(
    path: &Path,
    dim: usize,
    n_models: usize,
    entry: &SegmentEntry,
    use_mmap: bool,
) -> Result<Option<LoadedSegment>> {
    if entry.format == SEG_VERSION_V2 && use_mmap && cfg!(target_endian = "little") {
        let file = match File::open(path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("opening {}", path.display()));
            }
        };
        if let Ok(map) = crate::mmap::Mapping::map(&file) {
            let map = Arc::new(map);
            let (gids, feedbacks, emb_off, _emb_crc) =
                parse_v2(map.bytes(), dim, n_models, entry.records)
                    .with_context(|| format!("segment {}", path.display()))?;
            let floats = gids.len() * dim;
            let slab = crate::mmap::SlabRef::new(Arc::clone(&map), emb_off, floats)
                .ok_or_else(|| anyhow!("v2 segment slab out of mapped bounds"))?;
            return Ok(Some(LoadedSegment::Mapped(MappedSegment {
                gids,
                feedbacks,
                slab: Slab::Mapped(slab),
            })));
        }
        // map failed (exotic fs, resource limits): buffered fallback below
    }
    let bytes = match fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    if bytes.len() >= 8 && u32_at(&bytes, 0) == SEG_MAGIC && u32_at(&bytes, 4) == SEG_VERSION_V2 {
        let (gids, feedbacks, emb_off, emb_crc) = parse_v2(&bytes, dim, n_models, entry.records)
            .with_context(|| format!("segment {}", path.display()))?;
        if checksum(&bytes[emb_off..]) != emb_crc {
            bail!("segment {}: embedding slab checksum mismatch", path.display());
        }
        let mut floats = Vec::with_capacity(gids.len() * dim);
        for i in 0..gids.len() * dim {
            floats.push(f32::from_bits(u32_at(&bytes, emb_off + i * 4)));
        }
        return Ok(Some(LoadedSegment::Mapped(MappedSegment {
            gids,
            feedbacks,
            slab: Slab::Owned(floats),
        })));
    }
    // v1 framed segment (or damage — read_segment reports it precisely)
    let records = read_segment(path, dim, n_models, entry.records)
        .with_context(|| format!("segment {}", path.display()))?;
    Ok(Some(LoadedSegment::Decoded(records)))
}

/// A delta log replayed back from disk (truncated to its valid prefix).
pub(crate) struct LogReplay {
    pub(crate) records: Vec<(u32, Observation)>,
    /// The validated raw frame bytes (exactly what remains in the file).
    pub(crate) bytes: Vec<u8>,
    /// Bytes dropped because the final write was torn.
    pub(crate) lost: u64,
}

/// Replay a delta log, truncating the file to the last full record if the
/// final write was torn. Mutating — only the lock holder may call this;
/// a follower tailing a live leader uses [`scan_frames`] instead.
pub(crate) fn recover_log(path: &Path, dim: usize, n_models: usize) -> Result<LogReplay> {
    if !path.exists() {
        // a crash between manifest swap and log creation: the live log is
        // simply empty
        File::create(path).with_context(|| format!("creating {}", path.display()))?;
        return Ok(LogReplay { records: Vec::new(), bytes: Vec::new(), lost: 0 });
    }
    let mut bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let (records, valid) = scan_frames(&bytes, dim, n_models);
    let lost = (bytes.len() - valid) as u64;
    if lost > 0 {
        bytes.truncate(valid);
        let f = OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("truncating {}", path.display()))?;
        f.set_len(valid as u64)
            .with_context(|| format!("truncating {}", path.display()))?;
        let _ = f.sync_data();
    }
    Ok(LogReplay { records, bytes, lost })
}

/// Delete files a crashed seal left behind (segments/logs/tmp files not
/// referenced by the manifest).
pub(crate) fn sweep_orphans(dir: &Path, shard_count: usize, referenced: &HashSet<PathBuf>) {
    let _ = fs::remove_file(dir.join(MANIFEST).with_extension("tmp"));
    for shard in 0..shard_count {
        let Ok(entries) = fs::read_dir(dir.join(format!("shard-{shard}"))) else {
            continue;
        };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.is_file() && !referenced.contains(&path) {
                let _ = fs::remove_file(&path);
            }
        }
    }
}

// ---- manifest (de)serialization ----------------------------------------

fn f64_vec(vs: &[f64]) -> Value {
    Value::Arr(vs.iter().map(|&v| json::num(v)).collect())
}

fn manifest_json(meta: &StoreMeta, state: &ManifestState) -> String {
    let lanes: Vec<Value> = state
        .lanes
        .iter()
        .map(|l| {
            let segments: Vec<Value> = l
                .segments
                .iter()
                .map(|s| {
                    let mut fields = vec![
                        ("file", json::str_v(&s.file)),
                        ("records", json::num(s.records as f64)),
                        ("format", json::num(f64::from(s.format))),
                    ];
                    // gid range only when known (entries carried over from
                    // pre-1.1 manifests stay rangeless until compacted)
                    if let (Some(first), Some(last)) = (s.first_gid, s.last_gid) {
                        fields.push(("first_gid", json::num(f64::from(first))));
                        fields.push(("last_gid", json::num(f64::from(last))));
                    }
                    json::obj(fields)
                })
                .collect();
            json::obj(vec![
                ("next_file_id", json::num(l.next_file_id as f64)),
                ("log", json::str_v(&l.log)),
                ("segments", Value::Arr(segments)),
            ])
        })
        .collect();
    json::obj(vec![
        ("format_version", json::num(MANIFEST_VERSION)),
        ("generation", json::num(state.generation as f64)),
        ("dim", json::num(meta.dim as f64)),
        ("n_models", json::num(meta.n_models as f64)),
        ("p", json::num(meta.params.p)),
        ("n_neighbors", json::num(meta.params.n_neighbors as f64)),
        ("k_factor", json::num(meta.params.k_factor)),
        ("shard_count", json::num(meta.shards.count as f64)),
        // decimal string: u64 seeds must roundtrip exactly
        ("hash_seed", json::str_v(&meta.shards.hash_seed.to_string())),
        (
            "global",
            json::obj(vec![
                ("folded_gid", json::num(f64::from(state.global.folded_gid))),
                (
                    "history_len",
                    json::num(state.global.state.history_len as f64),
                ),
                ("samples", json::str_v(&state.global.state.samples.to_string())),
                ("last", f64_vec(&state.global.state.last_iterate)),
                ("sum", f64_vec(&state.global.state.rating_sum)),
            ]),
        ),
        ("lanes", Value::Arr(lanes)),
    ])
    .to_json()
}

fn f64s_of(v: &Value, what: &str) -> Result<Vec<f64>> {
    v.as_arr()
        .with_context(|| format!("manifest: {what}"))?
        .iter()
        .map(|x| x.as_f64().with_context(|| format!("manifest: {what} entry")))
        .collect()
}

pub(crate) fn parse_manifest(text: &str) -> Result<(StoreMeta, ManifestState)> {
    let v = json::parse(text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let version = v.get("format_version").as_f64().context("format_version")?;
    if version > MANIFEST_VERSION {
        bail!("manifest version {version} is newer than supported {MANIFEST_VERSION}");
    }
    // additive in-version field: absent on pre-replication manifests
    let generation = v.get("generation").as_usize().unwrap_or(0) as u64;
    let meta = StoreMeta {
        params: EagleParams {
            p: v.get("p").as_f64().context("p")?,
            n_neighbors: v.get("n_neighbors").as_usize().context("n_neighbors")?,
            k_factor: v.get("k_factor").as_f64().context("k_factor")?,
        },
        n_models: v.get("n_models").as_usize().context("n_models")?,
        dim: v.get("dim").as_usize().context("dim")?,
        shards: ShardParams {
            count: v.get("shard_count").as_usize().context("shard_count")?,
            hash_seed: v
                .get("hash_seed")
                .as_str()
                .context("hash_seed")?
                .parse()
                .context("hash_seed")?,
        },
    };
    let g = v.get("global");
    let global = GlobalCheckpoint {
        folded_gid: g.get("folded_gid").as_usize().context("folded_gid")? as u32,
        state: GlobalEloState {
            last_iterate: f64s_of(g.get("last"), "global.last")?,
            rating_sum: f64s_of(g.get("sum"), "global.sum")?,
            samples: g
                .get("samples")
                .as_str()
                .context("global.samples")?
                .parse()
                .context("global.samples")?,
            history_len: g.get("history_len").as_usize().context("global.history_len")?,
        },
    };
    if !global.state.last_iterate.is_empty()
        && (global.state.last_iterate.len() != meta.n_models
            || global.state.rating_sum.len() != meta.n_models)
    {
        bail!("global checkpoint width disagrees with n_models {}", meta.n_models);
    }
    let mut lanes = Vec::new();
    for lane in v.get("lanes").as_arr().context("lanes")? {
        let mut segments = Vec::new();
        for s in lane.get("segments").as_arr().context("lane.segments")? {
            segments.push(SegmentEntry {
                file: s.get("file").as_str().context("segment.file")?.to_string(),
                records: s.get("records").as_usize().context("segment.records")?,
                // additive 1.1 fields: a 1.0 manifest's entries are framed
                // v1 segments with an unknown gid range
                format: s.get("format").as_usize().map(|f| f as u32).unwrap_or(SEG_VERSION),
                first_gid: s.get("first_gid").as_usize().map(|g| g as u32),
                last_gid: s.get("last_gid").as_usize().map(|g| g as u32),
            });
        }
        lanes.push(LaneManifest {
            segments,
            log: lane.get("log").as_str().context("lane.log")?.to_string(),
            next_file_id: lane.get("next_file_id").as_usize().context("lane.next_file_id")?
                as u64,
        });
    }
    if lanes.len() != meta.shards.count {
        bail!("manifest lane count {} != shard_count {}", lanes.len(), meta.shards.count);
    }
    Ok((meta, ManifestState { generation, global, lanes }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{l2_normalize, Rng};

    const DIM: usize = 8;
    const N_MODELS: usize = 4;

    fn rand_obs(rng: &mut Rng) -> Observation {
        let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        let a = rng.below(N_MODELS);
        let mut b = rng.below(N_MODELS - 1);
        if b >= a {
            b += 1;
        }
        let outcome = match rng.below(3) {
            0 => Outcome::WinA,
            1 => Outcome::WinB,
            _ => Outcome::Draw,
        };
        Observation::single(v, Comparison { a, b, outcome })
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("eagle_durable_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn meta(shards: usize) -> StoreMeta {
        StoreMeta {
            params: EagleParams::default(),
            n_models: N_MODELS,
            dim: DIM,
            shards: ShardParams { count: shards, hash_seed: 0xEA61E },
        }
    }

    #[test]
    fn frame_roundtrip_and_corruption_detection() {
        let mut rng = Rng::new(1);
        let mut bytes = Vec::new();
        let mut expect = Vec::new();
        for gid in [0u32, 7, 1000, u32::MAX - 1] {
            let obs = rand_obs(&mut rng);
            encode_frame(&mut bytes, gid, &obs.comparisons, &obs.embedding);
            expect.push((gid, obs));
        }
        let (records, valid) = scan_frames(&bytes, DIM, N_MODELS);
        assert_eq!(valid, bytes.len());
        assert_eq!(records.len(), expect.len());
        for ((gid, obs), (egid, eobs)) in records.iter().zip(&expect) {
            assert_eq!(gid, egid);
            assert_eq!(obs.embedding, eobs.embedding);
            assert_eq!(obs.comparisons, eobs.comparisons);
        }
        // a truncated tail stops the scan at the last full record
        let cut = bytes.len() - 3;
        let (partial, valid) = scan_frames(&bytes[..cut], DIM, N_MODELS);
        assert_eq!(partial.len(), expect.len() - 1);
        assert!(valid <= cut);
        // a flipped payload byte fails the checksum
        let mut corrupt = bytes.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0xFF;
        let (partial, _) = scan_frames(&corrupt, DIM, N_MODELS);
        assert_eq!(partial.len(), expect.len() - 1);
    }

    #[test]
    fn manifest_roundtrips_bit_exactly() {
        let m = meta(3);
        let state = ManifestState {
            generation: 7,
            global: GlobalCheckpoint {
                folded_gid: 42,
                state: GlobalEloState {
                    last_iterate: vec![1000.123456789012, 999.9, 1002.5, 997.477],
                    rating_sum: vec![1.5e7, 2.5e7, 3.5e7, 4.5e7 + 0.125],
                    samples: 123_456,
                    history_len: 41_152,
                },
            },
            lanes: (0..3)
                .map(|s| LaneManifest {
                    segments: vec![SegmentEntry {
                        file: format!("shard-{s}/seg-00000001.seg"),
                        records: 10 + s,
                        format: if s == 0 { SEG_VERSION } else { SEG_VERSION_V2 },
                        first_gid: if s == 0 { None } else { Some(7 * s as u32) },
                        last_gid: if s == 0 { None } else { Some(7 * s as u32 + 3) },
                    }],
                    log: format!("shard-{s}/delta-00000002.log"),
                    next_file_id: 3,
                })
                .collect(),
        };
        let text = manifest_json(&m, &state);
        let (m2, s2) = parse_manifest(&text).unwrap();
        assert_eq!(s2.generation, 7);
        assert_eq!(m2.dim, m.dim);
        assert_eq!(m2.n_models, m.n_models);
        assert_eq!(m2.params, m.params);
        assert_eq!(m2.shards, m.shards);
        assert_eq!(s2.global.folded_gid, 42);
        assert_eq!(s2.global.state, state.global.state);
        assert_eq!(s2.lanes.len(), 3);
        assert_eq!(s2.lanes[1].segments[0].records, 11);
        assert_eq!(s2.lanes[2].log, "shard-2/delta-00000002.log");
        // 1.1 segment fields roundtrip; a rangeless v1 entry stays that way
        assert_eq!(s2.lanes[0].segments[0].format, SEG_VERSION);
        assert_eq!(s2.lanes[0].segments[0].first_gid, None);
        assert_eq!(s2.lanes[2].segments[0].format, SEG_VERSION_V2);
        assert_eq!(s2.lanes[2].segments[0].first_gid, Some(14));
        assert_eq!(s2.lanes[2].segments[0].last_gid, Some(17));
    }

    #[test]
    fn pre_v1_1_manifest_segment_entries_default_to_format_1() {
        // a 1.0 manifest's segment objects carry only file + records;
        // parsing must default format to 1 with an unknown gid range
        let m = meta(1);
        let state = ManifestState {
            generation: 0,
            global: GlobalCheckpoint::empty(),
            lanes: vec![LaneManifest {
                segments: vec![SegmentEntry {
                    file: "shard-0/seg-00000001.seg".to_string(),
                    records: 5,
                    format: SEG_VERSION,
                    first_gid: None,
                    last_gid: None,
                }],
                log: "shard-0/delta-00000002.log".to_string(),
                next_file_id: 3,
            }],
        };
        let text = manifest_json(&m, &state).replace(",\"format\":1,", ",");
        assert!(!text.contains("\"format\":"), "format field not stripped: {text}");
        let (_, s2) = parse_manifest(&text).unwrap();
        assert_eq!(s2.lanes[0].segments[0].format, SEG_VERSION);
        assert_eq!(s2.lanes[0].segments[0].first_gid, None);
        assert_eq!(s2.lanes[0].segments[0].last_gid, None);
    }

    #[test]
    fn create_open_roundtrip_empty() {
        let dir = tmp_dir("empty");
        let store = DurableStore::create(&dir, meta(2), DurableOptions::default()).unwrap();
        assert!(DurableStore::exists(&dir));
        assert_eq!(store.segment_counts(), vec![0, 0]);
        // creating over an existing store is refused
        assert!(DurableStore::create(&dir, meta(2), DurableOptions::default()).is_err());
        drop(store);
        let (_store, recovery) = DurableStore::open(&dir, DurableOptions::default()).unwrap();
        assert_eq!(recovery.total_records(), 0);
        assert_eq!(recovery.torn_bytes, 0);
        let router = recovery.into_router(EpochParams::default()).unwrap();
        assert_eq!(router.store_len(), 0);
        assert_eq!(router.history_len(), 0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_seal_recover_keeps_every_record() {
        let mut rng = Rng::new(2);
        let dir = tmp_dir("seal");
        // tiny seal threshold: force several seals over the run
        let opts = DurableOptions { seal_bytes: 600, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(1), opts.clone()).unwrap();
        let mut writer = store.lane_writer(0).unwrap();
        let mut expect = Vec::new();
        for gid in 0..50u32 {
            let obs = rand_obs(&mut rng);
            writer.append(gid, &obs).unwrap();
            expect.push((gid, obs));
        }
        writer.sync().unwrap();
        assert!(store.segment_counts()[0] >= 2, "seal threshold never tripped");
        drop(writer);
        drop(store);
        let (store2, recovery) = DurableStore::open(&dir, opts).unwrap();
        assert_eq!(recovery.torn_bytes, 0);
        assert_eq!(recovery.total_records(), 50);
        let all = recovery.lane_records(0).unwrap();
        assert_eq!(all.len(), expect.len());
        for (got, want) in all.iter().zip(&expect) {
            assert_eq!(got.0, want.0);
            assert_eq!(got.1.embedding, want.1.embedding);
            assert_eq!(got.1.comparisons, want.1.comparisons);
        }
        // the writer resumes appending + sealing after recovery
        let mut writer = store2.lane_writer(0).unwrap();
        for gid in 50..60u32 {
            writer.append(gid, &rand_obs(&mut rng)).unwrap();
        }
        writer.sync().unwrap();
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_log_tail_truncates_to_last_full_record() {
        let mut rng = Rng::new(3);
        let dir = tmp_dir("torn");
        let opts = DurableOptions { seal_bytes: usize::MAX, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(1), opts.clone()).unwrap();
        let mut writer = store.lane_writer(0).unwrap();
        for gid in 0..10u32 {
            writer.append(gid, &rand_obs(&mut rng)).unwrap();
        }
        writer.sync().unwrap();
        let log_path = dir.join("shard-0/delta-00000001.log");
        let len = fs::metadata(&log_path).unwrap().len();
        // tear the final record mid-frame
        OpenOptions::new()
            .write(true)
            .open(&log_path)
            .unwrap()
            .set_len(len - 5)
            .unwrap();
        drop(writer);
        drop(store);
        let (_store, recovery) = DurableStore::open(&dir, opts.clone()).unwrap();
        assert_eq!(recovery.total_records(), 9, "torn record must be dropped");
        assert!(recovery.torn_bytes > 0);
        // the truncation is persistent: a second open is clean
        let (_store, again) = DurableStore::open(&dir, opts).unwrap();
        assert_eq!(again.total_records(), 9);
        assert_eq!(again.torn_bytes, 0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn orphan_files_from_crashed_seal_are_swept() {
        let dir = tmp_dir("orphans");
        let opts = DurableOptions { seal_bytes: usize::MAX, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(1), opts.clone()).unwrap();
        drop(store);
        // simulate a crash between segment write and manifest swap
        fs::write(dir.join("shard-0/seg-00000009.seg"), b"orphan").unwrap();
        fs::write(dir.join("shard-0/delta-00000010.log"), b"orphan").unwrap();
        fs::write(dir.join("MANIFEST.tmp"), b"orphan").unwrap();
        let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
        assert_eq!(recovery.total_records(), 0);
        assert!(!dir.join("shard-0/seg-00000009.seg").exists());
        assert!(!dir.join("shard-0/delta-00000010.log").exists());
        assert!(!dir.join("MANIFEST.tmp").exists());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lock_guards_foreign_live_owners_but_allows_recovery() {
        let dir = tmp_dir("lock");
        let opts = DurableOptions { seal_bytes: usize::MAX, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(1), opts.clone()).unwrap();
        // same-process reopen is allowed (in-process restart, tests)
        let (store2, _) = DurableStore::open(&dir, opts.clone()).unwrap();
        drop(store2);
        drop(store);
        // dropping the owner releases the lock
        assert!(!dir.join(LOCK).exists());
        // a live foreign owner refuses the open (pid 1 always runs on
        // linux; skip where /proc is unavailable)
        fs::write(dir.join(LOCK), "1").unwrap();
        if Path::new("/proc/1").is_dir() {
            let err = DurableStore::open(&dir, opts.clone());
            assert!(err.is_err(), "open must refuse a live foreign lock");
        }
        // a dead owner's lock is taken over (the crash-recovery case)
        fs::write(dir.join(LOCK), u32::MAX.to_string()).unwrap();
        let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
        assert_eq!(recovery.total_records(), 0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_v2_roundtrip_mapped_and_buffered() {
        let mut rng = Rng::new(11);
        let dir = tmp_dir("v2rt");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg-v2.seg");
        let mut gids = Vec::new();
        let mut feedbacks = Vec::new();
        let mut floats = Vec::new();
        let mut expect = Vec::new();
        for i in 0..17u32 {
            let obs = rand_obs(&mut rng);
            gids.push(i * 3 + 1);
            floats.extend_from_slice(&obs.embedding);
            feedbacks.push(Feedback { comparisons: obs.comparisons.clone() });
            expect.push((i * 3 + 1, obs));
        }
        write_segment_v2(&path, DIM, &gids, &feedbacks, &floats, false).unwrap();
        let entry = SegmentEntry {
            file: "seg-v2.seg".to_string(),
            records: 17,
            format: SEG_VERSION_V2,
            first_gid: gids.first().copied(),
            last_gid: gids.last().copied(),
        };
        for use_mmap in [true, false] {
            let seg = load_segment(&path, DIM, N_MODELS, &entry, use_mmap)
                .unwrap()
                .expect("segment present");
            assert_eq!(seg.first_gid(), Some(1));
            assert_eq!(seg.last_gid(), Some(49));
            assert_eq!(seg.records(), 17);
            let mut got = Vec::new();
            seg.into_records(DIM, &mut got);
            assert_eq!(got.len(), expect.len());
            for ((g, o), (eg, eo)) in got.iter().zip(&expect) {
                assert_eq!(g, eg);
                assert_eq!(o.embedding, eo.embedding);
                assert_eq!(o.comparisons, eo.comparisons);
            }
        }
        // a missing file is the typed restart signal, not an error
        let gone = dir.join("not-there.seg");
        assert!(load_segment(&gone, DIM, N_MODELS, &entry, true).unwrap().is_none());
        assert!(load_segment(&gone, DIM, N_MODELS, &entry, false).unwrap().is_none());
        // flipping a slab byte fails the buffered load (which checks the
        // embedding checksum) ...
        let clean = fs::read(&path).unwrap();
        let mut corrupt = clean.clone();
        let last = corrupt.len() - 1;
        corrupt[last] ^= 0x40;
        fs::write(&path, &corrupt).unwrap();
        assert!(load_segment(&path, DIM, N_MODELS, &entry, false).is_err());
        // ... and flipping a side-array byte fails both paths
        let mut corrupt = clean.clone();
        corrupt[SEG2_HEADER_BYTES + 1] ^= 0x40;
        fs::write(&path, &corrupt).unwrap();
        assert!(load_segment(&path, DIM, N_MODELS, &entry, true).is_err());
        assert!(load_segment(&path, DIM, N_MODELS, &entry, false).is_err());
        // a record-count mismatch against the manifest is rejected
        fs::write(&path, &clean).unwrap();
        let wrong = SegmentEntry { records: 16, ..entry.clone() };
        assert!(load_segment(&path, DIM, N_MODELS, &wrong, false).is_err());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_bounds_segments_and_gc_deletes_after_grace() {
        let mut rng = Rng::new(12);
        let dir = tmp_dir("compact");
        // tiny threshold: dozens of single-digit-record segments
        let opts = DurableOptions { seal_bytes: 400, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(1), opts.clone()).unwrap();
        let mut writer = store.lane_writer(0).unwrap();
        let mut expect = Vec::new();
        for gid in 0..120u32 {
            let obs = rand_obs(&mut rng);
            writer.append(gid, &obs).unwrap();
            expect.push((gid, obs));
        }
        writer.sync().unwrap();
        let before = store.segment_counts()[0];
        assert!(before >= 8, "expected many small segments, got {before}");
        let gen_before = store.generation();
        let ops = store.compact_once();
        assert!(ops > 0, "compaction found nothing to merge");
        assert!(store.generation() > gen_before);
        let after = store.segment_counts()[0];
        // binary-counter invariant: strictly descending record counts →
        // O(log n) files
        assert!(after <= 8, "compaction left {after} segments (was {before})");
        {
            let m = store.manifest.lock().unwrap();
            let segs = &m.lanes[0].segments;
            for w in segs.windows(2) {
                assert!(w[0].records > w[1].records, "merge policy fixpoint violated");
            }
            for s in segs {
                assert_eq!(s.format, SEG_VERSION_V2);
                assert!(s.first_gid.is_some() && s.last_gid.is_some());
            }
        }
        // superseded files survive until the grace window passes ...
        assert!(store.retired_pending() > 0);
        assert_eq!(store.gc_retired(Duration::from_secs(3600)), 0);
        assert!(store.retired_pending() > 0);
        // ... then are deleted
        let deleted = store.gc_retired(Duration::ZERO);
        assert!(deleted > 0);
        assert_eq!(store.retired_pending(), 0);
        assert_eq!(store.compaction_stats().gc_files.get(), deleted as u64);
        // a second pass is a no-op: the fixpoint is stable
        assert_eq!(store.compact_once(), 0);
        // everything still recovers bit-identically after merge + GC
        drop(writer);
        drop(store);
        let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
        assert_eq!(recovery.total_records(), 120);
        let all = recovery.lane_records(0).unwrap();
        for (got, want) in all.iter().zip(&expect) {
            assert_eq!(got.0, want.0);
            assert_eq!(got.1.embedding, want.1.embedding);
            assert_eq!(got.1.comparisons, want.1.comparisons);
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compactor_upgrades_v1_segments_to_v2() {
        let mut rng = Rng::new(13);
        let dir = tmp_dir("upgrade");
        // write v1 segments (mmap disabled), then reopen with mmap on
        let v1_opts = DurableOptions { seal_bytes: 500, fsync: false, mmap: false };
        let store = DurableStore::create(&dir, meta(1), v1_opts.clone()).unwrap();
        let mut writer = store.lane_writer(0).unwrap();
        let mut expect = Vec::new();
        for gid in 0..60u32 {
            let obs = rand_obs(&mut rng);
            writer.append(gid, &obs).unwrap();
            expect.push((gid, obs));
        }
        writer.sync().unwrap();
        assert!(store.segment_counts()[0] >= 2);
        {
            let m = store.manifest.lock().unwrap();
            assert!(m.lanes[0].segments.iter().all(|s| s.format == SEG_VERSION));
        }
        drop(writer);
        drop(store);
        let opts = DurableOptions { mmap: true, ..v1_opts };
        let (store, _recovery) = DurableStore::open(&dir, opts.clone()).unwrap();
        // compact to quiescence: merges + solo upgrades leave only v2
        while store.compact_once() > 0 {}
        {
            let m = store.manifest.lock().unwrap();
            assert!(
                m.lanes[0].segments.iter().all(|s| s.format == SEG_VERSION_V2),
                "legacy v1 segments must be upgraded"
            );
        }
        assert!(
            store.compaction_stats().upgrades.get() > 0
                || store.compaction_stats().merges.get() > 0
        );
        store.gc_retired(Duration::ZERO);
        drop(store);
        let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
        assert_eq!(recovery.total_records(), 60);
        let all = recovery.lane_records(0).unwrap();
        for (got, want) in all.iter().zip(&expect) {
            assert_eq!(got.0, want.0);
            assert_eq!(got.1.embedding, want.1.embedding);
            assert_eq!(got.1.comparisons, want.1.comparisons);
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_recovery_reports_bounded_footprint() {
        let mut rng = Rng::new(14);
        let dir = tmp_dir("stream");
        let opts = DurableOptions { seal_bytes: 700, fsync: false, mmap: false };
        let store = DurableStore::create(&dir, meta(1), opts.clone()).unwrap();
        let mut writer = store.lane_writer(0).unwrap();
        for gid in 0..200u32 {
            writer.append(gid, &rand_obs(&mut rng)).unwrap();
        }
        writer.sync().unwrap();
        let segments = store.segment_counts()[0];
        assert!(segments >= 6, "need several segments, got {segments}");
        drop(writer);
        drop(store);
        let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
        let (catchup, fp) = recovery.resume_reporting(EpochParams::default()).unwrap();
        let router = catchup.finish();
        assert_eq!(router.store_len(), 200);
        // streaming invariant: the peak holds one segment (plus log
        // tails), not the whole corpus
        assert!(fp.total_segment_bytes > fp.largest_segment_bytes * 2);
        assert!(
            fp.peak_resident_bytes < fp.total_segment_bytes,
            "peak {} should be far below total {}",
            fp.peak_resident_bytes,
            fp.total_segment_bytes
        );
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_survives_reopen() {
        let dir = tmp_dir("ckpt");
        let opts = DurableOptions { seal_bytes: usize::MAX, fsync: false, mmap: true };
        let store = DurableStore::create(&dir, meta(2), opts.clone()).unwrap();
        let mut elo = GlobalElo::new(N_MODELS, 32.0);
        elo.apply_new(&[Comparison { a: 0, b: 1, outcome: Outcome::WinA }]);
        store.checkpoint_global(1, elo.export_state()).unwrap();
        drop(store);
        let (_store, recovery) = DurableStore::open(&dir, opts).unwrap();
        assert_eq!(recovery.folded_gid, 1);
        assert_eq!(recovery.global, elo.export_state());
        fs::remove_dir_all(&dir).ok();
    }
}
