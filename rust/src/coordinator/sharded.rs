//! Sharded scatter-gather snapshot routing: the multi-writer scale-out of
//! the RCU core in [`super::snapshot`].
//!
//! PR 1 made the scoring state an immutable value (snapshot + single
//! writer). This module partitions that value across K shards so both
//! read throughput and feedback ingest scale with cores:
//!
//! - the corpus is partitioned by a deterministic **embedding hash**
//!   ([`shard_of`]): every stored prompt lives in exactly one shard, and
//!   feedback ingest routes by the same hash, so each shard's
//!   [`RouterWriter`] applies and republishes **independently** (the
//!   multi-writer ingest prerequisite — see [`ShardedRouter::into_lanes`]);
//! - the **global ELO table is shared**, maintained in feedback-stream
//!   order by a [`GlobalLane`] and published through its own RCU cell —
//!   sharding the vector store must not change the global ranking;
//! - reads do lock-free **scatter-gather**: load one snapshot per shard
//!   plus the shared global table, fan the query across the per-shard
//!   views, and merge the per-shard top-N candidates into the exact
//!   global top-N (ties and all) before replaying the local ELO.
//!
//! ## Bit-exactness
//!
//! A [`ShardedSnapshot`] scores **bit-identically** to a single-shard
//! router over the same feedback stream, at every K:
//!
//! - entries carry their **global arrival id** through per-shard id maps
//!   ([`FrozenIds`]), so the merged candidate order — descending score,
//!   ascending global id — is exactly the order a single store's
//!   [`crate::vectordb::topk::TopK`] produces;
//! - a shard's local ids are assigned in arrival order, so within one
//!   shard (score, local id) sorts the same as (score, global id), and
//!   every member of the global top-N is inside its own shard's top-N —
//!   the K·N candidate union provably contains the answer;
//! - the merged neighbor list is scored through the *same*
//!   [`mixed_scores_from`] code path the single-shard scorer uses, seeded
//!   from the shared global table.
//!
//! `rust/tests/snapshot_routing.rs` property-tests this for
//! K ∈ {1, 2, 3, 8} over interleaved inserts.
//!
//! ## Publication ordering
//!
//! A lane publishes its id map *before* its snapshot, and readers load
//! the snapshot *before* the id map. Id maps are append-only with an
//! immutable prefix, so a reader always holds an id map at least as long
//! as its snapshot's view — every visible local id resolves.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{EagleParams, EpochParams, IvfPublishParams, ShardParams};
use crate::elo::{Comparison, GlobalElo};
use crate::vectordb::flat::FlatStore;
use crate::vectordb::view::{SegmentStore, Slab};
use crate::vectordb::{Feedback, Hit, ReadIndex, VectorIndex};

use super::router::{
    mixed_scores_batch_from, mixed_scores_from, mixed_scores_from_hits, EagleRouter, Observation,
    ScoreScratch,
};
use super::snapshot::{RcuCell, RouterSnapshot, RouterWriter, SnapshotRing};

/// Batches below this size score serially even on a sharded snapshot
/// (thread fan-out would cost more than it saves).
const PAR_MIN_BATCH: usize = 2;

/// Corpora below this size score serially even on a sharded snapshot.
const PAR_MIN_CORPUS: usize = 4096;

/// Minimum total scan work (queries × rows × dims ≈ multiply-adds) before
/// `score_batch` fans out threads: roughly a millisecond of serial scan,
/// comfortably above per-batch thread create/join cost. Smaller batches
/// stay serial even over a sharded corpus — identical results either way.
const PAR_MIN_WORK: usize = 4_000_000;

/// Deterministic shard assignment from the embedding bits: an FNV-style
/// fold over the raw `f32` bit patterns with a seed, finished with an
/// avalanche so the modulo sees every coordinate. Identical bits always
/// land on the same shard, so re-partitioning a restored corpus
/// reproduces the original placement.
pub fn shard_of(embedding: &[f32], hash_seed: u64, count: usize) -> usize {
    if count <= 1 {
        return 0;
    }
    let mut h = hash_seed ^ 0x9E37_79B9_7F4A_7C15;
    for &x in embedding {
        h ^= u64::from(x.to_bits());
        h = h.wrapping_mul(0x100_0000_01B3);
        h ^= h >> 29;
    }
    h ^= h >> 32;
    h = h.wrapping_mul(0xD6E8_FEB8_6659_FD93);
    h ^= h >> 32;
    (h % count as u64) as usize
}

/// Sort candidates exactly like [`crate::vectordb::topk::TopK::into_sorted`]:
/// descending score, ties by ascending (global) id.
fn sort_hits(hits: &mut [Hit]) {
    hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap().then(a.id.cmp(&b.id)));
}

/// Immutable local→global id map published alongside a shard snapshot.
///
/// Blocks hold ascending global ids (appends happen in arrival order and
/// merges concatenate adjacent blocks), which makes the reverse lookup a
/// two-level binary search.
#[derive(Debug, Clone, Default)]
pub struct FrozenIds {
    blocks: Vec<Arc<Vec<u32>>>,
    /// Local offset of each block's first entry (parallel to `blocks`).
    starts: Vec<usize>,
    len: usize,
}

impl FrozenIds {
    /// The empty map (what a cold-started lane publishes first).
    pub fn empty() -> Self {
        FrozenIds::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// Global arrival id of a shard-local entry.
    pub fn global_of(&self, local: u32) -> u32 {
        let b = self.starts.partition_point(|&s| s <= local as usize) - 1;
        self.blocks[b][local as usize - self.starts[b]]
    }

    /// Shard-local id of a global arrival id, if this shard holds it.
    pub fn local_of(&self, global: u32) -> Option<u32> {
        // blocks are never empty and ascending across the concatenation
        let b = self.blocks.partition_point(|blk| blk[0] <= global);
        if b == 0 {
            return None;
        }
        let blk = &self.blocks[b - 1];
        blk.binary_search(&global)
            .ok()
            .map(|i| (self.starts[b - 1] + i) as u32)
    }
}

/// Writer-side append-only id map with O(pending) freeze. Sealed blocks
/// merge binary-counter style (like
/// [`crate::vectordb::view::SegmentStore`]) so a map of n entries holds
/// O(log n) blocks and each id is copied O(log n) times total.
#[derive(Debug, Default)]
pub struct IdBlocks {
    blocks: Vec<Arc<Vec<u32>>>,
    starts: Vec<usize>,
    sealed_len: usize,
    pending: Vec<u32>,
}

impl IdBlocks {
    pub fn new() -> Self {
        IdBlocks::default()
    }

    pub fn len(&self) -> usize {
        self.sealed_len + self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append the next global id (must be strictly increasing).
    pub fn push(&mut self, global_id: u32) {
        self.pending.push(global_id);
    }

    /// Global id of a shard-local entry (sealed or pending) — the
    /// writer-side counterpart of [`FrozenIds::global_of`], used by the
    /// durable store to serialize a lane without freezing it.
    pub fn get(&self, local: usize) -> u32 {
        if local >= self.sealed_len {
            return self.pending[local - self.sealed_len];
        }
        let b = self.starts.partition_point(|&s| s <= local) - 1;
        self.blocks[b][local - self.starts[b]]
    }

    /// Seal pending ids and hand out an immutable view of everything.
    pub fn freeze(&mut self) -> FrozenIds {
        if !self.pending.is_empty() {
            let blk = std::mem::take(&mut self.pending);
            self.starts.push(self.sealed_len);
            self.sealed_len += blk.len();
            self.blocks.push(Arc::new(blk));
            while self.blocks.len() >= 2
                && self.blocks[self.blocks.len() - 1].len()
                    >= self.blocks[self.blocks.len() - 2].len()
            {
                let newer = self.blocks.pop().unwrap();
                let older = self.blocks.pop().unwrap();
                self.starts.pop();
                let mut merged = Vec::with_capacity(older.len() + newer.len());
                merged.extend_from_slice(&older);
                merged.extend_from_slice(&newer);
                self.blocks.push(Arc::new(merged));
            }
        }
        FrozenIds {
            blocks: self.blocks.clone(),
            starts: self.starts.clone(),
            len: self.sealed_len,
        }
    }
}

/// The shared global-ELO table frozen at one publish: the "background
/// knowledge" every shard's local replay seeds from.
#[derive(Debug, Clone)]
pub struct SharedGlobal {
    /// Trajectory-averaged ratings over the *full* feedback stream.
    pub ratings: Vec<f64>,
    /// Feedback records folded in up to this publish.
    pub history_len: usize,
}

/// The stream-order writer for the shared global table. Exactly one
/// thread applies; publication goes through an [`RcuCell`] so readers
/// never block on it.
pub struct GlobalLane {
    elo: GlobalElo,
    cell: Arc<RcuCell<SharedGlobal>>,
    cadence: EpochParams,
    since_publish: usize,
    last_publish: Instant,
}

impl GlobalLane {
    /// Wrap a (possibly checkpoint-resumed) table as the stream-order
    /// writer lane; the initial published cell is the table as given.
    pub(crate) fn from_elo(elo: GlobalElo, cadence: EpochParams) -> Self {
        let initial = SharedGlobal { ratings: elo.ratings(), history_len: elo.history_len() };
        GlobalLane {
            elo,
            cell: Arc::new(RcuCell::new(Arc::new(initial))),
            cadence,
            since_publish: 0,
            last_publish: Instant::now(),
        }
    }

    /// Fold one observation's comparisons into the global table, in
    /// feedback-stream order.
    pub fn apply(&mut self, comparisons: &[Comparison]) {
        self.elo.apply_new(comparisons);
        self.since_publish += 1;
    }

    /// True when the epoch cadence says pending records should publish.
    pub fn publish_due(&self) -> bool {
        self.since_publish != 0
            && (self.since_publish >= self.cadence.publish_every.max(1)
                || self.last_publish.elapsed()
                    >= Duration::from_millis(self.cadence.publish_interval_ms))
    }

    /// Publish if the cadence has tripped; returns whether it did.
    pub fn maybe_publish(&mut self) -> bool {
        if self.publish_due() {
            self.publish();
            true
        } else {
            false
        }
    }

    /// Unconditional publish of the current table.
    pub fn publish(&mut self) {
        self.cell.publish(Arc::new(SharedGlobal {
            ratings: self.elo.ratings(),
            history_len: self.elo.history_len(),
        }));
        self.since_publish = 0;
        self.last_publish = Instant::now();
    }

    /// Records applied to the table but not yet republished.
    pub fn unpublished(&self) -> usize {
        self.since_publish
    }

    /// Live (writer-side) comparisons applied, published or not.
    pub fn history_len(&self) -> usize {
        self.elo.history_len()
    }

    /// The live table (diagnostics / persistence; readers use the cell).
    pub fn elo(&self) -> &GlobalElo {
        &self.elo
    }
}

/// One shard's independent writer: a [`RouterWriter`] plus the id map
/// that names its entries globally. Lanes are `Send`, so each can live on
/// its own ingest thread (multi-writer ingest).
pub struct ShardLane {
    writer: RouterWriter,
    ids: IdBlocks,
    ids_cell: Arc<RcuCell<FrozenIds>>,
}

impl ShardLane {
    pub(crate) fn with_ids(writer: RouterWriter, mut ids: IdBlocks) -> Self {
        let initial = ids.freeze();
        debug_assert_eq!(initial.len(), writer.router().store().len(), "ids/store skew");
        ShardLane { writer, ids, ids_cell: Arc::new(RcuCell::new(Arc::new(initial))) }
    }

    /// Apply one observation routed to this shard. `global_id` is the
    /// record's arrival index in the full stream; ids must arrive in
    /// increasing order per lane.
    pub fn apply(&mut self, global_id: u32, obs: Observation) {
        self.ids.push(global_id);
        self.writer.apply(obs);
    }

    /// Bulk-apply one sealed block (a mapped v2 segment from the durable
    /// store): ids append per record, the store adopts the embedding slab
    /// as one zero-copy sealed segment, and per-record ELO/publication
    /// bookkeeping stays identical to [`ShardLane::apply`]. `gids` must be
    /// strictly increasing and past everything already applied; the
    /// caller folds global-table comparisons itself.
    pub(crate) fn apply_block(&mut self, gids: &[u32], slab: Slab, feedbacks: Vec<Feedback>) {
        for &gid in gids {
            self.ids.push(gid);
        }
        self.writer.apply_block(slab, feedbacks);
    }

    /// Publish if this lane's epoch cadence has tripped.
    pub fn maybe_publish(&mut self) -> Option<u64> {
        self.writer.publish_due().then(|| self.publish())
    }

    /// Unconditional publish: the id map first, then the snapshot (see
    /// module docs for why this order matters).
    pub fn publish(&mut self) -> u64 {
        self.ids_cell.publish(Arc::new(self.ids.freeze()));
        self.writer.publish()
    }

    /// Records applied to this lane but not yet visible to readers.
    pub fn unpublished(&self) -> usize {
        self.writer.unpublished()
    }

    /// Install the IVF publication policy on this lane's writer (see
    /// [`RouterWriter::set_ivf`]); the threshold applies to the *shard's*
    /// corpus, so K shards each flip to IVF independently.
    pub fn set_ivf(&mut self, params: IvfPublishParams) {
        self.writer.set_ivf(params);
    }

    /// Install the SQ8 publication policy on this lane's writer (see
    /// [`RouterWriter::set_quant`]); each shard quantizes its own sealed
    /// segments at publish time.
    pub fn set_quant(&mut self, params: crate::config::QuantParams) {
        self.writer.set_quant(params);
    }

    /// The wrapped single-shard writer (diagnostics).
    pub fn writer(&self) -> &RouterWriter {
        &self.writer
    }

    /// The writer-side id map (durable-store serialization).
    pub(crate) fn ids_ref(&self) -> &IdBlocks {
        &self.ids
    }
}

/// The sharded ingest side: a shared global lane plus one [`ShardLane`]
/// per shard. Single-threaded callers drive [`ShardedRouter::observe`];
/// multi-writer deployments split it with [`ShardedRouter::into_lanes`].
pub struct ShardedRouter {
    params: EagleParams,
    n_models: usize,
    dim: usize,
    shard_params: ShardParams,
    global: GlobalLane,
    lanes: Vec<ShardLane>,
    next_id: u32,
}

impl ShardedRouter {
    /// Cold-start router: K empty shards, uniform global table.
    pub fn new(
        params: EagleParams,
        n_models: usize,
        dim: usize,
        cadence: EpochParams,
        shards: ShardParams,
    ) -> Self {
        assert!(shards.count >= 1, "shard count must be >= 1");
        let lanes = (0..shards.count)
            .map(|_| {
                ShardLane::with_ids(
                    RouterWriter::new(params.clone(), n_models, dim, cadence.clone()),
                    IdBlocks::new(),
                )
            })
            .collect();
        let global = GlobalLane::from_elo(GlobalElo::new(n_models, params.k_factor), cadence);
        ShardedRouter {
            params,
            n_models,
            dim,
            shard_params: shards,
            global,
            lanes,
            next_id: 0,
        }
    }

    /// Partition an existing flat-store router (disk restore / pre-fit
    /// history) across K shards, keeping its global ELO state — including
    /// the averaging trajectory — intact.
    pub fn from_router(
        router: EagleRouter<FlatStore>,
        cadence: EpochParams,
        shards: ShardParams,
    ) -> Self {
        assert!(shards.count >= 1, "shard count must be >= 1");
        let params = router.params().clone();
        let n_models = router.n_models();
        let dim = router.store().dim();
        let n = router.store().len();
        let mut stores: Vec<SegmentStore> =
            (0..shards.count).map(|_| SegmentStore::new(dim)).collect();
        let mut id_maps: Vec<IdBlocks> = (0..shards.count).map(|_| IdBlocks::new()).collect();
        for id in 0..n as u32 {
            let v = router.store().vector(id);
            let s = shard_of(v, shards.hash_seed, shards.count);
            stores[s].add(v, router.store().feedback(id).clone());
            id_maps[s].push(id);
        }
        let global = GlobalLane::from_elo(router.global().clone(), cadence.clone());
        let lanes = stores
            .into_iter()
            .zip(id_maps)
            .map(|(store, ids)| {
                ShardLane::with_ids(
                    RouterWriter::from_segment_router(
                        EagleRouter::new(params.clone(), n_models, store),
                        cadence.clone(),
                    ),
                    ids,
                )
            })
            .collect();
        ShardedRouter {
            params,
            n_models,
            dim,
            shard_params: shards,
            global,
            lanes,
            next_id: n as u32,
        }
    }

    /// Reassemble a router around *live* lanes (the durable store's
    /// catch-up path, [`super::durable::CatchUp::finish`], which both
    /// crash recovery and replica promotion go through): the lanes carry
    /// their replayed stores + id maps and keep their publication rings,
    /// so reader handles taken before reassembly stay valid; `next_id`
    /// continues the global arrival-id space past every applied record.
    pub(crate) fn from_lanes(
        params: EagleParams,
        n_models: usize,
        dim: usize,
        shard_params: ShardParams,
        global: GlobalLane,
        lanes: Vec<ShardLane>,
        next_id: u32,
    ) -> Self {
        assert_eq!(lanes.len(), shard_params.count, "lane/shard count skew");
        ShardedRouter {
            params,
            n_models,
            dim,
            shard_params,
            global,
            lanes,
            next_id,
        }
    }

    /// The live (writer-side) global-ELO table — what the durable
    /// checkpoint captures.
    pub fn global_elo(&self) -> &GlobalElo {
        self.global.elo()
    }

    /// Writer-side lanes (durable-store bootstrap serialization).
    pub(crate) fn lanes_ref(&self) -> &[ShardLane] {
        &self.lanes
    }

    /// The lock-free reader handle (cheap to clone, `Send + Sync`).
    pub fn handle(&self) -> ShardedHandle {
        handle_of(self.params.clone(), self.dim, &self.global, &self.lanes)
    }

    /// Ingest one observation: fold into the shared global table (stream
    /// order), route to its shard by embedding hash, and let both lanes
    /// publish on their own cadence. Returns the shard's new epoch if its
    /// snapshot republished.
    pub fn observe(&mut self, obs: Observation) -> Option<u64> {
        let shard = shard_of(&obs.embedding, self.shard_params.hash_seed, self.lanes.len());
        let gid = self.next_id;
        self.next_id += 1;
        self.global.apply(&obs.comparisons);
        self.global.maybe_publish();
        let lane = &mut self.lanes[shard];
        lane.apply(gid, obs);
        lane.maybe_publish()
    }

    /// Publish every lane and the global table unconditionally; returns
    /// the highest shard epoch afterwards.
    pub fn publish_all(&mut self) -> u64 {
        self.global.publish();
        self.lanes.iter_mut().map(|l| l.publish()).max().unwrap_or(0)
    }

    /// Publish whichever lanes (and the global table) have tripped their
    /// cadence — the applier's staleness beat.
    pub fn maybe_publish_all(&mut self) {
        self.global.maybe_publish();
        for lane in &mut self.lanes {
            lane.maybe_publish();
        }
    }

    /// Records applied but not yet visible to readers: shard lanes plus
    /// the shared global table (whose cadence can trail the lanes', so a
    /// shutdown flush must not be skipped on lane counts alone).
    pub fn unpublished(&self) -> usize {
        self.lanes.iter().map(|l| l.unpublished()).sum::<usize>() + self.global.unpublished()
    }

    /// Comparisons folded into the shared global table (ingested,
    /// published or not).
    pub fn history_len(&self) -> usize {
        self.global.history_len()
    }

    /// Stored prompts across all shards (writer side).
    pub fn store_len(&self) -> usize {
        self.lanes.iter().map(|l| l.writer.router().store().len()).sum()
    }

    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }

    pub fn params(&self) -> &EagleParams {
        &self.params
    }

    pub fn shard_params(&self) -> &ShardParams {
        &self.shard_params
    }

    pub fn n_models(&self) -> usize {
        self.n_models
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Which shard an embedding routes to.
    pub fn shard_for(&self, embedding: &[f32]) -> usize {
        shard_of(embedding, self.shard_params.hash_seed, self.lanes.len())
    }

    /// Install the IVF publication policy on every shard lane (see
    /// [`RouterWriter::set_ivf`]). Call before ingest starts; per-shard
    /// corpora past `publish_threshold` publish IVF views.
    pub fn set_ivf(&mut self, params: IvfPublishParams) {
        for lane in &mut self.lanes {
            lane.set_ivf(params.clone());
        }
    }

    /// Install the SQ8 publication policy on every shard lane (see
    /// [`RouterWriter::set_quant`]). Scatter-gather scoring flows through
    /// each lane's published [`super::snapshot::SnapshotView`], so
    /// quantized lanes keep the exact-rerank contract shard by shard.
    pub fn set_quant(&mut self, params: crate::config::QuantParams) {
        for lane in &mut self.lanes {
            lane.set_quant(params);
        }
    }

    /// Decompose into independent writer lanes for multi-threaded ingest:
    /// one thread owns the [`GlobalLane`] (the full stream in order), one
    /// thread owns each [`ShardLane`] (its hash partition, with
    /// pre-assigned global ids). Reader handles taken before the split
    /// keep working. The next global arrival id to assign is
    /// [`ShardedRouter::next_global_id`].
    pub fn into_lanes(self) -> (GlobalLane, Vec<ShardLane>) {
        (self.global, self.lanes)
    }

    /// The next unassigned global arrival id (multi-writer callers that
    /// split via [`ShardedRouter::into_lanes`] continue the id space from
    /// here).
    pub fn next_global_id(&self) -> u32 {
        self.next_id
    }

    /// Persist the full sharded state as one flat snapshot (global-id
    /// order), readable by [`super::state::load_from`]. Publishes
    /// everything first so the serialized view is complete.
    pub fn save_to(&mut self, path: &Path) -> Result<()> {
        self.publish_all();
        self.handle().load().persist(path)
    }
}

/// Reader handle over writer-side lanes that are not (or not yet)
/// assembled into a [`ShardedRouter`] — the replica catch-up path
/// ([`super::durable::CatchUp::handle`]) serves routes from the same
/// rings its tail loop is still filling.
pub(crate) fn handle_of(
    params: EagleParams,
    dim: usize,
    global: &GlobalLane,
    lanes: &[ShardLane],
) -> ShardedHandle {
    ShardedHandle {
        params,
        dim,
        rings: lanes.iter().map(|l| l.writer.ring()).collect(),
        ids: lanes.iter().map(|l| l.ids_cell.clone()).collect(),
        global: global.cell.clone(),
    }
}

/// Cheap-to-clone reader side: one ring per shard, one id-map cell per
/// shard, one shared-global cell.
#[derive(Clone)]
pub struct ShardedHandle {
    params: EagleParams,
    dim: usize,
    rings: Vec<Arc<SnapshotRing>>,
    ids: Vec<Arc<RcuCell<FrozenIds>>>,
    global: Arc<RcuCell<SharedGlobal>>,
}

impl ShardedHandle {
    /// Acquire a consistent-enough scoring state: per shard, the snapshot
    /// is loaded *before* its id map (the writer publishes in the
    /// opposite order), so every visible local id resolves globally.
    /// Cross-shard staleness is bounded by the epoch cadence.
    pub fn load(&self) -> ShardedSnapshot {
        let shards: Vec<Arc<RouterSnapshot>> = self.rings.iter().map(|r| r.load()).collect();
        let ids: Vec<Arc<FrozenIds>> = self.ids.iter().map(|c| c.load()).collect();
        let global = self.global.load();
        ShardedSnapshot { params: self.params.clone(), dim: self.dim, global, shards, ids }
    }

    pub fn shard_count(&self) -> usize {
        self.rings.len()
    }

    /// Current epoch of each shard ring (diagnostics).
    pub fn shard_epochs(&self) -> Vec<u64> {
        self.rings.iter().map(|r| r.current_epoch()).collect()
    }
}

/// An immutable K-shard scoring state: per-shard snapshots + id maps +
/// the shared global table. Scoring takes no lock and sees no concurrent
/// mutation, ever — same contract as [`RouterSnapshot`], same math.
pub struct ShardedSnapshot {
    params: EagleParams,
    dim: usize,
    global: Arc<SharedGlobal>,
    shards: Vec<Arc<RouterSnapshot>>,
    ids: Vec<Arc<FrozenIds>>,
}

impl ShardedSnapshot {
    /// Shared trajectory-averaged global ratings.
    pub fn global_ratings(&self) -> &[f64] {
        &self.global.ratings
    }

    /// Feedback records folded into the shared global table.
    pub fn history_len(&self) -> usize {
        self.global.history_len
    }

    /// Stored prompts visible across all shard views.
    pub fn store_len(&self) -> usize {
        self.shards.iter().map(|s| s.store_len()).sum()
    }

    /// Highest shard epoch in this snapshot (display/diagnostics; shards
    /// publish independently, see [`ShardedSnapshot::shard_epochs`]).
    pub fn epoch(&self) -> u64 {
        self.shards.iter().map(|s| s.epoch()).max().unwrap_or(0)
    }

    pub fn shard_epochs(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.epoch()).collect()
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn params(&self) -> &EagleParams {
        &self.params
    }

    /// The merged read-only index over every shard view (global ids).
    pub fn scatter(&self) -> ScatterView<'_> {
        ScatterView { dim: self.dim, shards: &self.shards, ids: &self.ids }
    }

    /// Persist this (already published, immutable) routing state as one
    /// flat snapshot in global-id order, readable by
    /// [`super::state::load_from`]. Safe to call from any thread — no
    /// writer lane is touched.
    ///
    /// The snapshot's published global-id set must be a **complete
    /// prefix** of the id space (guaranteed right after
    /// [`ShardedRouter::publish_all`] or an ingest flush barrier): the
    /// serializer walks ids densely, so persisting a multi-shard state
    /// whose lanes published unevenly panics on the first gap.
    pub fn persist(&self, path: &Path) -> Result<()> {
        let text = super::state::snapshot_parts(
            &self.params,
            self.global.ratings.len(),
            self.global_ratings(),
            self.history_len(),
            &self.scatter(),
        );
        super::state::write_atomic(path, &text)
    }

    /// Combined Eagle scores for one embedded query — bit-identical to a
    /// single-shard [`RouterSnapshot`] over the same feedback stream.
    pub fn scores(&self, query_emb: &[f32]) -> Vec<f64> {
        if self.shards.len() == 1 {
            // K=1 fast path: local ids ARE global ids, so the id-mapping
            // merge is the identity — score the lone view directly (the
            // default single-shard config pays nothing for the machinery)
            return mixed_scores_from(
                &self.params,
                &self.global.ratings,
                self.shards[0].view(),
                query_emb,
            );
        }
        mixed_scores_from(&self.params, &self.global.ratings, &self.scatter(), query_emb)
    }

    /// Score a batch against this one frozen state. Every path retrieves
    /// through the query-blocked kernel scans; large batches over large
    /// sharded corpora additionally fan the scan across one thread per
    /// shard ([`ShardedSnapshot::score_batch_scatter`]). Results are
    /// bit-identical whichever path runs.
    pub fn score_batch(&self, query_embs: &[Vec<f32>]) -> Vec<Vec<f64>> {
        let rows = self.store_len();
        let work = query_embs.len().saturating_mul(rows).saturating_mul(self.dim);
        let parallel = self.shards.len() > 1
            && self.params.p < 1.0
            && query_embs.len() >= PAR_MIN_BATCH
            && rows >= PAR_MIN_CORPUS
            && work >= PAR_MIN_WORK;
        if parallel {
            self.score_batch_scatter(query_embs)
        } else {
            self.score_batch_serial(query_embs)
        }
    }

    /// The single-threaded batch path: K=1 scores the lone view directly
    /// through the blocked batch scorer; K>1 runs the same per-shard
    /// blocked searches as the parallel scatter, minus the threads.
    fn score_batch_serial(&self, query_embs: &[Vec<f32>]) -> Vec<Vec<f64>> {
        if self.params.p >= 1.0 {
            return query_embs.iter().map(|_| self.global.ratings.clone()).collect();
        }
        let queries: Vec<&[f32]> = query_embs.iter().map(|q| q.as_slice()).collect();
        let mut scratch = ScoreScratch::new();
        if self.shards.len() == 1 {
            // K=1 fast path: local ids ARE global ids, so the id-mapping
            // merge is the identity — score the lone view directly (the
            // default single-shard config pays nothing for the machinery)
            return mixed_scores_batch_from(
                &self.params,
                &self.global.ratings,
                self.shards[0].view(),
                &queries,
                &mut scratch,
            );
        }
        let n = self.params.n_neighbors;
        let per_shard: Vec<Vec<Vec<Hit>>> = self
            .shards
            .iter()
            .zip(&self.ids)
            .map(|(snap, ids)| shard_hits(snap, ids, &queries, n))
            .collect();
        self.gather_scores(queries.len(), &per_shard, &mut scratch)
    }

    /// The explicit parallel scatter-gather path: every shard runs the
    /// blocked multi-query scan over the whole query slab on its own
    /// thread (scatter), then each query's K sorted candidate lists merge
    /// into the exact global top-N and finish through the same scoring
    /// code as the serial path (gather).
    pub fn score_batch_scatter(&self, query_embs: &[Vec<f32>]) -> Vec<Vec<f64>> {
        if self.shards.len() <= 1 || self.params.p >= 1.0 {
            return self.score_batch_serial(query_embs);
        }
        let queries: Vec<&[f32]> = query_embs.iter().map(|q| q.as_slice()).collect();
        let n = self.params.n_neighbors;
        let qs: &[&[f32]] = &queries;
        let per_shard: Vec<Vec<Vec<Hit>>> = std::thread::scope(|scope| {
            let tasks: Vec<_> = self
                .shards
                .iter()
                .zip(&self.ids)
                .map(|(snap, ids)| scope.spawn(move || shard_hits(snap, ids, qs, n)))
                .collect();
            tasks
                .into_iter()
                .map(|t| t.join().expect("scatter thread panicked"))
                .collect()
        });
        let mut scratch = ScoreScratch::new();
        self.gather_scores(queries.len(), &per_shard, &mut scratch)
    }

    /// Merge each query's per-shard candidates into the exact global
    /// top-N — descending score, ascending global id, exactly what a
    /// single store's TopK yields — then replay through the shared
    /// scoring core with one scratch buffer set for the whole batch.
    fn gather_scores(
        &self,
        n_queries: usize,
        per_shard: &[Vec<Vec<Hit>>],
        scratch: &mut ScoreScratch,
    ) -> Vec<Vec<f64>> {
        let n = self.params.n_neighbors;
        let scatter = self.scatter();
        let mut merged: Vec<Hit> = Vec::new();
        (0..n_queries)
            .map(|qi| {
                merged.clear();
                merged.extend(per_shard.iter().flat_map(|hits| hits[qi].iter().copied()));
                sort_hits(&mut merged);
                merged.truncate(n);
                mixed_scores_from_hits(
                    &self.params,
                    &self.global.ratings,
                    &scatter,
                    &merged,
                    scratch,
                )
            })
            .collect()
    }
}

/// One shard's blocked batch search with local ids mapped to global —
/// the per-thread body of the parallel scatter (and the serial K>1
/// loop). Per-shard (score, local id) order sorts identically under
/// global ids, so the mapped lists stay sorted for the gather merge.
fn shard_hits(
    snap: &RouterSnapshot,
    ids: &FrozenIds,
    queries: &[&[f32]],
    n: usize,
) -> Vec<Vec<Hit>> {
    let mut hit_lists = snap.view().search_batch(queries, n);
    for hits in &mut hit_lists {
        for h in hits.iter_mut() {
            h.id = ids.global_of(h.id);
        }
    }
    hit_lists
}

/// Read-only merged index over K shard views, addressed by global ids.
/// This is what makes sharded scoring reuse the single-shard code path
/// verbatim: [`mixed_scores_from`] neither knows nor cares that search
/// and payload lookup scatter under the hood.
pub struct ScatterView<'a> {
    dim: usize,
    shards: &'a [Arc<RouterSnapshot>],
    ids: &'a [Arc<FrozenIds>],
}

impl ScatterView<'_> {
    fn locate(&self, global: u32) -> (usize, u32) {
        for (s, ids) in self.ids.iter().enumerate() {
            if let Some(local) = ids.local_of(global) {
                if (local as usize) < self.shards[s].store_len() {
                    return (s, local);
                }
            }
        }
        panic!("global id {global} not visible in any shard view");
    }
}

impl ReadIndex for ScatterView<'_> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.store_len()).sum()
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let mut merged = Vec::new();
        for (snap, ids) in self.shards.iter().zip(self.ids) {
            for h in snap.view().search(query, k) {
                merged.push(Hit { id: ids.global_of(h.id), score: h.score });
            }
        }
        sort_hits(&mut merged);
        merged.truncate(k);
        merged
    }

    fn feedback(&self, id: u32) -> &Feedback {
        let (s, local) = self.locate(id);
        self.shards[s].view().feedback(local)
    }

    fn vector(&self, id: u32) -> &[f32] {
        let (s, local) = self.locate(id);
        self.shards[s].view().vector(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elo::{Comparison, Outcome};
    use crate::util::{l2_normalize, Rng};

    const DIM: usize = 16;
    const N_MODELS: usize = 5;

    fn unit(rng: &mut Rng) -> Vec<f32> {
        let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    fn rand_obs(rng: &mut Rng) -> Observation {
        let a = rng.below(N_MODELS);
        let mut b = rng.below(N_MODELS - 1);
        if b >= a {
            b += 1;
        }
        let outcome = match rng.below(3) {
            0 => Outcome::WinA,
            1 => Outcome::WinB,
            _ => Outcome::Draw,
        };
        Observation::single(unit(rng), Comparison { a, b, outcome })
    }

    fn cadence(every: usize) -> EpochParams {
        EpochParams { publish_every: every, publish_interval_ms: 10_000 }
    }

    fn shards(count: usize) -> ShardParams {
        ShardParams { count, hash_seed: 0xEA61E }
    }

    fn reference(stream: &[Observation]) -> EagleRouter<FlatStore> {
        let mut r = EagleRouter::new(EagleParams::default(), N_MODELS, FlatStore::new(DIM));
        for obs in stream {
            r.observe(obs.clone());
        }
        r
    }

    #[test]
    fn shard_hash_is_deterministic_in_range_and_spread() {
        let mut rng = Rng::new(1);
        let mut counts = vec![0usize; 4];
        for _ in 0..2000 {
            let v = unit(&mut rng);
            let s = shard_of(&v, 7, 4);
            assert_eq!(s, shard_of(&v, 7, 4), "hash not deterministic");
            assert!(s < 4);
            counts[s] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            assert!(c > 200, "shard {s} got only {c}/2000 vectors");
        }
        // seed changes the partition
        let v = unit(&mut rng);
        assert_eq!(shard_of(&v, 3, 1), 0);
        let moved = (0..100)
            .map(|_| unit(&mut rng))
            .filter(|v| shard_of(v, 1, 8) != shard_of(v, 2, 8))
            .count();
        assert!(moved > 10, "hash seed has no effect ({moved}/100 moved)");
    }

    #[test]
    fn id_blocks_roundtrip_and_merge() {
        let mut rng = Rng::new(2);
        let mut ids = IdBlocks::new();
        let mut expect = Vec::new();
        let mut next = 0u32;
        let mut last = FrozenIds::empty();
        for round in 0..200 {
            for _ in 0..(1 + rng.below(5)) {
                // strictly increasing, gappy global ids (as one shard sees)
                next += 1 + rng.below(3) as u32;
                ids.push(next);
                expect.push(next);
            }
            if round % 3 == 0 {
                last = ids.freeze();
            }
        }
        let frozen = ids.freeze();
        assert_eq!(frozen.len(), expect.len());
        for (local, &gid) in expect.iter().enumerate() {
            assert_eq!(frozen.global_of(local as u32), gid);
            assert_eq!(frozen.local_of(gid), Some(local as u32));
        }
        // ids never inserted resolve to None
        assert_eq!(frozen.local_of(0), None);
        assert_eq!(frozen.local_of(next + 100), None);
        // binary-counter merging keeps the block count logarithmic
        assert!(
            frozen.block_count() <= 16,
            "{} blocks for {} ids",
            frozen.block_count(),
            frozen.len()
        );
        // earlier freezes stay valid prefixes
        for local in 0..last.len() as u32 {
            assert_eq!(last.global_of(local), frozen.global_of(local));
        }
    }

    #[test]
    fn empty_sharded_router_scores_uniform() {
        let router = ShardedRouter::new(EagleParams::default(), 4, DIM, cadence(8), shards(3));
        let snap = router.handle().load();
        assert_eq!(snap.store_len(), 0);
        assert_eq!(snap.history_len(), 0);
        assert_eq!(snap.shard_count(), 3);
        let q = vec![1.0; DIM];
        assert_eq!(snap.scores(&q), vec![crate::elo::INITIAL_RATING; 4]);
    }

    #[test]
    fn sharded_scores_match_reference_at_k4() {
        let mut rng = Rng::new(3);
        let mut sharded =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(7), shards(4));
        let handle = sharded.handle();
        let mut stream = Vec::new();
        for step in 0..400 {
            let obs = rand_obs(&mut rng);
            stream.push(obs.clone());
            sharded.observe(obs);
            if (step + 1) % 83 == 0 {
                sharded.publish_all();
                let snap = handle.load();
                let reference = reference(&stream);
                assert_eq!(snap.history_len(), reference.feedback_len());
                assert_eq!(snap.store_len(), stream.len());
                for _ in 0..3 {
                    let q = unit(&mut rng);
                    assert_eq!(
                        snap.scores(&q),
                        reference.combined_scores(&q),
                        "divergence at step {step}"
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_scatter_is_bit_identical_to_serial() {
        // at DIM=16 the auto path stays serial (below the work gate), so
        // the threaded path is exercised explicitly via
        // score_batch_scatter; both must agree with per-query scores
        let mut rng = Rng::new(4);
        let mut sharded =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(512), shards(3));
        let mut stream = Vec::new();
        for _ in 0..(PAR_MIN_CORPUS + 500) {
            let obs = rand_obs(&mut rng);
            stream.push(obs.clone());
            sharded.observe(obs);
        }
        sharded.publish_all();
        let snap = sharded.handle().load();
        assert!(snap.store_len() >= PAR_MIN_CORPUS);
        let queries: Vec<Vec<f32>> = (0..8).map(|_| unit(&mut rng)).collect();
        let batch = snap.score_batch(&queries);
        let scatter = snap.score_batch_scatter(&queries);
        let reference = reference(&stream);
        for (i, q) in queries.iter().enumerate() {
            let serial = snap.scores(q);
            assert_eq!(batch[i], serial, "auto batch path diverged at query {i}");
            assert_eq!(scatter[i], serial, "scatter path diverged at query {i}");
            assert_eq!(serial, reference.combined_scores(q), "reference diverged at {i}");
        }
    }

    #[test]
    fn quantized_lanes_score_identically_serial_and_scatter() {
        // every lane publishes an SQ8 view; with a rerank factor covering
        // each shard's whole corpus the rerank is total, so serial batch,
        // threaded scatter, and the flat reference all agree bitwise
        let mut rng = Rng::new(6);
        let mut sharded =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(512), shards(3));
        sharded.set_quant(crate::config::QuantParams { enable: true, rerank_factor: 1024 });
        let mut stream = Vec::new();
        for _ in 0..(PAR_MIN_CORPUS + 500) {
            let obs = rand_obs(&mut rng);
            stream.push(obs.clone());
            sharded.observe(obs);
        }
        sharded.publish_all();
        let snap = sharded.handle().load();
        // the big sealed segments really are quantized on every lane
        use crate::coordinator::snapshot::SnapshotView;
        for (shard, s) in snap.shards.iter().enumerate() {
            match s.view() {
                SnapshotView::Quant(v) => {
                    // each lane holds ~1.5k rows; even an uneven hash
                    // split leaves at least one >= 512-row sealed segment
                    assert!(v.quantized_rows() >= 512, "shard {shard} barely quantized")
                }
                other => panic!("shard {shard}: expected quant view, got {other:?}"),
            }
        }
        let queries: Vec<Vec<f32>> = (0..6).map(|_| unit(&mut rng)).collect();
        let batch = snap.score_batch(&queries);
        let scatter = snap.score_batch_scatter(&queries);
        let reference = reference(&stream);
        for (i, q) in queries.iter().enumerate() {
            let serial = snap.scores(q);
            assert_eq!(batch[i], serial, "quant batch path diverged at query {i}");
            assert_eq!(scatter[i], serial, "quant scatter path diverged at query {i}");
            assert_eq!(serial, reference.combined_scores(q), "reference diverged at {i}");
        }
    }

    #[test]
    fn from_router_preserves_state_and_scores() {
        let mut rng = Rng::new(5);
        let mut flat = EagleRouter::new(EagleParams::default(), N_MODELS, FlatStore::new(DIM));
        for _ in 0..250 {
            flat.observe(rand_obs(&mut rng));
        }
        let probes: Vec<Vec<f32>> = (0..4).map(|_| unit(&mut rng)).collect();
        let expected: Vec<Vec<f64>> = probes.iter().map(|q| flat.combined_scores(q)).collect();
        let feedback_len = flat.feedback_len();
        let mut sharded = ShardedRouter::from_router(flat, cadence(8), shards(4));
        assert_eq!(sharded.history_len(), feedback_len);
        assert_eq!(sharded.store_len(), 250);
        let snap = sharded.handle().load();
        for (q, want) in probes.iter().zip(&expected) {
            assert_eq!(&snap.scores(q), want);
        }
        // and it keeps ingesting consistently after the takeover
        let mut stream_tail = Vec::new();
        for _ in 0..60 {
            let obs = rand_obs(&mut rng);
            stream_tail.push(obs.clone());
            sharded.observe(obs);
        }
        sharded.publish_all();
        assert_eq!(sharded.handle().load().store_len(), 310);
    }

    #[test]
    fn save_restore_roundtrips_through_flat_snapshot() {
        let mut rng = Rng::new(6);
        let mut sharded =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(9), shards(3));
        let mut stream = Vec::new();
        for _ in 0..150 {
            let obs = rand_obs(&mut rng);
            stream.push(obs.clone());
            sharded.observe(obs);
        }
        let dir = std::env::temp_dir()
            .join(format!("eagle_sharded_state_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sharded.json");
        sharded.save_to(&path).unwrap();
        let restored = super::super::state::load_from(&path).unwrap();
        assert_eq!(restored.feedback_len(), 150);
        assert_eq!(restored.store().len(), 150);
        let snap = sharded.handle().load();
        for _ in 0..4 {
            let q = unit(&mut rng);
            assert_eq!(restored.combined_scores(&q), snap.scores(&q));
        }
        // and re-sharding the restored router reproduces the same scores
        let reloaded = ShardedRouter::from_router(restored, cadence(9), shards(3));
        let snap2 = reloaded.handle().load();
        let q = unit(&mut rng);
        assert_eq!(snap.scores(&q), snap2.scores(&q));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lanes_decompose_and_keep_handles_working() {
        let mut rng = Rng::new(7);
        let sharded =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, cadence(1), shards(2));
        let handle = sharded.handle();
        let stream: Vec<Observation> = (0..40).map(|_| rand_obs(&mut rng)).collect();
        let (mut global, mut lanes) = sharded.into_lanes();
        for (gid, obs) in stream.iter().enumerate() {
            global.apply(&obs.comparisons);
            let s = shard_of(&obs.embedding, 0xEA61E, 2);
            lanes[s].apply(gid as u32, obs.clone());
            lanes[s].maybe_publish();
        }
        global.publish();
        for lane in &mut lanes {
            lane.publish();
        }
        let snap = handle.load();
        assert_eq!(snap.store_len(), 40);
        let reference = reference(&stream);
        let q = unit(&mut rng);
        assert_eq!(snap.scores(&q), reference.combined_scores(&q));
    }
}
