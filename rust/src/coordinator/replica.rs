//! Follower replication over the durable log: tail a leader's per-shard
//! delta logs and manifest swaps and rebuild bit-identical routing state.
//!
//! The durable store (see [`super::durable`]) already writes a
//! replication stream in disguise: sealed-once segment files, checksummed
//! append-only delta frames, and an atomically swapped manifest. A
//! [`Follower`] consumes that stream *read-only* — no advisory lock, no
//! log truncation, no orphan sweep — through the same
//! [`CatchUp`] replay path crash recovery uses, so the follower's
//! rebuilt [`crate::coordinator::sharded::ShardedSnapshot`]s are
//! bit-identical to what a post-crash restart would produce from the same
//! bytes. This gives warm-standby failover and read-replica scale-out: N
//! followers serve the scatter-gather route path while the leader owns
//! ingest.
//!
//! ## Tail protocol (filesystem transport)
//!
//! Each [`Follower::poll`]:
//!
//! 1. re-reads + parses `MANIFEST.json` (atomic swap ⇒ always one
//!    consistent cut; a newer `format_version` is a clear error, never a
//!    panic),
//! 2. applies any sealed segments whose manifest gid range reaches past
//!    the lane's applied-gid frontier (per-lane monotone-gid dedup
//!    absorbs both the overlap between a fresh segment and the delta log
//!    it was sealed from, *and* the overlap a compacted segment has with
//!    ranges already tailed). mmap'd v2 segments take the zero-copy bulk
//!    path; v1 segments decode per-frame,
//! 3. tails each lane's live delta log from its byte cursor with the
//!    read-only frame scan — a torn/incomplete final frame is simply "not
//!    yet written" and is retried next poll,
//! 4. publishes lanes on the usual epoch cadence and updates the
//!    [`ReplicaMetrics`] lag gauges.
//!
//! ## Compaction and GC under the tail
//!
//! The leader's compactor merges sealed segments and eventually deletes
//! the superseded files (after a grace window). A follower mid-tail can
//! therefore open a segment named by the manifest cut it read and find
//! the file gone. That is not corruption — it is the typed
//! "restart from manifest" signal: the poll abandons the lane's segment
//! pass, counts a [`ReplicaMetrics::manifest_restarts`], and the next
//! poll re-reads the *current* manifest, whose merged segments re-cover
//! every record the follower has not applied. The gid frontier makes the
//! restart cheap: merged segments are skipped up to the frontier without
//! opening them, and re-covered records are deduped per-gid.
//!
//! The global table folds strictly in gid order (the [`CatchUp`]
//! contiguity buffer), so follower ratings are bit-identical to the
//! leader's at every quiescent point.
//!
//! ## Promotion
//!
//! [`Follower::promote`] turns a warm standby into the leader: take the
//! advisory `LOCK` (refused while the old leader still runs), run one
//! final poll over the quiescent files, truncate any torn log tails and
//! sweep orphans (now safe — we own the store), fold the remaining
//! pending comparisons, and reassemble a live
//! [`ShardedRouter`] *around the same lanes and rings* the follower was
//! serving from — reader handles taken before promotion stay valid. The
//! durable store attaches to the already-recovered directory and lane
//! writers resume appending at the recovered tail.

use std::collections::HashSet;
use std::fs::{self, File};
use std::io::{Read as _, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::EpochParams;
use crate::metrics::Counter;

use super::durable::{
    acquire_lock, load_segment, parse_manifest, recover_log, scan_frames, sweep_orphans, CatchUp,
    DurableOptions, DurableStore, ManifestState, StoreMeta, LOCK, MANIFEST,
};
use super::sharded::{ShardedHandle, ShardedRouter};

/// Counters + gauges for one follower's tail loop. Counters are monotone;
/// the lag gauges are recomputed every poll.
#[derive(Debug, Default)]
pub struct ReplicaMetrics {
    /// Tail polls completed (including no-op polls).
    pub polls: Counter,
    /// Polls that failed (manifest unreadable mid-swap, leader racing a
    /// seal); the tail loop keeps going.
    pub errors: Counter,
    /// Records applied to shard lanes via the tail.
    pub applied_records: Counter,
    /// Sealed segment files applied via the tail.
    pub applied_segments: Counter,
    /// Segment passes abandoned because the leader's GC deleted a
    /// manifest-named file mid-tail; the next poll restarts from the
    /// current manifest.
    pub manifest_restarts: Counter,
    lag_bytes: AtomicU64,
    lag_frames: AtomicU64,
    manifest_generation: AtomicU64,
    effective_poll_ms: AtomicU64,
}

impl ReplicaMetrics {
    /// Unconsumed log-tail bytes after the last poll (a partial frame the
    /// leader is still writing, or backlog the follower has not read).
    pub fn lag_bytes(&self) -> u64 {
        self.lag_bytes.load(Ordering::Relaxed)
    }

    /// Decoded records whose global fold is still waiting for a
    /// contiguous gid run.
    pub fn lag_frames(&self) -> u64 {
        self.lag_frames.load(Ordering::Relaxed)
    }

    /// Generation of the last manifest swap the follower has seen.
    pub fn manifest_generation(&self) -> u64 {
        self.manifest_generation.load(Ordering::Relaxed)
    }

    /// The tail loop's current sleep between polls, in milliseconds:
    /// the configured base interval after a productive poll, doubled
    /// (up to the configured cap) after each idle one.
    pub fn effective_poll_ms(&self) -> u64 {
        self.effective_poll_ms.load(Ordering::Relaxed)
    }

    fn set_effective_poll(&self, ms: u64) {
        self.effective_poll_ms.store(ms, Ordering::Relaxed);
    }

    fn set_lag(&self, bytes: u64, frames: u64) {
        self.lag_bytes.store(bytes, Ordering::Relaxed);
        self.lag_frames.store(frames, Ordering::Relaxed);
    }

    fn set_generation(&self, generation: u64) {
        self.manifest_generation.store(generation, Ordering::Relaxed);
    }
}

/// What one [`Follower::poll`] accomplished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PollStats {
    /// New records applied this poll (segments + log frames).
    pub applied: usize,
    /// Unconsumed log-tail bytes remaining after this poll.
    pub lag_bytes: u64,
    /// Records waiting for a contiguous gid run before the global fold.
    pub pending_folds: usize,
    /// True when at least one lane hit a GC'd segment file and abandoned
    /// its segment pass; the next poll restarts from the current
    /// manifest. Records applied before the restart are kept.
    pub restarted: bool,
}

/// Per-lane tail cursor into the leader's *delta log*. Sealed-segment
/// progress is not tracked here: the applied-gid frontier lives in
/// [`CatchUp::lane_frontier`], which stays valid when the compactor
/// rewrites the segment list (a positional cursor would not).
struct LaneCursor {
    /// Relative path of the delta log this cursor is tailing.
    log: String,
    /// Byte offset of the next unread frame in that log.
    offset: u64,
}

/// A read-only replica tailing a leader's durable store directory. See
/// the module docs for the tail protocol and promotion semantics.
pub struct Follower {
    dir: PathBuf,
    catchup: CatchUp,
    cursors: Vec<LaneCursor>,
    manifest: ManifestState,
    metrics: Arc<ReplicaMetrics>,
    use_mmap: bool,
}

impl Follower {
    /// Attach to a leader's durable store directory and catch up to the
    /// current durable state. Read-only: never takes the lock, never
    /// truncates, never sweeps. Fails with a clear error if the manifest
    /// is missing or written by a newer format version.
    pub fn open(dir: &Path, cadence: EpochParams) -> Result<Follower> {
        Self::open_with(dir, cadence, true)
    }

    /// [`Follower::open`] with an explicit mmap choice: `use_mmap`
    /// serves v2 segments from the page cache via zero-copy views;
    /// `false` forces the buffered decode path (v1 segments always
    /// decode).
    pub fn open_with(dir: &Path, cadence: EpochParams, use_mmap: bool) -> Result<Follower> {
        let path = dir.join(MANIFEST);
        let text = fs::read_to_string(&path)
            .with_context(|| format!("no durable store to follow at {}", dir.display()))?;
        let (meta, manifest) = parse_manifest(&text)?;
        let catchup = CatchUp::begin(
            meta,
            manifest.global.folded_gid,
            manifest.global.state.clone(),
            cadence,
        );
        let cursors = manifest
            .lanes
            .iter()
            .map(|l| LaneCursor { log: l.log.clone(), offset: 0 })
            .collect();
        let mut follower = Follower {
            dir: dir.to_path_buf(),
            catchup,
            cursors,
            manifest,
            metrics: Arc::new(ReplicaMetrics::default()),
            use_mmap,
        };
        follower.poll()?;
        follower.catchup.publish_all();
        Ok(follower)
    }

    pub fn meta(&self) -> &StoreMeta {
        self.catchup.meta()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn metrics(&self) -> &Arc<ReplicaMetrics> {
        &self.metrics
    }

    /// Records applied to shard lanes so far.
    pub fn applied_records(&self) -> usize {
        self.catchup.applied_records()
    }

    /// Reader handle over the replica's lanes (survives promotion).
    pub fn handle(&self) -> ShardedHandle {
        self.catchup.handle()
    }

    /// One tail round: manifest re-read, new segments, log deltas,
    /// cadence publishes. Cheap when nothing changed.
    pub fn poll(&mut self) -> Result<PollStats> {
        self.metrics.polls.inc();
        let text = fs::read_to_string(self.dir.join(MANIFEST))
            .with_context(|| format!("reading manifest in {}", self.dir.display()))?;
        let (meta, manifest) = parse_manifest(&text)?;
        let known = self.catchup.meta();
        if meta.params != known.params
            || meta.n_models != known.n_models
            || meta.dim != known.dim
            || meta.shards != known.shards
        {
            bail!("durable store identity changed under the follower");
        }
        self.manifest = manifest;
        self.metrics.set_generation(self.manifest.generation);
        let (dim, n_models) = (meta.dim, meta.n_models);
        let mut applied = 0usize;
        let mut lag_bytes = 0u64;
        let mut restarted = false;
        for (shard, cur) in self.cursors.iter_mut().enumerate() {
            let lane = &self.manifest.lanes[shard];
            let mut lane_restarted = false;
            for seg in &lane.segments {
                // Skip segments fully below the lane's applied frontier
                // without opening them — after a compaction restart this
                // is what makes re-walking the merged list cheap.
                let frontier = self.catchup.lane_frontier(shard);
                if let (Some(last), Some(prev)) = (seg.last_gid, frontier) {
                    if last <= prev {
                        continue;
                    }
                }
                match load_segment(&self.dir.join(&seg.file), dim, n_models, seg, self.use_mmap)
                    .with_context(|| format!("segment {}", seg.file))?
                {
                    Some(loaded) => {
                        let before = self.catchup.applied_records();
                        self.catchup.apply_loaded_segment(shard, loaded);
                        applied += self.catchup.applied_records() - before;
                        self.metrics.applied_segments.inc();
                    }
                    // The leader's GC deleted this file after the
                    // manifest cut we read: restart from the current
                    // manifest next poll (the typed signal, not an
                    // error — see the module docs).
                    None => {
                        self.metrics.manifest_restarts.inc();
                        lane_restarted = true;
                        break;
                    }
                }
            }
            if lane_restarted {
                // Do NOT tail the delta log with sealed records still
                // unapplied: log gids run past the sealed range, and
                // applying them would advance the frontier over a gap
                // the dedup could never backfill.
                restarted = true;
                continue;
            }
            if cur.log != lane.log {
                cur.log = lane.log.clone();
                cur.offset = 0;
            }
            // Read-only tail of the live log past the cursor. A missing
            // file means the leader sealed between our manifest read and
            // now — the next poll sees the new manifest.
            let Ok(bytes) = read_from(&self.dir.join(&cur.log), cur.offset) else {
                continue;
            };
            let (records, consumed) = scan_frames(&bytes, dim, n_models);
            for (gid, obs) in records {
                if self.catchup.apply_delta_frame(shard, gid, obs) {
                    applied += 1;
                }
            }
            cur.offset += consumed as u64;
            lag_bytes += (bytes.len() - consumed) as u64;
        }
        let pending_folds = self.catchup.pending_folds();
        self.metrics.applied_records.add(applied as u64);
        self.metrics.set_lag(lag_bytes, pending_folds as u64);
        self.catchup.maybe_publish_all();
        Ok(PollStats { applied, lag_bytes, pending_folds, restarted })
    }

    /// Promote this follower to leader: take the advisory `LOCK` (refused
    /// while the old leader is still alive), run a final catch-up over
    /// the now-quiescent files, truncate torn log tails, sweep orphans,
    /// fold what is still pending, and reassemble the live router around
    /// the follower's own lanes — reader handles taken from
    /// [`Follower::handle`] keep working. On failure the follower comes
    /// back in the error, still tailing-capable.
    pub fn promote(mut self, opts: DurableOptions) -> std::result::Result<Promotion, PromoteError> {
        if let Err(error) = acquire_lock(&self.dir) {
            return Err(PromoteError { follower: self, error });
        }
        // From here the lock is ours; release it on any failure so the
        // returned follower (or another candidate) can retry. The files
        // are quiescent (dead leader, lock held), so a restarted poll —
        // the old leader's GC won a race just before it died — settles
        // on the very next pass over the now-stable manifest.
        let mut attempts = 0;
        loop {
            match self.poll() {
                Ok(stats) if !stats.restarted => break,
                Ok(_) if attempts < 3 => attempts += 1,
                Ok(_) => {
                    // Quiescent files still name a missing segment: that
                    // is a damaged store, not a racing GC.
                    let _ = fs::remove_file(self.dir.join(LOCK));
                    let error = anyhow::anyhow!(
                        "manifest references missing segment files with no live leader"
                    );
                    return Err(PromoteError { follower: self, error });
                }
                Err(error) => {
                    let _ = fs::remove_file(self.dir.join(LOCK));
                    return Err(PromoteError { follower: self, error });
                }
            }
        }
        let (dim, n_models) = (self.meta().dim, self.meta().n_models);
        let mut referenced: HashSet<PathBuf> = HashSet::new();
        for lane in &self.manifest.lanes {
            for seg in &lane.segments {
                referenced.insert(self.dir.join(&seg.file));
            }
            let log_path = self.dir.join(&lane.log);
            referenced.insert(log_path.clone());
            // Truncate a torn tail (the crash that made us promote). Our
            // cursor only ever consumed validated frames, so nothing
            // applied is lost.
            if let Err(error) =
                recover_log(&log_path, dim, n_models).with_context(|| format!("log {}", lane.log))
            {
                let _ = fs::remove_file(self.dir.join(LOCK));
                return Err(PromoteError { follower: self, error });
            }
        }
        sweep_orphans(&self.dir, self.manifest.lanes.len(), &referenced);
        let Follower { dir, catchup, manifest, .. } = self;
        let meta = catchup.meta().clone();
        let router = catchup.finish();
        let store = DurableStore::attach(&dir, meta, opts, manifest);
        Ok(Promotion { store, router })
    }
}

/// A successful promotion: the attached store (lock held, logs repaired)
/// and the live router reassembled around the follower's lanes. Feed both
/// to the ingest pipeline to start accepting feedback.
pub struct Promotion {
    pub store: Arc<DurableStore>,
    pub router: ShardedRouter,
}

/// A failed promotion, with the follower handed back intact so it can
/// keep tailing (the usual cause: the leader is still alive and holds
/// the lock).
pub struct PromoteError {
    pub follower: Follower,
    pub error: anyhow::Error,
}

/// Background tail loop around a [`Follower`]: polls until stopped, at
/// which point the follower is handed back (for promotion). The sleep
/// between polls starts at the configured base interval and doubles
/// after every idle poll up to a cap, snapping back to the base the
/// moment a poll applies records, restarts from the manifest, or errors
/// — a quiet leader costs a handful of stat calls per cap interval
/// while a busy one is tailed at full cadence. Dropping the handle
/// stops the loop.
pub struct FollowerHandle {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<Follower>>,
    metrics: Arc<ReplicaMetrics>,
    handle: ShardedHandle,
}

impl FollowerHandle {
    /// Spawn the tail thread. Poll errors (a manifest swap racing the
    /// read, the leader dying) are counted, not fatal — the loop keeps
    /// retrying so a standby survives leader restarts. `backoff_max`
    /// caps the idle backoff; at or below `poll_interval` it disables
    /// backoff entirely (fixed-interval polling).
    pub fn spawn(
        follower: Follower,
        poll_interval: Duration,
        backoff_max: Duration,
    ) -> FollowerHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = follower.metrics().clone();
        let handle = follower.handle();
        let tail_stop = stop.clone();
        let base = poll_interval.max(Duration::from_millis(1));
        let cap = backoff_max.max(base);
        metrics.set_effective_poll(base.as_millis() as u64);
        let thread = std::thread::Builder::new()
            .name("eagle-replica-tail".into())
            .spawn(move || {
                let mut follower = follower;
                let mut interval = base;
                while !tail_stop.load(Ordering::Acquire) {
                    let idle = match follower.poll() {
                        Ok(stats) => stats.applied == 0 && !stats.restarted,
                        Err(_) => {
                            follower.metrics().errors.inc();
                            false
                        }
                    };
                    interval = if idle { (interval * 2).min(cap) } else { base };
                    follower.metrics().set_effective_poll(interval.as_millis() as u64);
                    interruptible_sleep(&tail_stop, interval);
                }
                follower
            })
            .expect("spawning eagle-replica-tail");
        FollowerHandle { stop, thread: Some(thread), metrics, handle }
    }

    pub fn metrics(&self) -> &Arc<ReplicaMetrics> {
        &self.metrics
    }

    /// Reader handle over the replica's lanes (valid across promotion).
    pub fn handle(&self) -> &ShardedHandle {
        &self.handle
    }

    /// Stop the tail loop and take the follower back (the promotion
    /// path). Returns `None` if already stopped.
    pub fn stop(&mut self) -> Option<Follower> {
        self.stop.store(true, Ordering::Release);
        self.thread.take().map(|t| t.join().expect("eagle-replica-tail panicked"))
    }
}

impl Drop for FollowerHandle {
    fn drop(&mut self) {
        let _ = self.stop();
    }
}

/// Sleep up to `total`, waking early when `stop` flips (keeps promotion
/// latency bounded even with long poll intervals).
fn interruptible_sleep(stop: &AtomicBool, total: Duration) {
    let mut left = total;
    while !stop.load(Ordering::Acquire) && left > Duration::ZERO {
        let step = left.min(Duration::from_millis(25));
        std::thread::sleep(step);
        left -= step;
    }
}

/// Read a file from `offset` to EOF (the follower's incremental log
/// tail; avoids re-reading already-consumed bytes every poll).
fn read_from(path: &Path, offset: u64) -> Result<Vec<u8>> {
    let mut f = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    f.seek(SeekFrom::Start(offset))
        .with_context(|| format!("seeking {}", path.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(bytes)
}
