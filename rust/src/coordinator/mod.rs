//! The Eagle serving coordinator — the paper's system contribution.
//!
//! - [`registry`] — the model pool visible to the router (names + costs).
//! - [`router`] — [`router::EagleRouter`]: global + local ELO scoring.
//! - [`policy`] — budget-constrained model selection.
//! - [`feedback`] — online feedback ingestion (paper workflow step 5).
//! - [`snapshot`] — RCU snapshot routing: lock-free scoring snapshots
//!   published at epoch cadence by a single-writer ingest side.
//! - [`sharded`] — K-shard scatter-gather routing over the RCU core:
//!   hash-partitioned corpus, one writer per shard, shared global ELO.
//! - [`ingest`] — the sharded ingest pipeline: embed-on-applier batching,
//!   a stream-order global dispatcher, one applier thread per shard lane.
//! - [`durable`] — segment-granular durable persistence: sealed segment
//!   files + per-shard delta logs + an atomically-swapped manifest, with
//!   crash recovery back to a bit-identical [`sharded::ShardedRouter`].
//! - [`replica`] — follower replication over the durable log: tail a
//!   leader's delta logs + manifest swaps read-only, rebuild bit-identical
//!   snapshots, and promote to leader on failover.
//! - [`state`] — legacy single-JSON snapshot/restore of router state.
//!
//! The [`Router`] trait is the uniform surface the evaluation harness and
//! the server drive; Eagle and the three baselines all implement it.

pub mod durable;
pub mod feedback;
pub mod ingest;
pub mod policy;
pub mod registry;
pub mod replica;
pub mod router;
pub mod sharded;
pub mod snapshot;
pub mod state;

use crate::baselines::QualityPredictor;

/// A router: maps a query embedding to a per-model desirability score.
/// Scores are only compared *within* one call (rankings), never across
/// routers — ELO points and predicted-quality units need not match.
pub trait Router {
    fn name(&self) -> String;

    /// Per-model scores for one (already embedded) query. Higher = better.
    fn scores(&self, query_emb: &[f32]) -> Vec<f64>;
}

/// Adapter: any [`QualityPredictor`] baseline is a [`Router`].
pub struct PredictorRouter<P: QualityPredictor> {
    inner: P,
}

impl<P: QualityPredictor> PredictorRouter<P> {
    pub fn new(inner: P) -> Self {
        PredictorRouter { inner }
    }

    pub fn inner(&self) -> &P {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut P {
        &mut self.inner
    }
}

impl<P: QualityPredictor> Router for PredictorRouter<P> {
    fn name(&self) -> String {
        self.inner.name().to_string()
    }

    fn scores(&self, query_emb: &[f32]) -> Vec<f64> {
        self.inner.predict(query_emb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::knn::KnnPredictor;
    use crate::baselines::linalg::Matrix;
    use crate::baselines::TrainSet;

    #[test]
    fn predictor_router_adapts() {
        let mut knn = KnnPredictor::new(1);
        knn.fit(&TrainSet::new(
            Matrix::from_rows(&[vec![1.0, 0.0]]),
            Matrix::from_rows(&[vec![0.25, 0.75]]),
        ));
        let r = PredictorRouter::new(knn);
        assert_eq!(r.name(), "knn");
        let s = r.scores(&[1.0, 0.0]);
        assert!((s[1] - 0.75).abs() < 1e-6);
    }
}
