//! Read-copy-update snapshot routing: the lock-free serving core.
//!
//! The old serving path funneled every request through one
//! `RwLock<EagleRouter>`; a feedback burst's write locks stalled all route
//! reads. This module splits the router into:
//!
//! - [`RouterSnapshot`] — an immutable scoring state (trajectory-averaged
//!   global ELO table + a frozen vector-index view), cheap to clone and
//!   safe to score against from any number of threads;
//! - [`RouterWriter`] — the single-writer ingest side. It owns the live
//!   `EagleRouter<SegmentStore>`, applies feedback with **no lock of any
//!   kind**, and republishes a fresh snapshot at a configurable epoch
//!   cadence (every K records or T ms, [`crate::config::EpochParams`]);
//! - [`SnapshotRing`] — the publication point. A fixed ring of
//!   `RwLock<Arc<RouterSnapshot>>` slots plus an atomic cursor: readers
//!   acquire the *current* slot, the writer only ever writes the *next*
//!   slot, so a route read never contends with publication (let alone
//!   with feedback application) unless a reader stalls for a full ring
//!   revolution — `RING_SLOTS` publishes — between loading the cursor and
//!   locking the slot. Readers therefore never block in practice, and the
//!   design stays 100% safe Rust (no hazard pointers, no leaked
//!   graveyard).
//!
//! Consistency: a snapshot is built by one thread and published via an
//! `Arc` swap, so every reader observes an internally consistent
//! `(epoch, ratings, view)` triple — torn reads are impossible by
//! construction, which `rust/tests/snapshot_routing.rs` verifies under a
//! feedback storm.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::config::{EagleParams, EpochParams, IvfPublishParams, QuantParams};
use crate::vectordb::flat::FlatStore;
use crate::vectordb::ivf::{IvfIndex, IvfParams, IvfView};
use crate::vectordb::quant::{QuantCache, QuantView, QUANT_MIN_SEGMENT_ROWS};
use crate::vectordb::view::{FrozenView, SegmentStore, Slab};
use crate::vectordb::{BatchTopK, Feedback, Hit, ReadIndex, VectorIndex};

use super::router::{
    mixed_scores_batch_from, mixed_scores_from, EagleRouter, Observation, ScoreScratch,
};
use super::Router;

/// Number of publication slots. Also the number of historical snapshots
/// kept alive (snapshots share segment storage, so this costs O(RING ·
/// (n_models + log n)) small allocations, not O(RING · corpus)).
pub const RING_SLOTS: usize = 64;

/// A generic single-writer RCU publication cell: a fixed ring of
/// `RwLock<Arc<T>>` slots plus an atomic cursor. Readers lock the
/// *current* slot, the writer only ever writes the *next* slot, so a
/// `load` never contends with a `publish` unless a reader stalls for a
/// full ring revolution between loading the cursor and locking the slot.
///
/// This is the publication mechanism behind [`SnapshotRing`], factored
/// out so the sharded router ([`super::sharded`]) can publish other
/// immutable values (shared global-ELO tables, id maps) the same way.
#[derive(Debug)]
pub struct RcuCell<T> {
    slots: Vec<RwLock<Arc<T>>>,
    /// Monotone publish counter; `counter % slots.len()` is the live slot.
    cursor: AtomicUsize,
}

impl<T> RcuCell<T> {
    /// Cell with the default [`RING_SLOTS`] depth.
    pub fn new(initial: Arc<T>) -> Self {
        Self::with_slots(initial, RING_SLOTS)
    }

    /// Cell with an explicit slot count (>= 2).
    pub fn with_slots(initial: Arc<T>, slots: usize) -> Self {
        assert!(slots >= 2, "an RCU cell needs at least 2 slots");
        RcuCell {
            slots: (0..slots).map(|_| RwLock::new(initial.clone())).collect(),
            cursor: AtomicUsize::new(0),
        }
    }

    /// The current value. Wait-free against the writer in practice (one
    /// uncontended `RwLock` read of a slot the writer is not touching).
    pub fn load(&self) -> Arc<T> {
        let c = self.cursor.load(Ordering::Acquire);
        self.slots[c % self.slots.len()].read().unwrap().clone()
    }

    /// Single-writer publish: install into the *next* slot, then advance
    /// the cursor. Callers must ensure only one thread publishes.
    pub fn publish(&self, value: Arc<T>) {
        let next = self.cursor.load(Ordering::Relaxed) + 1;
        *self.slots[next % self.slots.len()].write().unwrap() = value;
        self.cursor.store(next, Ordering::Release);
    }
}

/// The frozen index inside a snapshot: exact segmented view for the
/// serving default, SQ8-quantized scan + exact rerank when the `[quant]`
/// policy is on, IVF core + exact tail for large corpora (IVF supersedes
/// quantization past its threshold).
#[derive(Debug, Clone)]
pub enum SnapshotView {
    Flat(FrozenView),
    Quant(QuantView),
    Ivf(IvfView),
}

impl ReadIndex for SnapshotView {
    fn dim(&self) -> usize {
        match self {
            SnapshotView::Flat(v) => v.dim(),
            SnapshotView::Quant(v) => v.dim(),
            SnapshotView::Ivf(v) => v.dim(),
        }
    }

    fn len(&self) -> usize {
        match self {
            SnapshotView::Flat(v) => v.len(),
            SnapshotView::Quant(v) => v.len(),
            SnapshotView::Ivf(v) => v.len(),
        }
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        match self {
            SnapshotView::Flat(v) => v.search(query, k),
            SnapshotView::Quant(v) => v.search(query, k),
            SnapshotView::Ivf(v) => v.search(query, k),
        }
    }

    fn search_batch_into(&self, queries: &[&[f32]], k: usize, acc: &mut BatchTopK) {
        match self {
            SnapshotView::Flat(v) => v.search_batch_into(queries, k, acc),
            SnapshotView::Quant(v) => v.search_batch_into(queries, k, acc),
            SnapshotView::Ivf(v) => v.search_batch_into(queries, k, acc),
        }
    }

    fn feedback(&self, id: u32) -> &Feedback {
        match self {
            SnapshotView::Flat(v) => v.feedback(id),
            SnapshotView::Quant(v) => v.feedback(id),
            SnapshotView::Ivf(v) => v.feedback(id),
        }
    }

    fn vector(&self, id: u32) -> &[f32] {
        match self {
            SnapshotView::Flat(v) => v.vector(id),
            SnapshotView::Quant(v) => v.vector(id),
            SnapshotView::Ivf(v) => v.vector(id),
        }
    }
}

/// An immutable scoring state published at one epoch. Scoring against it
/// takes no lock and sees no concurrent mutation, ever.
#[derive(Debug, Clone)]
pub struct RouterSnapshot {
    epoch: u64,
    params: EagleParams,
    n_models: usize,
    global_ratings: Vec<f64>,
    history_len: usize,
    view: SnapshotView,
}

impl RouterSnapshot {
    /// Build a snapshot directly from parts (tests, custom publishers).
    pub fn with_view(
        epoch: u64,
        params: EagleParams,
        global_ratings: Vec<f64>,
        history_len: usize,
        view: SnapshotView,
    ) -> Self {
        let n_models = global_ratings.len();
        RouterSnapshot { epoch, params, n_models, global_ratings, history_len, view }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn params(&self) -> &EagleParams {
        &self.params
    }

    pub fn n_models(&self) -> usize {
        self.n_models
    }

    /// Trajectory-averaged global ratings frozen at publish time.
    pub fn global_ratings(&self) -> &[f64] {
        &self.global_ratings
    }

    /// Feedback records folded in up to this epoch.
    pub fn history_len(&self) -> usize {
        self.history_len
    }

    pub fn view(&self) -> &SnapshotView {
        &self.view
    }

    /// Stored prompts visible to this snapshot.
    pub fn store_len(&self) -> usize {
        self.view.len()
    }

    /// Combined Eagle scores for one embedded query (same math, same
    /// tie-breaks as `EagleRouter::combined_scores` over the same data).
    pub fn scores(&self, query_emb: &[f32]) -> Vec<f64> {
        mixed_scores_from(&self.params, &self.global_ratings, &self.view, query_emb)
    }

    /// Score a batch of queries against this one frozen state: a single
    /// snapshot acquisition amortized over the whole batch, retrieval
    /// through the query-blocked kernel scan, one scratch buffer set for
    /// the local replays — bit-identical to mapping
    /// [`RouterSnapshot::scores`] per query.
    pub fn score_batch(&self, query_embs: &[Vec<f32>]) -> Vec<Vec<f64>> {
        let mut scratch = ScoreScratch::new();
        self.score_batch_with(query_embs, &mut scratch)
    }

    /// [`RouterSnapshot::score_batch`] with a caller-held scratch, for
    /// serving loops that score batch after batch (no allocation once the
    /// scratch is warm).
    pub fn score_batch_with(
        &self,
        query_embs: &[Vec<f32>],
        scratch: &mut ScoreScratch,
    ) -> Vec<Vec<f64>> {
        let queries: Vec<&[f32]> = query_embs.iter().map(|q| q.as_slice()).collect();
        mixed_scores_batch_from(&self.params, &self.global_ratings, &self.view, &queries, scratch)
    }
}

/// The lock-free publication point (see module docs for the ring
/// argument). Readers call [`SnapshotRing::load`]; only the single
/// [`RouterWriter`] calls `publish`.
pub struct SnapshotRing {
    cell: RcuCell<RouterSnapshot>,
}

impl SnapshotRing {
    fn new(initial: Arc<RouterSnapshot>) -> Self {
        SnapshotRing { cell: RcuCell::new(initial) }
    }

    /// The current snapshot. Wait-free against feedback application and
    /// effectively uncontended against publication (one uncontended
    /// `RwLock` read of a slot the writer is not touching).
    pub fn load(&self) -> Arc<RouterSnapshot> {
        self.cell.load()
    }

    /// Epoch of the current snapshot (diagnostics).
    pub fn current_epoch(&self) -> u64 {
        self.load().epoch()
    }

    /// Single-writer publish (the [`RouterWriter`] owning this ring).
    fn publish(&self, snap: Arc<RouterSnapshot>) {
        self.cell.publish(snap);
    }
}

/// A [`SnapshotRing`] is itself a [`Router`]: every call scores against
/// the currently published snapshot. This is the serving read path as a
/// trait object — the evaluation harness can drive it like any other
/// router, so quality numbers come from exactly what the server serves.
impl Router for SnapshotRing {
    fn name(&self) -> String {
        "eagle-snapshot".to_string()
    }

    fn scores(&self, query_emb: &[f32]) -> Vec<f64> {
        self.load().scores(query_emb)
    }
}

/// Once the IVF tail reaches this fraction of the core, the next publish
/// rebuilds the core over the full contents (geometric compaction: the
/// O(n) rebuild amortizes to O(log n) rebuilds over the corpus lifetime,
/// and the exact-scanned tail never exceeds half the core).
const IVF_REBUILD_TAIL_FRACTION: f64 = 0.5;

/// k-means refinement passes per core rebuild (cells only need to be
/// good enough for probing; exactness comes from `nprobe`, and
/// `nprobe == n_cells` is exhaustive regardless of cell quality).
const IVF_KMEANS_ITERS: usize = 6;

/// The single-writer ingest side: applies feedback to the live router
/// (lock-free — it owns it) and republishes snapshots at epoch cadence.
///
/// With an IVF publication policy installed ([`RouterWriter::set_ivf`]),
/// the writer additionally maintains an IVF *core* + exact *tail* beside
/// the authoritative segment store: past the corpus-size threshold,
/// publishes hand out [`SnapshotView::Ivf`] instead of the flat view, and
/// the core is rebuilt over the full contents at compaction time — on the
/// ingest thread, never on the route path (readers keep their pinned
/// snapshots throughout a rebuild).
pub struct RouterWriter {
    router: EagleRouter<SegmentStore>,
    ring: Arc<SnapshotRing>,
    cadence: EpochParams,
    epoch: u64,
    since_publish: usize,
    last_publish: Instant,
    /// IVF publication policy; `None` (or `publish_threshold == 0`) keeps
    /// every publish on the exact flat view.
    ivf: Option<IvfPublishParams>,
    /// The immutable IVF core shared with published snapshots.
    ivf_core: Option<Arc<IvfIndex>>,
    /// Entries ingested since the core was last rebuilt (ids continue the
    /// core's id space).
    ivf_tail: Option<SegmentStore>,
    /// SQ8 publication policy; `None` (or `enable == false`) publishes
    /// plain flat views. Applies only below the IVF threshold.
    quant: Option<QuantParams>,
    /// Per-segment SQ8 sidecars carried across publishes: a sealed
    /// segment is encoded once and reused until compaction merges it
    /// away (the cache drops entries for retired segments on refresh).
    quant_cache: QuantCache,
    /// `n_cells` the last core rebuild actually used (tracks the
    /// sqrt(corpus) resolution when the policy says `auto`).
    ivf_resolved_cells: usize,
}

impl RouterWriter {
    /// Cold-start writer; publishes the empty epoch-0 snapshot.
    pub fn new(params: EagleParams, n_models: usize, dim: usize, cadence: EpochParams) -> Self {
        Self::from_router_generic(
            EagleRouter::new(params, n_models, SegmentStore::new(dim)),
            cadence,
        )
    }

    /// Take over a flat-store router (disk restore / pre-fit history),
    /// keeping its global ELO state intact.
    pub fn from_router(router: EagleRouter<FlatStore>, cadence: EpochParams) -> Self {
        Self::from_router_generic(
            router.map_store(|flat| SegmentStore::from_flat(&flat)),
            cadence,
        )
    }

    /// Take over a segment-store router directly (sharded lanes restore a
    /// pre-partitioned corpus through this).
    pub fn from_segment_router(router: EagleRouter<SegmentStore>, cadence: EpochParams) -> Self {
        Self::from_router_generic(router, cadence)
    }

    fn from_router_generic(mut router: EagleRouter<SegmentStore>, cadence: EpochParams) -> Self {
        let initial = Arc::new(RouterSnapshot {
            epoch: 0,
            params: router.params().clone(),
            n_models: router.n_models(),
            global_ratings: router.global().ratings(),
            history_len: router.feedback_len(),
            view: SnapshotView::Flat(router.store_mut().freeze()),
        });
        RouterWriter {
            router,
            ring: Arc::new(SnapshotRing::new(initial)),
            cadence,
            epoch: 0,
            since_publish: 0,
            last_publish: Instant::now(),
            ivf: None,
            ivf_core: None,
            ivf_tail: None,
            quant: None,
            quant_cache: QuantCache::new(),
            ivf_resolved_cells: 0,
        }
    }

    /// Install (or replace) the IVF publication policy. A
    /// `publish_threshold` of 0 disables IVF publication; the next
    /// publish past the threshold builds the first core.
    pub fn set_ivf(&mut self, params: IvfPublishParams) {
        if params.publish_threshold == 0 {
            self.ivf = None;
            self.ivf_core = None;
            self.ivf_tail = None;
        } else {
            self.ivf = Some(params);
        }
    }

    /// Install (or replace) the SQ8 publication policy. `enable == false`
    /// (or `rerank_factor == 0`) turns quantized publication off and
    /// drops the sidecar cache; the next publish past any sealed segment
    /// of [`QUANT_MIN_SEGMENT_ROWS`] rows hands out
    /// [`SnapshotView::Quant`]. IVF publication supersedes this once the
    /// corpus passes its threshold.
    pub fn set_quant(&mut self, params: QuantParams) {
        if params.enable && params.rerank_factor > 0 {
            self.quant = Some(params);
        } else {
            self.quant = None;
            self.quant_cache = QuantCache::new();
        }
    }

    /// The active SQ8 publication policy, if any.
    pub fn quant_params(&self) -> Option<QuantParams> {
        self.quant
    }

    /// `n_cells` used by the most recent IVF core rebuild (0 before any
    /// rebuild). With `[ivf] n_cells = auto` this is the sqrt(corpus)
    /// resolution, otherwise the configured value.
    pub fn ivf_resolved_cells(&self) -> usize {
        self.ivf_resolved_cells
    }

    /// Entries currently inside the IVF core / tail (diagnostics; (0, 0)
    /// while publishing flat views).
    pub fn ivf_core_tail_len(&self) -> (usize, usize) {
        (
            self.ivf_core.as_ref().map_or(0, |c| c.len()),
            self.ivf_tail.as_ref().map_or(0, |t| t.len()),
        )
    }

    /// The publication ring handle to hand to readers.
    pub fn ring(&self) -> Arc<SnapshotRing> {
        self.ring.clone()
    }

    /// The live (writer-side) router. Reads here see unpublished records;
    /// use for persistence and diagnostics, never for serving.
    pub fn router(&self) -> &EagleRouter<SegmentStore> {
        &self.router
    }

    pub fn cadence(&self) -> &EpochParams {
        &self.cadence
    }

    /// Last published epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Records applied but not yet visible to readers.
    pub fn unpublished(&self) -> usize {
        self.since_publish
    }

    /// Ingest one observation and republish if the epoch cadence says so.
    /// Returns the new epoch if a publish happened.
    pub fn observe(&mut self, obs: Observation) -> Option<u64> {
        self.apply(obs);
        self.maybe_publish()
    }

    /// Apply one observation *without* checking the publish cadence.
    /// Callers that coordinate a multi-part publication (the sharded
    /// lanes publish an id map before the snapshot) drive
    /// [`RouterWriter::publish_due`] + [`RouterWriter::publish`]
    /// themselves.
    pub fn apply(&mut self, obs: Observation) {
        if let Some(tail) = &mut self.ivf_tail {
            // mirror into the IVF tail: ids continue the core's space, so
            // core.len() + tail ids == the authoritative store's ids
            tail.add(&obs.embedding, Feedback { comparisons: obs.comparisons.clone() });
        }
        self.router.observe(obs);
        self.since_publish += 1;
    }

    /// Bulk-apply one sealed block (a mapped v2 segment replayed by the
    /// durable store's catch-up): the store adopts the embedding slab as
    /// one zero-copy sealed segment; ELO folds and publication
    /// bookkeeping stay per-record, bit-identical to repeating
    /// [`RouterWriter::apply`] over the block's rows.
    pub(crate) fn apply_block(&mut self, slab: Slab, feedbacks: Vec<Feedback>) {
        let dim = self.router.store().dim();
        if let Some(tail) = &mut self.ivf_tail {
            for (row, fb) in slab.as_f32s().chunks_exact(dim).zip(&feedbacks) {
                tail.add(row, Feedback { comparisons: fb.comparisons.clone() });
            }
        }
        self.since_publish += feedbacks.len();
        self.router.absorb_block(slab, feedbacks);
    }

    /// True when the epoch cadence says pending records should publish.
    pub fn publish_due(&self) -> bool {
        self.since_publish != 0
            && (self.since_publish >= self.cadence.publish_every.max(1)
                || self.last_publish.elapsed()
                    >= Duration::from_millis(self.cadence.publish_interval_ms))
    }

    /// Publish if either cadence threshold (K records / T ms with pending
    /// records) has tripped.
    pub fn maybe_publish(&mut self) -> Option<u64> {
        self.publish_due().then(|| self.publish())
    }

    /// Unconditional publish of the current writer state.
    pub fn publish(&mut self) -> u64 {
        self.epoch += 1;
        let view = self.build_view();
        let snap = RouterSnapshot {
            epoch: self.epoch,
            params: self.router.params().clone(),
            n_models: self.router.n_models(),
            global_ratings: self.router.global().ratings(),
            history_len: self.router.feedback_len(),
            view,
        };
        self.ring.publish(Arc::new(snap));
        self.since_publish = 0;
        self.last_publish = Instant::now();
        self.epoch
    }

    /// The frozen index for the next snapshot: the exact flat view below
    /// the IVF threshold, IVF core + exact tail beyond it (rebuilding the
    /// core first when the tail has outgrown its compaction budget).
    fn build_view(&mut self) -> SnapshotView {
        let threshold = match &self.ivf {
            Some(p) if p.publish_threshold > 0 => p.publish_threshold,
            _ => return self.build_flat_view(),
        };
        let total = self.router.store().len();
        if total < threshold {
            return self.build_flat_view();
        }
        let due = match (&self.ivf_core, &self.ivf_tail) {
            (Some(core), Some(tail)) => {
                tail.len() as f64 >= core.len().max(1) as f64 * IVF_REBUILD_TAIL_FRACTION
            }
            _ => true,
        };
        if due {
            self.rebuild_ivf_core();
        }
        let core = self.ivf_core.as_ref().expect("core exists past threshold").clone();
        let tail = self.ivf_tail.as_mut().expect("tail exists past threshold").freeze();
        debug_assert_eq!(core.len() + tail.len(), total, "ivf core/tail id-space skew");
        SnapshotView::Ivf(IvfView::new(core, tail))
    }

    /// Flat publication: the plain frozen view, or its SQ8-quantized
    /// wrapper when the `[quant]` policy is on (sidecar encodes happen
    /// here, on the ingest thread, reusing cached segments).
    fn build_flat_view(&mut self) -> SnapshotView {
        let frozen = self.router.store_mut().freeze();
        match self.quant {
            Some(p) => SnapshotView::Quant(QuantView::build(
                frozen,
                &mut self.quant_cache,
                QUANT_MIN_SEGMENT_ROWS,
                p.rerank_factor,
            )),
            None => SnapshotView::Flat(frozen),
        }
    }

    /// Compaction: re-cluster the *entire* current contents into a fresh
    /// IVF core and reset the tail. O(n · n_cells · kmeans_iters) on the
    /// ingest thread; route scoring is untouched (readers pin the old
    /// core's `Arc` until their snapshots retire).
    ///
    /// This is also where `[ivf] n_cells = auto` (0) resolves: the cell
    /// count becomes `ceil(sqrt(corpus))`, and `nprobe` clamps (with a
    /// warning) if it exceeds the resolved count.
    fn rebuild_ivf_core(&mut self) {
        let params = self.ivf.as_ref().expect("rebuild without ivf policy");
        let store = self.router.store_mut().freeze();
        let n = store.len();
        let n_cells = if params.n_cells == 0 {
            ((n as f64).sqrt().ceil() as usize).clamp(1, n.max(1))
        } else {
            params.n_cells
        };
        let nprobe = if params.nprobe > n_cells {
            if params.n_cells > 0 {
                // explicit configs were range-checked at parse time; only
                // the auto resolution can land below a configured nprobe
                debug_assert!(false, "explicit nprobe above n_cells survived validation");
            }
            eprintln!(
                "warning: ivf.nprobe = {} exceeds resolved n_cells = {n_cells}; clamping",
                params.nprobe
            );
            n_cells
        } else {
            params.nprobe
        };
        self.ivf_resolved_cells = n_cells;
        let mut vectors = Vec::with_capacity(n);
        let mut payloads = Vec::with_capacity(n);
        for id in 0..n as u32 {
            vectors.push(store.vector(id).to_vec());
            payloads.push(store.feedback(id).clone());
        }
        let core = IvfIndex::build(
            store.dim(),
            &vectors,
            payloads,
            IvfParams {
                n_cells,
                nprobe,
                kmeans_iters: IVF_KMEANS_ITERS,
                seed: 0x1F5 ^ self.epoch,
            },
        );
        self.ivf_core = Some(Arc::new(core));
        self.ivf_tail = Some(SegmentStore::new(store.dim()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elo::{Comparison, Outcome};
    use crate::util::{l2_normalize, Rng};

    const DIM: usize = 16;

    fn unit(rng: &mut Rng) -> Vec<f32> {
        let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    fn rand_obs(rng: &mut Rng, n_models: usize) -> Observation {
        let a = rng.below(n_models);
        let mut b = rng.below(n_models - 1);
        if b >= a {
            b += 1;
        }
        let outcome = match rng.below(3) {
            0 => Outcome::WinA,
            1 => Outcome::WinB,
            _ => Outcome::Draw,
        };
        Observation::single(unit(rng), Comparison { a, b, outcome })
    }

    fn cadence(every: usize, ms: u64) -> EpochParams {
        EpochParams { publish_every: every, publish_interval_ms: ms }
    }

    #[test]
    fn cold_start_publishes_empty_epoch_zero() {
        let writer = RouterWriter::new(EagleParams::default(), 4, DIM, cadence(8, 10_000));
        let snap = writer.ring().load();
        assert_eq!(snap.epoch(), 0);
        assert_eq!(snap.store_len(), 0);
        assert_eq!(snap.scores(&vec![1.0; DIM]).len(), 4);
    }

    #[test]
    fn record_cadence_publishes_every_k() {
        let mut rng = Rng::new(1);
        let mut writer = RouterWriter::new(EagleParams::default(), 4, DIM, cadence(8, 10_000));
        let ring = writer.ring();
        for i in 0..7 {
            assert_eq!(writer.observe(rand_obs(&mut rng, 4)), None, "record {i}");
        }
        assert_eq!(ring.load().epoch(), 0, "no publish before K records");
        assert_eq!(writer.observe(rand_obs(&mut rng, 4)), Some(1));
        let snap = ring.load();
        assert_eq!(snap.epoch(), 1);
        assert_eq!(snap.history_len(), 8);
        assert_eq!(snap.store_len(), 8);
    }

    #[test]
    fn time_cadence_publishes_stale_records() {
        let mut rng = Rng::new(2);
        let mut writer = RouterWriter::new(EagleParams::default(), 4, DIM, cadence(1_000, 20));
        writer.observe(rand_obs(&mut rng, 4));
        assert_eq!(writer.ring().load().epoch(), 0);
        std::thread::sleep(Duration::from_millis(30));
        // next arrival trips the time threshold
        assert!(writer.observe(rand_obs(&mut rng, 4)).is_some());
        assert_eq!(writer.ring().load().history_len(), 2);
        // and maybe_publish with nothing pending is a no-op
        assert_eq!(writer.maybe_publish(), None);
    }

    #[test]
    fn snapshot_scores_equal_locked_router_exactly() {
        // the acceptance-criterion equivalence: same records => the
        // published snapshot scores bit-identically to a flat-store
        // EagleRouter over the same history prefix
        let mut rng = Rng::new(3);
        let params = EagleParams::default();
        let mut writer = RouterWriter::new(params.clone(), 5, DIM, cadence(10, 10_000));
        let mut reference = EagleRouter::new(params, 5, FlatStore::new(DIM));

        let ring = writer.ring();
        for step in 0..100 {
            let obs = rand_obs(&mut rng, 5);
            reference.observe(obs.clone());
            writer.observe(obs);
            if (step + 1) % 10 == 0 {
                let snap = ring.load();
                assert_eq!(snap.history_len(), step + 1);
                for _ in 0..3 {
                    let q = unit(&mut rng);
                    assert_eq!(
                        snap.scores(&q),
                        reference.combined_scores(&q),
                        "divergence at history {}",
                        step + 1
                    );
                }
            }
        }
    }

    #[test]
    fn score_batch_bit_identical_to_singles_on_flat_and_ivf_views() {
        let mut rng = Rng::new(41);
        let mut writer = RouterWriter::new(EagleParams::default(), 5, DIM, cadence(20, 10_000));
        writer.set_ivf(IvfPublishParams { publish_threshold: 80, n_cells: 6, nprobe: 3 });
        let ring = writer.ring();
        let mut saw_ivf = false;
        for step in 0..200 {
            writer.observe(rand_obs(&mut rng, 5));
            if (step + 1) % 40 == 0 {
                let snap = ring.load();
                saw_ivf |= matches!(snap.view(), SnapshotView::Ivf(_));
                let queries: Vec<Vec<f32>> = (0..9).map(|_| unit(&mut rng)).collect();
                let batch = snap.score_batch(&queries);
                assert_eq!(batch.len(), queries.len());
                for (q, scores) in queries.iter().zip(&batch) {
                    assert_eq!(scores, &snap.scores(q), "batch diverged at step {step}");
                }
            }
        }
        assert!(saw_ivf, "ivf view never exercised");
        // empty batch is fine
        assert!(ring.load().score_batch(&[]).is_empty());
    }

    #[test]
    fn old_snapshots_stay_consistent_after_more_publishes() {
        let mut rng = Rng::new(4);
        let mut writer = RouterWriter::new(EagleParams::default(), 4, DIM, cadence(5, 10_000));
        let ring = writer.ring();
        for _ in 0..5 {
            writer.observe(rand_obs(&mut rng, 4));
        }
        let pinned = ring.load();
        assert_eq!(pinned.epoch(), 1);
        let q = unit(&mut rng);
        let before = pinned.scores(&q);
        // 20 more epochs of churn, including segment merges
        for _ in 0..100 {
            writer.observe(rand_obs(&mut rng, 4));
        }
        assert_eq!(ring.load().epoch(), 21);
        assert_eq!(pinned.scores(&q), before, "pinned snapshot mutated");
        assert_eq!(pinned.history_len(), 5);
    }

    #[test]
    fn from_router_keeps_state() {
        let mut rng = Rng::new(5);
        let mut flat_router = EagleRouter::new(EagleParams::default(), 4, FlatStore::new(DIM));
        let mut probe_scores = Vec::new();
        for _ in 0..30 {
            flat_router.observe(rand_obs(&mut rng, 4));
        }
        let q = unit(&mut rng);
        probe_scores.push(flat_router.combined_scores(&q));
        let writer = RouterWriter::from_router(flat_router, cadence(8, 10_000));
        let snap = writer.ring().load();
        assert_eq!(snap.history_len(), 30);
        assert_eq!(snap.store_len(), 30);
        assert_eq!(snap.scores(&q), probe_scores[0]);
    }

    #[test]
    fn ivf_view_snapshot_scores() {
        use crate::vectordb::ivf::{IvfIndex, IvfParams};
        use crate::vectordb::VectorIndex;

        let mut rng = Rng::new(6);
        let params = EagleParams::default();
        let mut flat_router = EagleRouter::new(params.clone(), 4, FlatStore::new(DIM));
        let params_ivf = IvfParams { n_cells: 4, nprobe: 4, kmeans_iters: 3, seed: 9 };
        let mut core = IvfIndex::new(DIM, params_ivf);
        let mut tail = SegmentStore::new(DIM);
        for i in 0..120 {
            let obs = rand_obs(&mut rng, 4);
            let fb = Feedback { comparisons: obs.comparisons.clone() };
            if i < 100 {
                core.add(&obs.embedding, fb);
            } else {
                tail.add(&obs.embedding, fb);
            }
            flat_router.observe(obs);
        }
        let snap = RouterSnapshot::with_view(
            7,
            params,
            flat_router.global().ratings(),
            flat_router.feedback_len(),
            SnapshotView::Ivf(IvfView::new(Arc::new(core), tail.freeze())),
        );
        assert_eq!(snap.epoch(), 7);
        assert_eq!(snap.store_len(), 120);
        // exhaustive probe (nprobe == n_cells) => identical scores
        let q = unit(&mut rng);
        assert_eq!(snap.scores(&q), flat_router.combined_scores(&q));
    }

    #[test]
    fn ivf_publish_engages_past_threshold_and_scores_exactly() {
        // exhaustive probe (nprobe == n_cells): the published IVF view
        // must score bit-identically to the flat reference at every epoch
        let mut rng = Rng::new(31);
        let params = EagleParams::default();
        let mut writer = RouterWriter::new(params.clone(), 5, DIM, cadence(25, 10_000));
        writer.set_ivf(IvfPublishParams { publish_threshold: 60, n_cells: 8, nprobe: 8 });
        let mut reference = EagleRouter::new(params, 5, FlatStore::new(DIM));
        let ring = writer.ring();
        let mut saw_flat = false;
        let mut saw_ivf = false;
        for step in 0..300 {
            let obs = rand_obs(&mut rng, 5);
            reference.observe(obs.clone());
            writer.observe(obs);
            if (step + 1) % 25 == 0 {
                let snap = ring.load();
                match snap.view() {
                    SnapshotView::Flat(_) => {
                        saw_flat = true;
                        assert!(snap.store_len() < 60, "flat view past threshold");
                    }
                    SnapshotView::Ivf(v) => {
                        saw_ivf = true;
                        assert!(snap.store_len() >= 60);
                        assert_eq!(v.core_len() + v.tail_len(), snap.store_len());
                    }
                    SnapshotView::Quant(_) => unreachable!("quant policy not enabled here"),
                }
                for _ in 0..2 {
                    let q = unit(&mut rng);
                    assert_eq!(
                        snap.scores(&q),
                        reference.combined_scores(&q),
                        "ivf-published snapshot diverged at step {step}"
                    );
                }
            }
        }
        assert!(saw_flat && saw_ivf, "both view kinds must be exercised");
        let (core, tail) = writer.ivf_core_tail_len();
        assert!(core >= 60 && core + tail == 300);
    }

    #[test]
    fn ivf_compaction_resets_tail_and_keeps_old_snapshots_valid() {
        let mut rng = Rng::new(32);
        let mut writer = RouterWriter::new(EagleParams::default(), 4, DIM, cadence(10, 10_000));
        writer.set_ivf(IvfPublishParams { publish_threshold: 40, n_cells: 4, nprobe: 4 });
        for _ in 0..50 {
            writer.observe(rand_obs(&mut rng, 4));
        }
        let pinned = ring_snapshot(&writer);
        let q = unit(&mut rng);
        let before = pinned.scores(&q);
        // enough churn to force several core rebuilds (tail >= core/2)
        for _ in 0..400 {
            writer.observe(rand_obs(&mut rng, 4));
        }
        let (core, tail) = writer.ivf_core_tail_len();
        assert!(core > 50, "core never rebuilt (len {core})");
        assert!(
            (tail as f64) < core as f64 * 0.75,
            "tail ({tail}) outgrew its compaction budget (core {core})"
        );
        // the pinned pre-compaction snapshot still scores identically
        assert_eq!(pinned.scores(&q), before, "pinned snapshot mutated by rebuild");
        // disabling the policy falls back to flat publishes
        writer.set_ivf(IvfPublishParams { publish_threshold: 0, n_cells: 0, nprobe: 0 });
        writer.observe(rand_obs(&mut rng, 4));
        writer.publish();
        assert!(matches!(ring_snapshot(&writer).view(), SnapshotView::Flat(_)));
    }

    fn ring_snapshot(writer: &RouterWriter) -> Arc<RouterSnapshot> {
        writer.ring().load()
    }

    #[test]
    fn rcu_cell_publish_load_roundtrip() {
        let cell = RcuCell::with_slots(Arc::new(0u64), 4);
        assert_eq!(*cell.load(), 0);
        for v in 1..=10u64 {
            cell.publish(Arc::new(v));
            assert_eq!(*cell.load(), v, "cell lost publish {v}");
        }
        // old Arcs pinned by readers stay valid across wraps
        let pinned = cell.load();
        for v in 11..=20u64 {
            cell.publish(Arc::new(v));
        }
        assert_eq!(*pinned, 10);
        assert_eq!(*cell.load(), 20);
    }

    #[test]
    fn ring_is_a_router_over_the_current_snapshot() {
        let mut rng = Rng::new(21);
        let mut writer = RouterWriter::new(EagleParams::default(), 4, DIM, cadence(1, 10_000));
        let ring = writer.ring();
        let q = unit(&mut rng);
        assert_eq!(Router::scores(&*ring, &q), ring.load().scores(&q));
        writer.observe(rand_obs(&mut rng, 4));
        assert_eq!(ring.name(), "eagle-snapshot");
        assert_eq!(Router::scores(&*ring, &q), ring.load().scores(&q));
        assert_eq!(ring.load().epoch(), 1);
    }

    #[test]
    fn apply_defers_publication_until_driven() {
        let mut rng = Rng::new(22);
        let mut writer = RouterWriter::new(EagleParams::default(), 4, DIM, cadence(2, 10_000));
        writer.apply(rand_obs(&mut rng, 4));
        writer.apply(rand_obs(&mut rng, 4));
        writer.apply(rand_obs(&mut rng, 4));
        // cadence tripped but apply never publishes by itself
        assert!(writer.publish_due());
        assert_eq!(writer.ring().load().epoch(), 0);
        assert_eq!(writer.maybe_publish(), Some(1));
        assert_eq!(writer.ring().load().history_len(), 3);
        assert!(!writer.publish_due());
    }

    #[test]
    fn quant_publish_with_full_rerank_scores_exactly() {
        // rerank_factor large enough that rerank covers the whole corpus
        // (n_neighbors * factor >= n): the quantized scan only *selects*
        // candidates, the exact kernel rescores all of them, so scores
        // must be bit-identical to the flat reference at every epoch —
        // including epochs where big sealed segments really are quantized.
        let mut rng = Rng::new(51);
        let params = EagleParams::default();
        let mut writer = RouterWriter::new(params.clone(), 5, DIM, cadence(25, 10_000));
        writer.set_quant(QuantParams { enable: true, rerank_factor: 64 });
        let mut reference = EagleRouter::new(params, 5, FlatStore::new(DIM));
        let ring = writer.ring();
        let mut max_quantized = 0usize;
        for step in 0..600 {
            let obs = rand_obs(&mut rng, 5);
            reference.observe(obs.clone());
            writer.observe(obs);
            if (step + 1) % 50 == 0 {
                let snap = ring.load();
                match snap.view() {
                    SnapshotView::Quant(v) => max_quantized = max_quantized.max(v.quantized_rows()),
                    other => panic!("expected quant view, got {other:?}"),
                }
                for _ in 0..2 {
                    let q = unit(&mut rng);
                    assert_eq!(
                        snap.scores(&q),
                        reference.combined_scores(&q),
                        "quant-published snapshot diverged at step {step}"
                    );
                }
            }
        }
        // binary-counter merging in units of publish_every (25) tops out
        // at a 16x25 = 400-row segment by step 600 — past the 256-row
        // quantization floor, so real sidecars must have been exercised
        assert!(
            max_quantized >= 400,
            "no large segment ever quantized (max coverage {max_quantized})"
        );
        // disabling the policy reverts to plain flat publishes
        writer.set_quant(QuantParams { enable: false, rerank_factor: 4 });
        assert!(writer.quant_params().is_none());
        writer.observe(rand_obs(&mut rng, 5));
        writer.publish();
        assert!(matches!(ring.load().view(), SnapshotView::Flat(_)));
    }

    #[test]
    fn ivf_auto_cells_resolve_clamp_and_supersede_quant() {
        // n_cells = 0 (auto) resolves to ceil(sqrt(corpus)) at rebuild
        // time; the oversized nprobe clamps to the resolved count, which
        // makes every probe exhaustive => bit-identical to the flat
        // reference. Quantization is enabled too and must be superseded
        // past the IVF threshold.
        let mut rng = Rng::new(52);
        let params = EagleParams::default();
        let mut writer = RouterWriter::new(params.clone(), 4, DIM, cadence(20, 10_000));
        writer.set_ivf(IvfPublishParams { publish_threshold: 60, n_cells: 0, nprobe: 10_000 });
        writer.set_quant(QuantParams { enable: true, rerank_factor: 64 });
        let mut reference = EagleRouter::new(params, 4, FlatStore::new(DIM));
        let ring = writer.ring();
        let mut saw_quant = false;
        let mut saw_ivf = false;
        for step in 0..240 {
            let obs = rand_obs(&mut rng, 4);
            reference.observe(obs.clone());
            writer.observe(obs);
            if (step + 1) % 20 == 0 {
                let snap = ring.load();
                match snap.view() {
                    SnapshotView::Quant(_) => {
                        saw_quant = true;
                        assert!(snap.store_len() < 60, "quant view past ivf threshold");
                    }
                    SnapshotView::Ivf(_) => saw_ivf = true,
                    SnapshotView::Flat(_) => panic!("flat view with quant policy on"),
                }
                let q = unit(&mut rng);
                assert_eq!(
                    snap.scores(&q),
                    reference.combined_scores(&q),
                    "auto-cells snapshot diverged at step {step}"
                );
            }
        }
        assert!(saw_quant && saw_ivf, "both publication modes must be exercised");
        let resolved = writer.ivf_resolved_cells();
        let (core, _) = writer.ivf_core_tail_len();
        assert!(resolved > 0, "auto n_cells never resolved");
        assert_eq!(
            resolved,
            (core as f64).sqrt().ceil() as usize,
            "resolved cells != ceil(sqrt(core size {core}))"
        );
    }

    #[test]
    fn ring_survives_many_wraps() {
        let mut rng = Rng::new(7);
        let mut writer = RouterWriter::new(EagleParams::default(), 3, DIM, cadence(1, 10_000));
        let ring = writer.ring();
        // 3 full ring revolutions of publishes
        for i in 0..(3 * RING_SLOTS) {
            writer.observe(rand_obs(&mut rng, 3));
            assert_eq!(ring.load().epoch(), (i + 1) as u64);
        }
    }
}
