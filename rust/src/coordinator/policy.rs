//! Routing policies: how router scores plus model costs become one
//! routing decision.
//!
//! The paper's policy (§2) is "select the highest-ranked model that falls
//! within the user's specified budget" — [`PolicySpec::Budget`]. The
//! related work frames routing as a cost/quality Pareto problem, so the
//! policy layer is first-class here: a [`RoutePolicy`] is built from the
//! registry (costs, [`CostCurve`]s, availability) and evaluates a
//! per-query [`PolicySpec`]:
//!
//! - **Budget** — maximize score s.t. flat expected cost <= budget
//!   (paper §2; the default, and bit-identical to the pre-policy-layer
//!   behavior).
//! - **CostAware** — maximize score s.t. *expected spend on this query*
//!   <= budget, where spend comes from the per-model [`CostCurve`] at the
//!   query's estimated prompt volume — long prompts price differently
//!   across models (RouterBench's cost model).
//! - **Threshold** — RouteLLM-style calibrated threshold: route to the
//!   strongest available model iff its win probability over the cheapest
//!   one clears `threshold`; [`RoutePolicy::calibrate_threshold`] picks
//!   the threshold that hits a target strong-model fraction on a sample
//!   of score vectors.
//!
//! If nothing is affordable the policy falls back to the cheapest
//! available model — a serving system must answer every request.

use super::registry::{CostCurve, ModelRegistry};

/// A per-query policy choice. `Copy`, so the server's co-batched route
/// path threads it through [`RoutePolicy::select_spec`] allocation-free.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicySpec {
    /// Maximize score subject to flat expected cost <= budget (paper §2).
    Budget { budget: f64 },
    /// Maximize score subject to curve-priced expected spend <= budget.
    CostAware { budget: f64 },
    /// Strong model iff its win probability over the cheap model clears
    /// the threshold (RouteLLM).
    Threshold { threshold: f64 },
}

impl PolicySpec {
    /// Unconstrained default: every model is affordable.
    pub fn unbounded() -> PolicySpec {
        PolicySpec::Budget { budget: f64::INFINITY }
    }

    /// Parse a named mode + knobs (wire protocol, `[policy]` config).
    /// `budget <= 0` means unconstrained.
    pub fn from_mode(mode: &str, budget: f64, threshold: f64) -> Result<PolicySpec, String> {
        let budget = if budget > 0.0 { budget } else { f64::INFINITY };
        match mode {
            "budget" => Ok(PolicySpec::Budget { budget }),
            "cost_aware" => Ok(PolicySpec::CostAware { budget }),
            "threshold" => {
                if !(0.0..=1.0).contains(&threshold) {
                    return Err(format!("threshold {threshold} not in [0,1]"));
                }
                Ok(PolicySpec::Threshold { threshold })
            }
            other => Err(format!(
                "unknown policy '{other}' (expected budget, cost_aware or threshold)"
            )),
        }
    }

    /// The wire/config name of this spec's mode.
    pub fn mode(&self) -> &'static str {
        match self {
            PolicySpec::Budget { .. } => "budget",
            PolicySpec::CostAware { .. } => "cost_aware",
            PolicySpec::Threshold { .. } => "threshold",
        }
    }
}

/// Rough prompt-token estimate for cost curves: whitespace words scaled
/// by the usual ~4/3 tokens-per-word. Allocation-free — it rides the
/// batched route hot path.
pub fn approx_tokens(text: &str) -> f64 {
    (text.split_whitespace().count() as f64 * 4.0 / 3.0).max(1.0)
}

/// Cost-aware selection over router scores: registry-derived costs,
/// cost curves and availability, evaluated against a per-query
/// [`PolicySpec`].
#[derive(Debug, Clone)]
pub struct RoutePolicy {
    costs: Vec<f64>,
    curves: Vec<CostCurve>,
    available: Vec<bool>,
}

impl RoutePolicy {
    pub fn new(registry: &ModelRegistry) -> Self {
        RoutePolicy {
            costs: registry.costs(),
            curves: registry.cost_curves(),
            available: registry.entries().iter().map(|e| e.available).collect(),
        }
    }

    /// Selection from explicit flat costs (tests, ablations).
    pub fn from_costs(costs: Vec<f64>) -> Self {
        let available = vec![true; costs.len()];
        let curves = costs.iter().map(|&c| CostCurve::flat(c)).collect();
        RoutePolicy { costs, curves, available }
    }

    pub fn n_models(&self) -> usize {
        self.costs.len()
    }

    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Mirror a registry availability change (operator drain).
    pub fn set_available(&mut self, model: usize, available: bool) {
        self.available[model] = available;
    }

    /// Highest-scoring model with expected cost <= budget; falls back to
    /// the cheapest available model when nothing is affordable. This is
    /// `select_spec` with [`PolicySpec::Budget`] — the paper's policy.
    pub fn select(&self, scores: &[f64], budget: f64) -> usize {
        self.select_spec(scores, PolicySpec::Budget { budget }, 0.0)
    }

    /// Evaluate one policy spec against one score vector.
    /// `prompt_tokens` is the query's estimated prompt volume (only the
    /// cost-aware mode reads it; pass 0.0 when unknown).
    pub fn select_spec(&self, scores: &[f64], spec: PolicySpec, prompt_tokens: f64) -> usize {
        debug_assert_eq!(scores.len(), self.costs.len());
        match spec {
            PolicySpec::Budget { budget } => {
                self.select_constrained(scores, budget, |m| self.costs[m])
            }
            PolicySpec::CostAware { budget } => {
                self.select_constrained(scores, budget, |m| self.curves[m].cost(prompt_tokens))
            }
            PolicySpec::Threshold { threshold } => self.select_threshold(scores, threshold),
        }
    }

    /// Shared affordability scan: maximize score over available models
    /// whose `cost_of(m) <= budget`, tie-breaking toward the cheaper
    /// model (same quality for less).
    fn select_constrained<F: Fn(usize) -> f64>(
        &self,
        scores: &[f64],
        budget: f64,
        cost_of: F,
    ) -> usize {
        let mut best: Option<usize> = None;
        for m in 0..self.costs.len() {
            if !self.available[m] || cost_of(m) > budget {
                continue;
            }
            match best {
                None => best = Some(m),
                Some(b) => {
                    if scores[m] > scores[b]
                        || (scores[m] == scores[b] && cost_of(m) < cost_of(b))
                    {
                        best = Some(m);
                    }
                }
            }
        }
        best.unwrap_or_else(|| self.cheapest())
    }

    /// RouteLLM-style strong/weak routing: strong = the most expensive
    /// available model, weak = the cheapest; route strong iff its ELO win
    /// probability over weak clears the threshold. With one available
    /// model (or a drained registry) this degrades like everything else.
    fn select_threshold(&self, scores: &[f64], threshold: f64) -> usize {
        let (Some(strong), Some(weak)) = (self.strongest_available(), self.cheapest_checked())
        else {
            return self.cheapest();
        };
        if strong == weak {
            return strong;
        }
        if Self::win_prob(scores[strong], scores[weak]) >= threshold {
            strong
        } else {
            weak
        }
    }

    /// ELO win probability of `a` over `b` (logistic, 400-point scale —
    /// the same curve the rating engine uses).
    pub fn win_prob(score_a: f64, score_b: f64) -> f64 {
        1.0 / (1.0 + 10f64.powf((score_b - score_a) / 400.0))
    }

    /// Strong-arm candidate for the threshold mode: the most expensive
    /// available model (price tracks capability in every pool we model).
    fn strongest_available(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for m in 0..self.costs.len() {
            if !self.available[m] {
                continue;
            }
            best = match best {
                None => Some(m),
                Some(b) if self.costs[m] > self.costs[b] => Some(m),
                keep => keep,
            };
        }
        best
    }

    fn cheapest_checked(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for m in 0..self.costs.len() {
            if !self.available[m] {
                continue;
            }
            best = match best {
                None => Some(m),
                Some(b) if self.costs[m] < self.costs[b] => Some(m),
                keep => keep,
            };
        }
        best
    }

    /// Calibrate a threshold hitting a target strong-model fraction on a
    /// sample of score vectors (RouteLLM calibrates against a traffic
    /// sample the same way). `target_strong_frac` in [0,1]; returns a
    /// threshold such that about that fraction of the sample routes to
    /// the strong model. Deterministic in the sample order-insensitively.
    pub fn calibrate_threshold(&self, score_sample: &[Vec<f64>], target_strong_frac: f64) -> f64 {
        let (Some(strong), Some(weak)) = (self.strongest_available(), self.cheapest_checked())
        else {
            return 1.0;
        };
        if strong == weak || score_sample.is_empty() {
            return 1.0;
        }
        let mut probs: Vec<f64> = score_sample
            .iter()
            .map(|s| Self::win_prob(s[strong], s[weak]))
            .collect();
        // descending: probs[k-1] is the k-th most strong-leaning query
        probs.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let frac = target_strong_frac.clamp(0.0, 1.0);
        let k = (frac * probs.len() as f64).round() as usize;
        if k == 0 {
            // route nothing strong: a threshold just above the max prob
            return (probs[0] + 1e-9).min(1.0);
        }
        probs[k.min(probs.len()) - 1]
    }

    /// Cheapest available model index. When every model is drained this
    /// degrades to the globally cheapest model instead of panicking: a
    /// registry with all models marked unavailable is an operational state
    /// (rolling restart, mass drain), not a programming error, and `select`
    /// sits on the request path — unwinding here would kill a serving
    /// thread. The caller still gets a valid index; the drained model's
    /// backend surfaces its own error if it truly cannot serve.
    pub fn cheapest(&self) -> usize {
        let mut best: Option<usize> = None;
        let mut best_any: Option<usize> = None;
        for m in 0..self.costs.len() {
            let better = |cur: Option<usize>| match cur {
                None => true,
                Some(b) => self.costs[m] < self.costs[b],
            };
            if better(best_any) {
                best_any = Some(m);
            }
            if self.available[m] && better(best) {
                best = Some(m);
            }
        }
        best.or(best_any).unwrap_or(0)
    }

    /// A willingness-to-pay sweep covering the full cost range: one level
    /// just below each distinct model cost, each exact cost, and one above
    /// the max — the x-axis of Fig 2a.
    pub fn budget_sweep(&self) -> Vec<f64> {
        let mut costs: Vec<f64> = self.costs.clone();
        costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        costs.dedup();
        let mut levels = Vec::with_capacity(costs.len() * 2 + 1);
        for &c in &costs {
            // additive epsilon: a multiplicative one collapses at c == 0.0
            // (0.999 * 0 == 0), so a free tier would never be excluded
            let eps = (c.abs() * 1e-3).max(1e-9);
            levels.push(c - eps); // just below: excludes this tier
            levels.push(c + eps); // just above: includes it
        }
        let last = *costs.last().unwrap();
        levels.push(last + (last.abs() * 0.5).max(1.0));
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn policy() -> RoutePolicy {
        RoutePolicy::from_costs(vec![10.0, 1.0, 5.0])
    }

    #[test]
    fn picks_best_affordable() {
        let p = policy();
        // scores favor model 0 but it costs 10
        let scores = vec![3.0, 1.0, 2.0];
        assert_eq!(p.select(&scores, 20.0), 0);
        assert_eq!(p.select(&scores, 6.0), 2);
        assert_eq!(p.select(&scores, 2.0), 1);
    }

    #[test]
    fn unaffordable_falls_back_to_cheapest() {
        let p = policy();
        assert_eq!(p.select(&[1.0, 2.0, 3.0], 0.1), 1);
    }

    #[test]
    fn tie_breaks_to_cheaper() {
        let p = RoutePolicy::from_costs(vec![10.0, 1.0]);
        assert_eq!(p.select(&[2.0, 2.0], 20.0), 1);
    }

    #[test]
    fn drained_model_never_selected() {
        let mut p = policy();
        p.available[0] = false;
        assert_eq!(p.select(&[9.0, 1.0, 2.0], 100.0), 2);
    }

    #[test]
    fn all_models_drained_degrades_without_panicking() {
        // regression: cheapest() used to .expect() on the request path, so
        // a fully drained registry unwound the serving thread
        let mut p = policy();
        for a in p.available.iter_mut() {
            *a = false;
        }
        let pick = p.select(&[1.0, 2.0, 3.0], 100.0);
        assert_eq!(pick, 1, "degrades to the globally cheapest model");
        assert_eq!(p.cheapest(), 1);
        // every spec degrades the same way
        for spec in [
            PolicySpec::Budget { budget: 100.0 },
            PolicySpec::CostAware { budget: 100.0 },
            PolicySpec::Threshold { threshold: 0.5 },
        ] {
            assert_eq!(p.select_spec(&[1.0, 2.0, 3.0], spec, 0.0), 1, "{spec:?}");
        }
    }

    #[test]
    fn budget_spec_matches_legacy_select_bit_identically() {
        // the Budget spec IS the old flat policy: same picks at any
        // budget, including unaffordable fallbacks
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..200 {
            let n = 2 + rng.below(8);
            let costs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 3.0)).collect();
            let p = RoutePolicy::from_costs(costs);
            let b = rng.range_f64(0.0, 12.0);
            assert_eq!(
                p.select(&scores, b),
                p.select_spec(&scores, PolicySpec::Budget { budget: b }, 0.0)
            );
        }
    }

    #[test]
    fn cost_aware_prices_long_prompts_out() {
        // model 0: cheap base but steep per-token; model 1: flat
        let p = RoutePolicy {
            costs: vec![0.01, 0.02],
            curves: vec![
                CostCurve { base: 0.0, per_token: 1e-4, mean_tokens: 100.0 },
                CostCurve::flat(0.02),
            ],
            available: vec![true, true],
        };
        let scores = vec![2.0, 1.0]; // favors model 0
        let budget = 0.025;
        // short prompt: model 0 costs 0.01+, affordable, wins on score
        assert_eq!(p.select_spec(&scores, PolicySpec::CostAware { budget }, 10.0), 0);
        // long prompt: model 0's spend (0.0001 * 1100 = 0.11) blows the
        // budget; the flat model is all that's affordable
        assert_eq!(p.select_spec(&scores, PolicySpec::CostAware { budget }, 1000.0), 1);
        // the flat Budget spec ignores prompt volume entirely
        assert_eq!(p.select_spec(&scores, PolicySpec::Budget { budget }, 1000.0), 0);
    }

    #[test]
    fn threshold_routes_strong_only_on_confident_wins() {
        let p = RoutePolicy::from_costs(vec![10.0, 1.0]); // 0 strong, 1 weak
        // equal scores: win prob 0.5
        assert_eq!(
            p.select_spec(&[1000.0, 1000.0], PolicySpec::Threshold { threshold: 0.6 }, 0.0),
            1
        );
        // strong up 200 ELO: win prob ~0.76
        assert_eq!(
            p.select_spec(&[1200.0, 1000.0], PolicySpec::Threshold { threshold: 0.6 }, 0.0),
            0
        );
        // ultra-conservative threshold keeps it weak
        assert_eq!(
            p.select_spec(&[1200.0, 1000.0], PolicySpec::Threshold { threshold: 0.99 }, 0.0),
            1
        );
        assert!((RoutePolicy::win_prob(1000.0, 1000.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn calibrated_threshold_hits_target_fraction() {
        let p = RoutePolicy::from_costs(vec![10.0, 1.0]);
        let mut rng = crate::util::Rng::new(11);
        let sample: Vec<Vec<f64>> = (0..400)
            .map(|_| vec![rng.range_f64(900.0, 1300.0), 1000.0])
            .collect();
        for target in [0.1, 0.25, 0.5, 0.9] {
            let tau = p.calibrate_threshold(&sample, target);
            let spec = PolicySpec::Threshold { threshold: tau };
            let strong = sample
                .iter()
                .filter(|s| p.select_spec(s, spec, 0.0) == 0)
                .count() as f64
                / sample.len() as f64;
            assert!(
                (strong - target).abs() <= 0.02,
                "target {target}: routed {strong} strong at tau {tau}"
            );
        }
        // degenerate targets
        let tau0 = p.calibrate_threshold(&sample, 0.0);
        let spec0 = PolicySpec::Threshold { threshold: tau0 };
        assert!(sample.iter().all(|s| p.select_spec(s, spec0, 0.0) == 1));
    }

    #[test]
    fn spec_from_mode_parses_and_validates() {
        assert_eq!(
            PolicySpec::from_mode("budget", 0.5, 0.0).unwrap(),
            PolicySpec::Budget { budget: 0.5 }
        );
        assert_eq!(
            PolicySpec::from_mode("budget", 0.0, 0.0).unwrap(),
            PolicySpec::Budget { budget: f64::INFINITY }
        );
        assert_eq!(
            PolicySpec::from_mode("cost_aware", 1.0, 0.0).unwrap(),
            PolicySpec::CostAware { budget: 1.0 }
        );
        assert_eq!(
            PolicySpec::from_mode("threshold", 0.0, 0.7).unwrap(),
            PolicySpec::Threshold { threshold: 0.7 }
        );
        assert!(PolicySpec::from_mode("threshold", 0.0, 1.5).is_err());
        assert!(PolicySpec::from_mode("nope", 0.0, 0.0).is_err());
        assert_eq!(PolicySpec::unbounded().mode(), "budget");
    }

    #[test]
    fn approx_tokens_tracks_length() {
        assert_eq!(approx_tokens(""), 1.0);
        assert!(approx_tokens("one two three four") > approx_tokens("one two"));
    }

    #[test]
    fn zero_cost_models_get_distinct_sweep_levels() {
        // regression: c * 0.999 == c at c == 0.0, so a free tier was never
        // excluded by its "just below" level
        let p = RoutePolicy::from_costs(vec![0.0, 1.0]);
        let sweep = p.budget_sweep();
        assert!(
            sweep.iter().any(|&b| b < 0.0),
            "no level excludes the free tier: {sweep:?}"
        );
        assert!(sweep.iter().any(|&b| b >= 0.0 && b < 1.0));
        for w in sweep.windows(2) {
            assert!(w[0] <= w[1]);
        }

        // an all-free registry still produces a non-collapsed sweep
        let free = RoutePolicy::from_costs(vec![0.0, 0.0]);
        let sweep = free.budget_sweep();
        let mut distinct = sweep.clone();
        distinct.dedup();
        assert!(distinct.len() >= 3, "collapsed sweep: {sweep:?}");
        assert!(sweep.last().unwrap() > &0.0);
    }

    #[test]
    fn sweep_covers_all_tiers() {
        let p = policy();
        let sweep = p.budget_sweep();
        // every model becomes affordable at some sweep level
        for (m, &c) in p.costs().iter().enumerate() {
            assert!(sweep.iter().any(|&b| b >= c), "model {m} never affordable");
        }
        // the lowest level excludes everything but the fallback
        assert!(sweep[0] < p.costs().iter().cloned().fold(f64::MAX, f64::min));
        // sorted
        for w in sweep.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn budget_monotonicity() {
        // larger budgets never select a *lower-scoring* model
        prop::check("budget monotone", 200, |rng| {
            let n = 2 + rng.below(8);
            let costs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 10.0)).collect();
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let p = RoutePolicy::from_costs(costs);
            let b1 = rng.range_f64(0.0, 12.0);
            let b2 = b1 + rng.range_f64(0.0, 5.0);
            let s1 = scores[p.select(&scores, b1)];
            let s2 = scores[p.select(&scores, b2)];
            // fallback cases can violate score order only when b1 affords nothing
            let affordable1 = p.costs().iter().any(|&c| c <= b1);
            if affordable1 {
                prop::assert_prop(s2 >= s1 - 1e-12, "score decreased with budget")
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn cost_aware_budget_monotonicity() {
        // the monotonicity invariant holds for curve-priced selection too,
        // at any fixed prompt volume
        prop::check("cost-aware monotone", 200, |rng| {
            let n = 2 + rng.below(8);
            let p = RoutePolicy {
                costs: (0..n).map(|_| rng.range_f64(0.1, 10.0)).collect(),
                curves: (0..n)
                    .map(|_| CostCurve {
                        base: rng.range_f64(0.0, 0.5),
                        per_token: rng.range_f64(0.0, 1e-3),
                        mean_tokens: rng.range_f64(100.0, 1000.0),
                    })
                    .collect(),
                available: vec![true; n],
            };
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let tokens = rng.range_f64(0.0, 2000.0);
            let b1 = rng.range_f64(0.0, 2.0);
            let b2 = b1 + rng.range_f64(0.0, 2.0);
            let s1 = scores[p.select_spec(&scores, PolicySpec::CostAware { budget: b1 }, tokens)];
            let s2 = scores[p.select_spec(&scores, PolicySpec::CostAware { budget: b2 }, tokens)];
            let affordable1 = (0..n).any(|m| p.curves[m].cost(tokens) <= b1);
            if affordable1 {
                prop::assert_prop(s2 >= s1 - 1e-12, "score decreased with budget")
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sweep_distinctness_with_zero_cost_tiers_property() {
        // budget_sweep must give every distinct cost tier an excluding and
        // an including level, even when free (0-cost) tiers are present
        prop::check("sweep distinctness", 200, |rng| {
            let n = 1 + rng.below(8);
            let mut costs: Vec<f64> = (0..n)
                .map(|_| if rng.chance(0.3) { 0.0 } else { rng.range_f64(0.0, 5.0) })
                .collect();
            let p = RoutePolicy::from_costs(costs.clone());
            let sweep = p.budget_sweep();
            costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            costs.dedup();
            for &c in &costs {
                prop::assert_prop(
                    sweep.iter().any(|&b| b < c),
                    "no level excludes a tier",
                )?;
                prop::assert_prop(
                    sweep.iter().any(|&b| b >= c),
                    "no level includes a tier",
                )?;
            }
            prop::assert_prop(
                sweep.windows(2).all(|w| w[0] <= w[1]),
                "sweep not sorted",
            )?;
            prop::assert_prop(
                sweep.last().unwrap() > costs.last().unwrap(),
                "no level above the max tier",
            )
        });
    }

    #[test]
    fn drained_registry_degradation_property() {
        // regression net for PR 6's cheapest() fix: under any availability
        // mask (including all-drained) every spec returns a valid index
        // and never picks a drained model while any model is available
        prop::check("drained degradation", 300, |rng| {
            let n = 1 + rng.below(8);
            let mut p = RoutePolicy::from_costs((0..n).map(|_| rng.range_f64(0.0, 10.0)).collect());
            for m in 0..n {
                p.available[m] = rng.chance(0.5);
            }
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 2000.0)).collect();
            let any_available = p.available.iter().any(|&a| a);
            let specs = [
                PolicySpec::Budget { budget: rng.range_f64(0.0, 12.0) },
                PolicySpec::CostAware { budget: rng.range_f64(0.0, 12.0) },
                PolicySpec::Threshold { threshold: rng.f64() },
            ];
            for spec in specs {
                let pick = p.select_spec(&scores, spec, rng.range_f64(0.0, 500.0));
                prop::assert_prop(pick < n, "index out of range")?;
                if any_available {
                    prop::assert_prop(
                        p.available[pick],
                        "picked a drained model while others were available",
                    )?;
                }
            }
            Ok(())
        });
    }
}
