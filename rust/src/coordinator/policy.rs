//! Budget policy: "select the highest-ranked model that falls within the
//! user's specified budget" (paper §2).
//!
//! The budget is a willingness-to-pay in $ per query, compared against each
//! model's *expected* per-query cost from the registry. If nothing is
//! affordable the policy falls back to the cheapest available model — a
//! serving system must answer every request.

use super::registry::ModelRegistry;

/// Budget-constrained selection over router scores.
#[derive(Debug, Clone)]
pub struct BudgetPolicy {
    costs: Vec<f64>,
    available: Vec<bool>,
}

impl BudgetPolicy {
    pub fn new(registry: &ModelRegistry) -> Self {
        BudgetPolicy {
            costs: registry.costs(),
            available: registry.entries().iter().map(|e| e.available).collect(),
        }
    }

    /// Selection from explicit costs (tests, ablations).
    pub fn from_costs(costs: Vec<f64>) -> Self {
        let available = vec![true; costs.len()];
        BudgetPolicy { costs, available }
    }

    pub fn n_models(&self) -> usize {
        self.costs.len()
    }

    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Highest-scoring model with expected cost <= budget; falls back to
    /// the cheapest available model when nothing is affordable.
    pub fn select(&self, scores: &[f64], budget: f64) -> usize {
        debug_assert_eq!(scores.len(), self.costs.len());
        let mut best: Option<usize> = None;
        for m in 0..self.costs.len() {
            if !self.available[m] || self.costs[m] > budget {
                continue;
            }
            match best {
                None => best = Some(m),
                Some(b) => {
                    // tie-break toward the cheaper model (same quality for less)
                    if scores[m] > scores[b]
                        || (scores[m] == scores[b] && self.costs[m] < self.costs[b])
                    {
                        best = Some(m);
                    }
                }
            }
        }
        best.unwrap_or_else(|| self.cheapest())
    }

    /// Cheapest available model index. When every model is drained this
    /// degrades to the globally cheapest model instead of panicking: a
    /// registry with all models marked unavailable is an operational state
    /// (rolling restart, mass drain), not a programming error, and `select`
    /// sits on the request path — unwinding here would kill a serving
    /// thread. The caller still gets a valid index; the drained model's
    /// backend surfaces its own error if it truly cannot serve.
    pub fn cheapest(&self) -> usize {
        let mut best: Option<usize> = None;
        let mut best_any: Option<usize> = None;
        for m in 0..self.costs.len() {
            let better = |cur: Option<usize>| match cur {
                None => true,
                Some(b) => self.costs[m] < self.costs[b],
            };
            if better(best_any) {
                best_any = Some(m);
            }
            if self.available[m] && better(best) {
                best = Some(m);
            }
        }
        best.or(best_any).unwrap_or(0)
    }

    /// A willingness-to-pay sweep covering the full cost range: one level
    /// just below each distinct model cost, each exact cost, and one above
    /// the max — the x-axis of Fig 2a.
    pub fn budget_sweep(&self) -> Vec<f64> {
        let mut costs: Vec<f64> = self.costs.clone();
        costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        costs.dedup();
        let mut levels = Vec::with_capacity(costs.len() * 2 + 1);
        for &c in &costs {
            // additive epsilon: a multiplicative one collapses at c == 0.0
            // (0.999 * 0 == 0), so a free tier would never be excluded
            let eps = (c.abs() * 1e-3).max(1e-9);
            levels.push(c - eps); // just below: excludes this tier
            levels.push(c + eps); // just above: includes it
        }
        let last = *costs.last().unwrap();
        levels.push(last + (last.abs() * 0.5).max(1.0));
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn policy() -> BudgetPolicy {
        BudgetPolicy::from_costs(vec![10.0, 1.0, 5.0])
    }

    #[test]
    fn picks_best_affordable() {
        let p = policy();
        // scores favor model 0 but it costs 10
        let scores = vec![3.0, 1.0, 2.0];
        assert_eq!(p.select(&scores, 20.0), 0);
        assert_eq!(p.select(&scores, 6.0), 2);
        assert_eq!(p.select(&scores, 2.0), 1);
    }

    #[test]
    fn unaffordable_falls_back_to_cheapest() {
        let p = policy();
        assert_eq!(p.select(&[1.0, 2.0, 3.0], 0.1), 1);
    }

    #[test]
    fn tie_breaks_to_cheaper() {
        let p = BudgetPolicy::from_costs(vec![10.0, 1.0]);
        assert_eq!(p.select(&[2.0, 2.0], 20.0), 1);
    }

    #[test]
    fn drained_model_never_selected() {
        let mut p = policy();
        p.available[0] = false;
        assert_eq!(p.select(&[9.0, 1.0, 2.0], 100.0), 2);
    }

    #[test]
    fn all_models_drained_degrades_without_panicking() {
        // regression: cheapest() used to .expect() on the request path, so
        // a fully drained registry unwound the serving thread
        let mut p = policy();
        for a in p.available.iter_mut() {
            *a = false;
        }
        let pick = p.select(&[1.0, 2.0, 3.0], 100.0);
        assert_eq!(pick, 1, "degrades to the globally cheapest model");
        assert_eq!(p.cheapest(), 1);
    }

    #[test]
    fn zero_cost_models_get_distinct_sweep_levels() {
        // regression: c * 0.999 == c at c == 0.0, so a free tier was never
        // excluded by its "just below" level
        let p = BudgetPolicy::from_costs(vec![0.0, 1.0]);
        let sweep = p.budget_sweep();
        assert!(
            sweep.iter().any(|&b| b < 0.0),
            "no level excludes the free tier: {sweep:?}"
        );
        assert!(sweep.iter().any(|&b| b >= 0.0 && b < 1.0));
        for w in sweep.windows(2) {
            assert!(w[0] <= w[1]);
        }

        // an all-free registry still produces a non-collapsed sweep
        let free = BudgetPolicy::from_costs(vec![0.0, 0.0]);
        let sweep = free.budget_sweep();
        let mut distinct = sweep.clone();
        distinct.dedup();
        assert!(distinct.len() >= 3, "collapsed sweep: {sweep:?}");
        assert!(sweep.last().unwrap() > &0.0);
    }

    #[test]
    fn sweep_covers_all_tiers() {
        let p = policy();
        let sweep = p.budget_sweep();
        // every model becomes affordable at some sweep level
        for (m, &c) in p.costs().iter().enumerate() {
            assert!(sweep.iter().any(|&b| b >= c), "model {m} never affordable");
        }
        // the lowest level excludes everything but the fallback
        assert!(sweep[0] < p.costs().iter().cloned().fold(f64::MAX, f64::min));
        // sorted
        for w in sweep.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn budget_monotonicity() {
        // larger budgets never select a *lower-scoring* model
        prop::check("budget monotone", 200, |rng| {
            let n = 2 + rng.below(8);
            let costs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 10.0)).collect();
            let scores: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 1.0)).collect();
            let p = BudgetPolicy::from_costs(costs);
            let b1 = rng.range_f64(0.0, 12.0);
            let b2 = b1 + rng.range_f64(0.0, 5.0);
            let s1 = scores[p.select(&scores, b1)];
            let s2 = scores[p.select(&scores, b2)];
            // fallback cases can violate score order only when b1 affords nothing
            let affordable1 = p.costs().iter().any(|&c| c <= b1);
            if affordable1 {
                prop::assert_prop(s2 >= s1 - 1e-12, "score decreased with budget")
            } else {
                Ok(())
            }
        });
    }
}
