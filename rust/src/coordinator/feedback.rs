//! Feedback pipeline: the paper's workflow step 5.
//!
//! After serving a response, Eagle may pick a *second* model and ask the
//! user to compare the two responses; the resulting pairwise preference is
//! the only supervision the router ever receives. This module implements:
//!
//! - the comparison-partner sampling policy (uncertainty-weighted: prefer
//!   the model whose rating is closest to the served one — maximal ELO
//!   information per comparison),
//! - a generic bounded ingestion queue ([`Queue`]) decoupling the serving
//!   path from router updates (requests never block on feedback
//!   processing); the sharded ingest pipeline
//!   ([`super::ingest`]) runs one per shard lane plus one for the raw
//!   feedback stream.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::elo::{Comparison, Outcome};
use crate::util::Rng;

use super::router::Observation;

/// Chooses which second model (if any) to ask the user to compare against.
#[derive(Debug, Clone)]
pub struct ComparisonSampler {
    /// Probability of requesting a comparison at all (paper: "optional").
    pub sample_rate: f64,
    /// Softmax temperature over negative rating distance.
    pub temperature: f64,
}

impl Default for ComparisonSampler {
    fn default() -> Self {
        ComparisonSampler { sample_rate: 0.3, temperature: 50.0 }
    }
}

impl ComparisonSampler {
    /// Pick a comparison partner for `served` given current ratings, or
    /// None if this request is not sampled for feedback.
    pub fn pick_partner(
        &self,
        rng: &mut Rng,
        served: usize,
        ratings: &[f64],
    ) -> Option<usize> {
        if ratings.len() < 2 || !rng.chance(self.sample_rate) {
            return None;
        }
        // softmax over -|rating gap| / T : close-rated models carry the most
        // information per comparison (E near 0.5 maximizes K*(S-E) variance)
        let mut weights = Vec::with_capacity(ratings.len());
        let mut total = 0.0f64;
        for (m, &r) in ratings.iter().enumerate() {
            if m == served {
                weights.push(0.0);
                continue;
            }
            let w = (-(r - ratings[served]).abs() / self.temperature).exp();
            weights.push(w);
            total += w;
        }
        if total <= 0.0 {
            return None;
        }
        let mut draw = rng.f64() * total;
        for (m, w) in weights.iter().enumerate() {
            draw -= w;
            if draw <= 0.0 && *w > 0.0 {
                return Some(m);
            }
        }
        weights.iter().rposition(|&w| w > 0.0)
    }
}

/// A raw (not yet embedded) user verdict on (model_a, model_b) for a
/// prompt *text*. The request handler enqueues these; embedding happens on
/// the ingest pipeline's applier side, batched through the same PJRT
/// bucket path the route slabs use (see [`super::ingest`]).
#[derive(Debug, Clone)]
pub struct RawVerdict {
    pub text: String,
    pub model_a: usize,
    pub model_b: usize,
    /// 1.0 a wins, 0.0 b wins, 0.5 draw.
    pub score_a: f64,
}

/// A pending user verdict on (model_a, model_b) for a prompt embedding.
#[derive(Debug, Clone)]
pub struct Verdict {
    pub embedding: Vec<f32>,
    pub model_a: usize,
    pub model_b: usize,
    /// 1.0 a wins, 0.0 b wins, 0.5 draw.
    pub score_a: f64,
}

impl Verdict {
    pub fn to_observation(&self) -> Option<Observation> {
        Outcome::decode(self.score_a).map(|outcome| {
            Observation::single(
                self.embedding.clone(),
                Comparison { a: self.model_a, b: self.model_b, outcome },
            )
        })
    }

    /// Consuming conversion: moves the embedding instead of cloning it
    /// (the ingest hot path converts every record exactly once).
    pub fn into_observation(self) -> Option<Observation> {
        Outcome::decode(self.score_a).map(|outcome| {
            Observation::single(
                self.embedding,
                Comparison { a: self.model_a, b: self.model_b, outcome },
            )
        })
    }
}

/// Generic bounded MPSC queue with blocking batched pop.
///
/// Data pushes go through [`Queue::push_bounded`], which rejects (drops
/// the *incoming* item) when the queue is at capacity so the caller can
/// count the drop — backpressure lands on the producer, never on a
/// blocked consumer. Producers that must not lose an item (it was already
/// acknowledged upstream) use [`Queue::push_wait`], which blocks for
/// capacity up to a bound. Control messages (flush barriers) use
/// [`Queue::push`], which ignores the capacity so a full queue can never
/// wedge a flush.
pub struct Queue<T> {
    inner: Mutex<QueueInner<T>>,
    cond: Condvar,
    /// Signalled whenever items leave the queue, for `push_wait` blockers.
    space: Condvar,
    capacity: usize,
}

/// The server's feedback ingestion queue (kept as an alias for the
/// historical name).
pub type FeedbackQueue = Queue<Verdict>;

struct QueueInner<T> {
    items: VecDeque<T>,
    dropped: u64,
    closed: bool,
}

impl<T> Queue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Queue {
            inner: Mutex::new(QueueInner {
                items: VecDeque::new(),
                dropped: 0,
                closed: false,
            }),
            cond: Condvar::new(),
            space: Condvar::new(),
            capacity,
        }
    }

    /// Push unconditionally (control messages / trusted producers).
    /// Returns false if the queue is closed.
    pub fn push(&self, v: T) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return false;
        }
        inner.items.push_back(v);
        drop(inner);
        self.cond.notify_one();
        true
    }

    /// Push unless the queue is full or closed; a rejected item is handed
    /// back so the caller can count it as dropped.
    pub fn push_bounded(&self, v: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.items.len() >= self.capacity {
            inner.dropped += u64::from(!inner.closed);
            return Err(v);
        }
        inner.items.push_back(v);
        drop(inner);
        self.cond.notify_one();
        Ok(())
    }

    /// Bounded blocking push: waits up to `timeout` for capacity instead
    /// of rejecting. Returns the item back on timeout or close so the
    /// caller can decide what to do with it — used by producers applying
    /// backpressure for records a client has already been acknowledged
    /// for, where a silent drop would break the ack contract.
    pub fn push_wait(&self, v: T, timeout: std::time::Duration) -> Result<(), T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(v);
            }
            if inner.items.len() < self.capacity {
                inner.items.push_back(v);
                drop(inner);
                self.cond.notify_one();
                return Ok(());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(v);
            }
            let (guard, _) = self.space.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Block until the queue has spare capacity, the queue closes, or
    /// `timeout` elapses. Returns true when it is safe to proceed with a
    /// push (spare capacity, or closed — a push after close is a no-op),
    /// false only on timeout with the queue still full. For producers
    /// that stage items into batch messages and need to throttle *before*
    /// pushing rather than hand items back.
    pub fn wait_for_capacity(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed || inner.items.len() < self.capacity {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.space.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Blocking pop; None once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(v) = inner.items.pop_front() {
                drop(inner);
                self.space.notify_all();
                return Some(v);
            }
            if inner.closed {
                return None;
            }
            inner = self.cond.wait(inner).unwrap();
        }
    }

    /// Batched pop for the applier: blocks up to `timeout` for the first
    /// item, then greedily drains up to `max` items without blocking.
    ///
    /// Returns `None` once the queue is closed and drained; an empty vec
    /// means the timeout elapsed (the caller uses that beat to flush a
    /// stale snapshot epoch).
    pub fn pop_batch(&self, max: usize, timeout: std::time::Duration) -> Option<Vec<T>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.items.is_empty() {
                let take = inner.items.len().min(max.max(1));
                let batch: Vec<T> = inner.items.drain(..take).collect();
                drop(inner);
                self.space.notify_all();
                return Some(batch);
            }
            if inner.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Some(Vec::new());
            }
            let (guard, res) = self.cond.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
            if res.timed_out() && inner.items.is_empty() {
                return if inner.closed { None } else { Some(Vec::new()) };
            }
        }
    }

    /// Like [`Queue::pop_batch`], but once the first item is available,
    /// linger up to `linger` for batch-mates instead of draining
    /// immediately — the same drain-or-wait shape as the embed engine's
    /// batcher. Under a trickle this turns batch-of-1 pops into real
    /// batches; under load the batch hits `max` and returns at once, so
    /// the linger costs nothing at throughput.
    pub fn pop_batch_linger(
        &self,
        max: usize,
        timeout: std::time::Duration,
        linger: std::time::Duration,
    ) -> Option<Vec<T>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.items.is_empty() {
                let max = max.max(1);
                let linger_until = std::time::Instant::now() + linger;
                while inner.items.len() < max && !inner.closed {
                    let now = std::time::Instant::now();
                    if now >= linger_until {
                        break;
                    }
                    let (guard, _) = self.cond.wait_timeout(inner, linger_until - now).unwrap();
                    inner = guard;
                }
                let take = inner.items.len().min(max);
                let batch: Vec<T> = inner.items.drain(..take).collect();
                drop(inner);
                self.space.notify_all();
                return Some(batch);
            }
            if inner.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Some(Vec::new());
            }
            let (guard, res) = self.cond.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
            if res.timed_out() && inner.items.is_empty() {
                return if inner.closed { None } else { Some(Vec::new()) };
            }
        }
    }

    /// Non-blocking drain of everything queued.
    pub fn drain(&self) -> Vec<T> {
        let mut inner = self.inner.lock().unwrap();
        let all: Vec<T> = inner.items.drain(..).collect();
        drop(inner);
        self.space.notify_all();
        all
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Items rejected by [`Queue::push_bounded`] because the queue was at
    /// capacity.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Close the queue; blocked pops return None after drain, blocked
    /// capacity waiters get their item back.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
        self.space.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_respects_rate() {
        let s = ComparisonSampler { sample_rate: 0.0, temperature: 50.0 };
        let mut rng = Rng::new(1);
        assert_eq!(s.pick_partner(&mut rng, 0, &[1000.0, 1000.0]), None);

        let s = ComparisonSampler { sample_rate: 1.0, temperature: 50.0 };
        let hits = (0..100)
            .filter(|_| s.pick_partner(&mut rng, 0, &[1000.0, 1000.0]).is_some())
            .count();
        assert_eq!(hits, 100);
    }

    #[test]
    fn sampler_never_picks_served() {
        let s = ComparisonSampler { sample_rate: 1.0, temperature: 50.0 };
        let mut rng = Rng::new(2);
        let ratings = vec![1000.0, 1100.0, 900.0, 1050.0];
        for _ in 0..200 {
            let p = s.pick_partner(&mut rng, 1, &ratings).unwrap();
            assert_ne!(p, 1);
        }
    }

    #[test]
    fn sampler_prefers_close_ratings() {
        let s = ComparisonSampler { sample_rate: 1.0, temperature: 30.0 };
        let mut rng = Rng::new(3);
        // model 1 is 10 points away, model 2 is 400 points away
        let ratings = vec![1000.0, 1010.0, 1400.0];
        let close = (0..500)
            .filter(|_| s.pick_partner(&mut rng, 0, &ratings) == Some(1))
            .count();
        assert!(close > 400, "close picked {close}/500");
    }

    #[test]
    fn sampler_single_model_none() {
        let s = ComparisonSampler { sample_rate: 1.0, temperature: 50.0 };
        let mut rng = Rng::new(4);
        assert_eq!(s.pick_partner(&mut rng, 0, &[1000.0]), None);
    }

    #[test]
    fn verdict_decodes_outcomes() {
        let v = Verdict { embedding: vec![1.0], model_a: 0, model_b: 1, score_a: 1.0 };
        assert_eq!(v.to_observation().unwrap().comparisons[0].outcome, Outcome::WinA);
        let v = Verdict { score_a: 0.25, ..v };
        assert!(v.to_observation().is_none());
    }

    #[test]
    fn queue_fifo_and_drain() {
        let q = FeedbackQueue::new(10);
        for i in 0..3 {
            q.push(Verdict {
                embedding: vec![i as f32],
                model_a: 0,
                model_b: 1,
                score_a: 1.0,
            });
        }
        assert_eq!(q.len(), 3);
        let all = q.drain();
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].embedding, vec![0.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn queue_bounded_push_rejects_on_overflow() {
        let q = FeedbackQueue::new(2);
        let mut rejected = 0;
        for i in 0..5 {
            let v = Verdict {
                embedding: vec![i as f32],
                model_a: 0,
                model_b: 1,
                score_a: 0.0,
            };
            if let Err(back) = q.push_bounded(v) {
                // the rejected item is handed back intact
                assert_eq!(back.embedding, vec![i as f32]);
                rejected += 1;
            }
        }
        assert_eq!(rejected, 3);
        assert_eq!(q.dropped(), 3);
        // the oldest items survive (backpressure drops the incoming ones)
        let all = q.drain();
        assert_eq!(all[0].embedding, vec![0.0]);
        assert_eq!(all[1].embedding, vec![1.0]);
        // unconditional push ignores capacity (control messages)
        for i in 0..5 {
            assert!(q.push(Verdict {
                embedding: vec![i as f32],
                model_a: 0,
                model_b: 1,
                score_a: 0.0,
            }));
        }
        assert_eq!(q.len(), 5);
    }

    #[test]
    fn push_wait_blocks_for_capacity_then_succeeds() {
        use std::sync::Arc;
        use std::time::Duration;
        let q = Arc::new(FeedbackQueue::new(1));
        let v = |i: usize| Verdict {
            embedding: vec![i as f32],
            model_a: 0,
            model_b: 1,
            score_a: 1.0,
        };
        q.push(v(0));
        // full queue + nobody popping: push_wait times out and hands back
        let back = q.push_wait(v(1), Duration::from_millis(30));
        assert!(back.is_err());
        assert_eq!(back.err().unwrap().embedding, vec![1.0]);
        // a consumer frees space while the producer is blocked
        let popper = {
            let q = q.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                q.pop()
            })
        };
        assert!(q.push_wait(v(2), Duration::from_secs(5)).is_ok());
        assert_eq!(popper.join().unwrap().unwrap().embedding, vec![0.0]);
        // close unblocks a capacity waiter with the item handed back
        q.push(v(3));
        let waiter = {
            let q = q.clone();
            std::thread::spawn(move || q.push_wait(v(4), Duration::from_secs(5)))
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(waiter.join().unwrap().is_err());
    }

    #[test]
    fn verdict_into_observation_moves_embedding() {
        let v = Verdict { embedding: vec![1.0, 2.0], model_a: 0, model_b: 1, score_a: 0.0 };
        let obs = v.clone().into_observation().unwrap();
        assert_eq!(obs.embedding, vec![1.0, 2.0]);
        assert_eq!(obs.comparisons[0].outcome, Outcome::WinB);
        assert!(Verdict { score_a: 0.7, ..v }.into_observation().is_none());
    }

    #[test]
    fn queue_close_unblocks_pop() {
        use std::sync::Arc;
        let q = Arc::new(FeedbackQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
        assert!(!q.push(Verdict {
            embedding: vec![],
            model_a: 0,
            model_b: 1,
            score_a: 1.0
        }));
    }

    #[test]
    fn pop_batch_drains_up_to_max() {
        let q = FeedbackQueue::new(100);
        for i in 0..7 {
            q.push(Verdict { embedding: vec![i as f32], model_a: 0, model_b: 1, score_a: 1.0 });
        }
        let batch = q.pop_batch(5, std::time::Duration::from_millis(100)).unwrap();
        assert_eq!(batch.len(), 5);
        assert_eq!(batch[0].embedding, vec![0.0]);
        let rest = q.pop_batch(5, std::time::Duration::from_millis(100)).unwrap();
        assert_eq!(rest.len(), 2);
    }

    #[test]
    fn pop_batch_timeout_returns_empty() {
        let q = FeedbackQueue::new(4);
        let t0 = std::time::Instant::now();
        let batch = q.pop_batch(8, std::time::Duration::from_millis(30)).unwrap();
        assert!(batch.is_empty());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));
    }

    #[test]
    fn pop_batch_linger_collects_a_trickle_into_one_batch() {
        use std::sync::Arc;
        use std::time::Duration;
        let q = Arc::new(FeedbackQueue::new(100));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..5 {
                    q.push(Verdict {
                        embedding: vec![i as f32],
                        model_a: 0,
                        model_b: 1,
                        score_a: 1.0,
                    });
                    std::thread::sleep(Duration::from_millis(10));
                }
            })
        };
        // generous linger: the whole trickle lands in one batch
        let batch = q
            .pop_batch_linger(64, Duration::from_secs(5), Duration::from_millis(1500))
            .unwrap();
        producer.join().unwrap();
        assert_eq!(batch.len(), 5, "linger failed to collect the trickle");
        assert_eq!(batch[0].embedding, vec![0.0]);
    }

    #[test]
    fn pop_batch_linger_returns_immediately_at_max() {
        use std::time::{Duration, Instant};
        let q = FeedbackQueue::new(100);
        for i in 0..8 {
            q.push(Verdict { embedding: vec![i as f32], model_a: 0, model_b: 1, score_a: 0.5 });
        }
        let t0 = Instant::now();
        let batch = q
            .pop_batch_linger(4, Duration::from_secs(5), Duration::from_secs(5))
            .unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_secs(1), "lingered despite a full batch");
        // zero linger behaves like pop_batch: immediate drain of the rest
        let rest = q
            .pop_batch_linger(8, Duration::from_millis(100), Duration::ZERO)
            .unwrap();
        assert_eq!(rest.len(), 4);
        // timeout with an empty queue still returns the empty beat
        let beat = q
            .pop_batch_linger(8, Duration::from_millis(20), Duration::from_millis(5))
            .unwrap();
        assert!(beat.is_empty());
        // close during a linger drains what is there
        q.push(Verdict { embedding: vec![9.0], model_a: 0, model_b: 1, score_a: 0.5 });
        q.close();
        let last = q
            .pop_batch_linger(8, Duration::from_millis(100), Duration::from_millis(50))
            .unwrap();
        assert_eq!(last.len(), 1);
        assert!(q.pop_batch_linger(8, Duration::from_millis(10), Duration::ZERO).is_none());
    }

    #[test]
    fn pop_batch_none_after_close() {
        let q = FeedbackQueue::new(4);
        q.push(Verdict { embedding: vec![1.0], model_a: 0, model_b: 1, score_a: 0.5 });
        q.close();
        // drains what's left, then reports closed
        assert_eq!(q.pop_batch(8, std::time::Duration::from_millis(10)).unwrap().len(), 1);
        assert!(q.pop_batch(8, std::time::Duration::from_millis(10)).is_none());
    }

    #[test]
    fn queue_concurrent_producers() {
        use std::sync::Arc;
        let q = Arc::new(FeedbackQueue::new(1000));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..50 {
                        q.push(Verdict {
                            embedding: vec![t as f32, i as f32],
                            model_a: 0,
                            model_b: 1,
                            score_a: 0.5,
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), 200);
        assert_eq!(q.dropped(), 0);
    }
}
