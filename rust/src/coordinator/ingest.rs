//! Sharded feedback-ingest pipeline: multi-threaded, embed-on-applier
//! scale-out of the server's old single applier thread.
//!
//! ```text
//!  request handlers (N)        dispatcher thread          shard appliers (K)
//!  feedback: validate ──► raw queue ──► batch-embed (PJRT buckets)
//!                                   ──► GlobalLane.apply  (stream order)
//!                                   ──► shard_of(embedding) ──► lane queue s
//!                                                               └► ShardLane.apply
//!                                                                  + publish @ epoch
//! ```
//!
//! The request path enqueues **raw text** and returns immediately —
//! embedding happens on the ingest side, batched through the same PJRT
//! bucket path the route slabs use, so an embed failure becomes an ingest
//! metric ([`IngestMetrics::dropped_embed`]) instead of a request error.
//! The dispatcher owns the shared [`GlobalLane`] and folds every record
//! into the global ELO table **in arrival order** (the stream-order
//! invariant sharding must not break), assigns the record its global
//! arrival id, and hands it to its hash shard's queue. One applier thread
//! per [`ShardLane`] drains its queue independently, so store inserts,
//! segment merges, and snapshot publication scale with the shard count.
//!
//! Route scoring never touches any of this: readers keep loading
//! immutable snapshots from the [`ShardedHandle`]. Backpressure has two
//! regimes: *unacknowledged* records shed at the bounded raw-queue push
//! (counted per reason, the client sees an error reply), while records
//! already acknowledged with `FeedbackAccepted` are never dropped — a
//! full shard-lane queue stalls the dispatcher (bounded blocking,
//! counted as [`IngestMetrics::dropped_lane_backlog`] stall events)
//! until the applier drains. A [`IngestPipeline::flush`] barrier flows
//! through the same queues so "everything enqueued before the flush" is
//! applied and published when it returns.
//!
//! The dispatcher pop **lingers** ([`IngestOptions::linger`], the same
//! drain-or-wait shape as the embed engine's batcher): under a feedback
//! trickle, records bucket into real batches instead of batch-of-1
//! embeds; under load the batch fills to the dispatch ceiling and the
//! linger costs nothing.
//!
//! The dispatcher beat also drives optional background persistence
//! ([`crate::config::PersistParams`]) into the durable segment store
//! (`[persist] dir`, the one persistence shape since the legacy
//! whole-JSON sink was retired): each shard applier owns a
//! [`DurableLaneWriter`] and appends every record to its shard's delta
//! log as it applies it; the beat publishes a consistent cut (global
//! table + a flush barrier through every lane, which fsyncs the logs)
//! and then advances the manifest's global-ELO checkpoint — O(records
//! since the last beat), never O(corpus). Seals happen inline on the
//! applier when a lane's tail crosses the seal threshold. No writer lane
//! is ever locked for persistence, and route reads are untouched. (The
//! admin `snapshot` op can still write a one-shot JSON snapshot through
//! the reader handle; that path does not ride this pipeline.)

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::EpochParams;
use crate::embedding::EmbedHandle;
use crate::metrics::Counter;

use super::durable::{DurableLaneWriter, DurableStore};
use super::feedback::{Queue, RawVerdict, Verdict};
use super::router::Observation;
use super::sharded::{shard_of, GlobalLane, ShardLane, ShardedHandle, ShardedRouter};

/// Max messages the dispatcher folds per queue pop (also the embed batch
/// ceiling; the embed engine re-buckets internally).
const DISPATCH_BATCH: usize = 256;

/// Max lane messages a shard applier folds per queue pop.
const LANE_BATCH: usize = 64;

/// Per-reason drop counters plus queue/apply progress, shared between the
/// pipeline threads and the stats endpoint. All counters are atomics; the
/// ingest hot path never locks to record them.
#[derive(Debug)]
pub struct IngestMetrics {
    /// Records accepted onto the raw ingest queue.
    pub queued: Counter,
    /// Records folded into the shared global table (stream order).
    pub folded_global: Counter,
    /// Records applied to a shard lane (store insert done).
    pub applied: Counter,
    /// Rejected at the raw-queue push — the client saw an error reply.
    pub dropped_overflow: Counter,
    /// Dispatcher stall events on a full shard-lane queue. Historically
    /// this counted records silently dropped *after* the client got
    /// `FeedbackAccepted`; the dispatcher now applies bounded blocking
    /// backpressure instead (no post-ack loss), and the counter is kept
    /// as a stall diagnostic: a rising value means an applier is slow or
    /// wedged and the dispatcher is throttling on it.
    pub dropped_lane_backlog: Counter,
    /// Dropped on the ingest side because embedding failed.
    pub dropped_embed: Counter,
    /// Rejected at the request handler: unknown model name.
    pub dropped_unknown_model: Counter,
    /// Dropped because the verdict did not decode to a valid outcome.
    pub dropped_invalid: Counter,
    /// Dispatcher batches that carried at least one feedback record —
    /// `folded_global / dispatch_batches` is the mean embed-batch size
    /// the linger achieved.
    pub dispatch_batches: Counter,
    /// Durable checkpoint attempts / failures (the persistence beat and
    /// admin cuts); `persist_failures` also counts failed durable
    /// appends/syncs on the applier side.
    pub persists: Counter,
    pub persist_failures: Counter,
    shards: Vec<ShardCounters>,
}

/// Per-shard ingest progress.
#[derive(Debug, Default)]
pub struct ShardCounters {
    /// Records handed to this shard's queue.
    pub queued: Counter,
    /// Records this shard's applier folded into its lane.
    pub applied: Counter,
}

impl IngestMetrics {
    pub fn new(shard_count: usize) -> Self {
        IngestMetrics {
            queued: Counter::new(),
            folded_global: Counter::new(),
            applied: Counter::new(),
            dropped_overflow: Counter::new(),
            dropped_lane_backlog: Counter::new(),
            dropped_embed: Counter::new(),
            dropped_unknown_model: Counter::new(),
            dropped_invalid: Counter::new(),
            dispatch_batches: Counter::new(),
            persists: Counter::new(),
            persist_failures: Counter::new(),
            shards: (0..shard_count).map(|_| ShardCounters::default()).collect(),
        }
    }

    pub fn shard(&self, s: usize) -> &ShardCounters {
        &self.shards[s]
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total records dropped, across every reason. Lane-backlog stalls
    /// are not drops (the record is applied after the stall resolves), so
    /// they do not count here.
    pub fn dropped_total(&self) -> u64 {
        self.dropped_overflow.get()
            + self.dropped_embed.get()
            + self.dropped_unknown_model.get()
            + self.dropped_invalid.get()
    }

    /// One ingest section for the stats endpoint / logs.
    pub fn report(&self) -> String {
        let per_shard: Vec<String> = self
            .shards
            .iter()
            .enumerate()
            .map(|(s, c)| format!("s{s}:{}/{}", c.applied.get(), c.queued.get()))
            .collect();
        format!(
            "ingest: queued={} folded_global={} applied={} batches={} dropped(overflow={} \
             embed={} unknown_model={} invalid={}) lane_stalls={} persists={}/{} \
             shards(applied/queued)=[{}]",
            self.queued.get(),
            self.folded_global.get(),
            self.applied.get(),
            self.dispatch_batches.get(),
            self.dropped_overflow.get(),
            self.dropped_embed.get(),
            self.dropped_unknown_model.get(),
            self.dropped_invalid.get(),
            self.dropped_lane_backlog.get(),
            self.persists.get() - self.persist_failures.get(),
            self.persists.get(),
            per_shard.join(" "),
        )
    }
}

/// A countdown barrier that rides the queues: `flush` pushes one, the
/// dispatcher forwards a clone to every shard lane *behind* everything
/// already queued, and each lane publishes then counts down. FIFO order
/// is the correctness argument: when the barrier resolves, every record
/// enqueued before the flush is applied and visible to readers.
#[derive(Clone)]
pub struct FlushBarrier {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl FlushBarrier {
    fn new(count: usize) -> Self {
        FlushBarrier { inner: Arc::new((Mutex::new(count), Condvar::new())) }
    }

    fn count_down(&self) {
        let (lock, cond) = &*self.inner;
        let mut left = lock.lock().unwrap();
        *left = left.saturating_sub(1);
        if *left == 0 {
            cond.notify_all();
        }
    }

    fn wait(&self) {
        let (lock, cond) = &*self.inner;
        let mut left = lock.lock().unwrap();
        while *left > 0 {
            left = cond.wait(left).unwrap();
        }
    }
}

/// A message on the raw ingest queue (request handlers → dispatcher).
pub enum IngestMsg {
    /// Raw text to embed on the ingest side (the serving path).
    Raw(RawVerdict),
    /// Pre-embedded verdict (benches, replay drivers, back-compat).
    Embedded(Verdict),
    /// Flush barrier (see [`FlushBarrier`]).
    Flush(FlushBarrier),
    /// Run a persistence cut now (admin snapshot op), then resolve the
    /// barrier.
    PersistNow(FlushBarrier),
}

/// A message on one shard lane's queue (dispatcher → shard applier).
enum LaneMsg {
    /// A batch of (global arrival id, observation) for this shard, in
    /// stream order.
    Apply(Vec<(u32, Observation)>),
    Flush(FlushBarrier),
}

/// Background-persistence target for the dispatcher beat: the durable
/// segment store (appliers append delta-log frames inline; the beat
/// fsyncs + advances the global checkpoint). A zero `interval` disables
/// the periodic beat; the store still appends and seals inline, and
/// flushes on barriers/shutdown and the admin
/// [`IngestPipeline::persist_now`].
#[derive(Clone)]
pub struct PersistTarget {
    pub store: Arc<DurableStore>,
    pub interval: Duration,
}

/// Tuning for [`IngestPipeline::start`].
#[derive(Clone)]
pub struct IngestOptions {
    /// Capacity of the raw ingest queue (records).
    pub queue_capacity: usize,
    /// Capacity of each shard lane queue, in messages (each message
    /// carries up to one dispatch batch of records).
    pub lane_queue_capacity: usize,
    /// Epoch cadence; `publish_interval_ms` doubles as the beat that
    /// flushes stale epochs and drives persistence.
    pub epoch: EpochParams,
    /// How long the dispatcher lingers for batch-mates once the first
    /// record of a pop arrives (the embed-batching window for trickle
    /// feedback; zero drains immediately).
    pub linger: Duration,
    /// Periodic background persistence (None = admin-op only).
    pub persist: Option<PersistTarget>,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions {
            queue_capacity: 8192,
            lane_queue_capacity: 1024,
            epoch: EpochParams::default(),
            linger: Duration::from_millis(2),
            persist: None,
        }
    }
}

/// The running ingest pipeline: one dispatcher thread (embed + global
/// ELO + shard routing) plus one applier thread per shard lane. See the
/// module docs for the dataflow.
pub struct IngestPipeline {
    ingest: Arc<Queue<IngestMsg>>,
    metrics: Arc<IngestMetrics>,
    handle: ShardedHandle,
    shard_count: usize,
    has_persist: bool,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl IngestPipeline {
    /// Decompose `router` into its lanes and spawn the pipeline threads.
    /// `embed = None` builds an embedded-verdicts-only pipeline (raw text
    /// is counted as an embed drop) — benches and tests use this.
    pub fn start(
        router: ShardedRouter,
        embed: Option<EmbedHandle>,
        opts: IngestOptions,
    ) -> IngestPipeline {
        Self::start_with_metrics(router, embed, opts, None)
    }

    /// [`IngestPipeline::start`], but reusing an existing metrics handle.
    /// The promotion path ([`crate::coordinator::replica`]) spawns a new
    /// pipeline mid-flight and must keep the `Arc<IngestMetrics>` the
    /// server already hands out stable. `metrics` must have been built
    /// for the same shard count.
    pub fn start_with_metrics(
        router: ShardedRouter,
        embed: Option<EmbedHandle>,
        opts: IngestOptions,
        metrics: Option<Arc<IngestMetrics>>,
    ) -> IngestPipeline {
        let handle = router.handle();
        let shard_params = router.shard_params().clone();
        let next_gid = router.next_global_id();
        let (global, lanes) = router.into_lanes();
        let shard_count = lanes.len();
        let metrics = match metrics {
            Some(m) => {
                assert_eq!(m.shards.len(), shard_count, "metrics shard count mismatch");
                m
            }
            None => Arc::new(IngestMetrics::new(shard_count)),
        };
        let has_persist = opts.persist.is_some();
        let ingest: Arc<Queue<IngestMsg>> = Arc::new(Queue::new(opts.queue_capacity));
        let lane_queues: Vec<Arc<Queue<LaneMsg>>> =
            (0..shard_count).map(|_| Arc::new(Queue::new(opts.lane_queue_capacity))).collect();
        let beat = Duration::from_millis(opts.epoch.publish_interval_ms.max(1));

        // durable sink: every applier owns its shard's delta-log writer
        let mut durable_writers: Vec<Option<DurableLaneWriter>> = match &opts.persist {
            Some(PersistTarget { store, .. }) => (0..shard_count)
                .map(|s| Some(store.lane_writer(s).expect("durable store lane writer")))
                .collect(),
            None => (0..shard_count).map(|_| None).collect(),
        };

        let mut threads = Vec::with_capacity(shard_count + 1);
        for (s, lane) in lanes.into_iter().enumerate() {
            let q = lane_queues[s].clone();
            let m = metrics.clone();
            let durable = durable_writers[s].take();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("eagle-shard-applier-{s}"))
                    .spawn(move || applier_loop(lane, q, s, m, beat, durable))
                    .expect("spawn shard applier"),
            );
        }
        let dispatcher = Dispatcher {
            global,
            lanes: lane_queues,
            lane_capacity: opts.lane_queue_capacity,
            embed,
            metrics: metrics.clone(),
            hash_seed: shard_params.hash_seed,
            next_gid,
            linger: opts.linger,
            persist: opts.persist,
            last_persist: Instant::now(),
        };
        let q = ingest.clone();
        threads.push(
            std::thread::Builder::new()
                .name("eagle-ingest-dispatcher".into())
                .spawn(move || dispatcher.run(q, beat))
                .expect("spawn ingest dispatcher"),
        );

        IngestPipeline {
            ingest,
            metrics,
            handle,
            shard_count,
            has_persist,
            threads: Mutex::new(threads),
        }
    }

    /// Enqueue a raw-text verdict (the request path). Never blocks;
    /// returns false when the queue is full or the pipeline is shutting
    /// down (the drop is counted either way).
    pub fn push_raw(&self, v: RawVerdict) -> bool {
        match self.ingest.push_bounded(IngestMsg::Raw(v)) {
            Ok(()) => {
                self.metrics.queued.inc();
                true
            }
            Err(_) => {
                self.metrics.dropped_overflow.inc();
                false
            }
        }
    }

    /// Enqueue a pre-embedded verdict (benches / replay drivers).
    pub fn push_verdict(&self, v: Verdict) -> bool {
        match self.ingest.push_bounded(IngestMsg::Embedded(v)) {
            Ok(()) => {
                self.metrics.queued.inc();
                true
            }
            Err(_) => {
                self.metrics.dropped_overflow.inc();
                false
            }
        }
    }

    /// Like [`IngestPipeline::push_verdict`] but hands a rejected verdict
    /// back *without* counting a drop, so producers can treat
    /// backpressure as blocking and retry.
    pub fn try_push_verdict(&self, v: Verdict) -> Result<(), Verdict> {
        match self.ingest.push_bounded(IngestMsg::Embedded(v)) {
            Ok(()) => {
                self.metrics.queued.inc();
                Ok(())
            }
            Err(IngestMsg::Embedded(v)) => Err(v),
            Err(_) => unreachable!("push_bounded returns the message it was given"),
        }
    }

    /// Barrier: apply and publish everything enqueued before this call
    /// (every shard lane and the shared global table); with a durable
    /// sink the lanes also fsync their delta logs. Returns false if the
    /// pipeline is already shut down.
    pub fn flush(&self) -> bool {
        let barrier = FlushBarrier::new(self.shard_count);
        if !self.ingest.push(IngestMsg::Flush(barrier.clone())) {
            return false;
        }
        barrier.wait();
        true
    }

    /// Run a full persistence cut now, regardless of the beat interval:
    /// flush + publish everything accepted so far, fsync the delta logs,
    /// and advance the durable checkpoint (or write the JSON snapshot).
    /// The admin `snapshot` op rides this. Returns false if the pipeline
    /// is shut down or has no persist target.
    pub fn persist_now(&self) -> bool {
        if !self.has_persist {
            return false;
        }
        let barrier = FlushBarrier::new(1);
        if !self.ingest.push(IngestMsg::PersistNow(barrier.clone())) {
            return false;
        }
        barrier.wait();
        true
    }

    /// The lock-free reader handle this pipeline publishes through.
    pub fn handle(&self) -> &ShardedHandle {
        &self.handle
    }

    pub fn metrics(&self) -> &Arc<IngestMetrics> {
        &self.metrics
    }

    pub fn shard_count(&self) -> usize {
        self.shard_count
    }

    /// Records sitting in the raw queue right now (diagnostics).
    pub fn queue_len(&self) -> usize {
        self.ingest.len()
    }

    /// Close the intake, drain everything already queued (publishing the
    /// tails), and join all pipeline threads. Idempotent.
    pub fn shutdown(&self) {
        self.ingest.close();
        let threads: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
    }
}

impl Drop for IngestPipeline {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Dispatcher state (owned by the dispatcher thread).
struct Dispatcher {
    global: GlobalLane,
    lanes: Vec<Arc<Queue<LaneMsg>>>,
    lane_capacity: usize,
    embed: Option<EmbedHandle>,
    metrics: Arc<IngestMetrics>,
    hash_seed: u64,
    next_gid: u32,
    linger: Duration,
    persist: Option<PersistTarget>,
    last_persist: Instant,
}

impl Dispatcher {
    fn run(mut self, queue: Arc<Queue<IngestMsg>>, beat: Duration) {
        loop {
            match queue.pop_batch_linger(DISPATCH_BATCH, beat, self.linger) {
                None => {
                    // closed and drained: flush the global tail, then let
                    // the lanes drain theirs (syncing their delta logs);
                    // a durable sink gets one final checkpoint so a clean
                    // shutdown recovers without any log replay
                    if self.global.unpublished() > 0 {
                        self.global.publish();
                    }
                    if let Some(PersistTarget { store, .. }) = self.persist.clone() {
                        let folded_gid = self.next_gid;
                        let state = self.global.elo().export_state();
                        let barrier = FlushBarrier::new(self.lanes.len());
                        for q in &self.lanes {
                            q.push(LaneMsg::Flush(barrier.clone()));
                            q.close();
                        }
                        barrier.wait();
                        self.metrics.persists.inc();
                        if store.checkpoint_global(folded_gid, state).is_err() {
                            self.metrics.persist_failures.inc();
                        }
                    } else {
                        for q in &self.lanes {
                            q.close();
                        }
                    }
                    return;
                }
                Some(batch) if batch.is_empty() => {
                    // timeout beat: publish a stale global epoch, persist
                    self.global.maybe_publish();
                    self.maybe_persist();
                }
                Some(batch) => {
                    self.dispatch(batch);
                    self.global.maybe_publish();
                    self.maybe_persist();
                }
            }
        }
    }

    /// Fold one popped batch: embed the raw records in one slab, then
    /// walk the batch in arrival order applying the global table and
    /// routing each observation to its shard queue.
    fn dispatch(&mut self, batch: Vec<IngestMsg>) {
        // one embed round trip for every raw record in the batch — the
        // same amortization the batched route path gets
        let texts: Vec<&str> = batch
            .iter()
            .filter_map(|m| match m {
                IngestMsg::Raw(r) => Some(r.text.as_str()),
                _ => None,
            })
            .collect();
        // per-text results: a single bad embed drops exactly that record,
        // never the rest of the (already acknowledged) slab
        let mut embeddings = match (&self.embed, texts.is_empty()) {
            (Some(handle), false) => handle.embed_each(&texts).into_iter(),
            (None, false) => {
                self.metrics.dropped_embed.add(texts.len() as u64);
                Vec::new().into_iter()
            }
            _ => Vec::new().into_iter(),
        };

        if batch
            .iter()
            .any(|m| matches!(m, IngestMsg::Raw(_) | IngestMsg::Embedded(_)))
        {
            self.metrics.dispatch_batches.inc();
        }

        let mut staged: Vec<Vec<(u32, Observation)>> =
            (0..self.lanes.len()).map(|_| Vec::new()).collect();
        for msg in batch {
            let obs = match msg {
                IngestMsg::Raw(r) => match embeddings.next() {
                    Some(Ok(embedding)) => Verdict {
                        embedding,
                        model_a: r.model_a,
                        model_b: r.model_b,
                        score_a: r.score_a,
                    }
                    .into_observation(),
                    Some(Err(_)) => {
                        self.metrics.dropped_embed.inc();
                        continue;
                    }
                    // no embed handle configured; already counted above
                    None => continue,
                },
                IngestMsg::Embedded(v) => v.into_observation(),
                IngestMsg::Flush(barrier) => {
                    // barrier: everything staged so far must reach the
                    // lanes first, then every lane publishes + acks
                    self.flush_staged(&mut staged);
                    self.global.publish();
                    for q in &self.lanes {
                        q.push(LaneMsg::Flush(barrier.clone()));
                    }
                    continue;
                }
                IngestMsg::PersistNow(barrier) => {
                    // admin cut: everything staged reaches the lanes,
                    // then a full persistence cut runs (blocking this
                    // dispatcher on the lanes' sync barrier)
                    self.flush_staged(&mut staged);
                    self.persist_cut();
                    barrier.count_down();
                    continue;
                }
            };
            let Some(obs) = obs else {
                self.metrics.dropped_invalid.inc();
                continue;
            };
            let shard = shard_of(&obs.embedding, self.hash_seed, self.lanes.len());
            // the dispatcher is the only producer on lane queues, so this
            // capacity check cannot race, and it happens *before* the
            // global apply to keep the global table and the stores
            // consistent. These records were already acknowledged to the
            // client (`FeedbackAccepted`), so a backed-up lane gets
            // bounded blocking backpressure — stall the dispatcher until
            // the applier drains — never a silent drop; unacknowledged
            // load sheds upstream at the raw-queue push instead.
            // `dropped_lane_backlog` now counts stall events (diagnostic
            // for a wedged or slow applier), not lost records.
            if self.lanes[shard].len() >= self.lane_capacity {
                // hand over everything staged so far so the backed-up
                // applier has work it can drain while we wait
                self.flush_staged(&mut staged);
                self.metrics.dropped_lane_backlog.inc();
                while !self.lanes[shard].wait_for_capacity(Duration::from_millis(100)) {}
            }
            let gid = self.next_gid;
            self.next_gid += 1;
            self.global.apply(&obs.comparisons);
            self.metrics.folded_global.inc();
            self.metrics.shard(shard).queued.inc();
            staged[shard].push((gid, obs));
        }
        self.flush_staged(&mut staged);
    }

    /// Hand each shard its staged slab as one queue message (one lock
    /// acquisition per shard per batch).
    fn flush_staged(&self, staged: &mut [Vec<(u32, Observation)>]) {
        for (s, items) in staged.iter_mut().enumerate() {
            if !items.is_empty() {
                self.lanes[s].push(LaneMsg::Apply(std::mem::take(items)));
            }
        }
    }

    fn maybe_persist(&mut self) {
        let Some(target) = &self.persist else { return };
        if target.interval.is_zero() || self.last_persist.elapsed() < target.interval {
            return;
        }
        self.last_persist = Instant::now();
        self.persist_cut();
    }

    /// One durable persistence cut: publish, barrier every lane (which
    /// fsyncs the delta logs), advance the global checkpoint.
    fn persist_cut(&mut self) {
        let Some(target) = self.persist.clone() else { return };
        // capture the fold point *before* the barrier: every record
        // folded so far was staged to its lane already, so the FIFO
        // barrier proves all of them are applied AND fsynced before the
        // checkpoint claims them
        let folded_gid = self.next_gid;
        let state = self.global.elo().export_state();
        self.global.publish();
        let barrier = FlushBarrier::new(self.lanes.len());
        for q in &self.lanes {
            q.push(LaneMsg::Flush(barrier.clone()));
        }
        barrier.wait();
        self.metrics.persists.inc();
        if target.store.checkpoint_global(folded_gid, state).is_err() {
            self.metrics.persist_failures.inc();
        }
    }
}

/// One shard's applier: drains its queue into the lane, publishing at
/// the epoch cadence (plus the timeout beat for stale epochs). With a
/// durable sink it also owns the shard's delta-log writer: every record
/// is appended (and the lane sealed past the threshold) as it is
/// applied, and flush barriers fsync the log before acking — durability
/// work stays on the ingest side, never on the route path.
fn applier_loop(
    mut lane: ShardLane,
    queue: Arc<Queue<LaneMsg>>,
    shard: usize,
    metrics: Arc<IngestMetrics>,
    beat: Duration,
    mut durable: Option<DurableLaneWriter>,
) {
    loop {
        match queue.pop_batch(LANE_BATCH, beat) {
            None => {
                if lane.unpublished() > 0 {
                    lane.publish();
                }
                if let Some(d) = durable.as_mut() {
                    if d.sync().is_err() {
                        metrics.persist_failures.inc();
                    }
                }
                return;
            }
            Some(msgs) if msgs.is_empty() => {
                lane.maybe_publish();
            }
            Some(msgs) => {
                for msg in msgs {
                    match msg {
                        LaneMsg::Apply(items) => {
                            let n = items.len() as u64;
                            for (gid, obs) in items {
                                if let Some(d) = durable.as_mut() {
                                    if d.append(gid, &obs).is_err() {
                                        metrics.persist_failures.inc();
                                    }
                                }
                                lane.apply(gid, obs);
                            }
                            metrics.shard(shard).applied.add(n);
                            metrics.applied.add(n);
                            lane.maybe_publish();
                        }
                        LaneMsg::Flush(barrier) => {
                            lane.publish();
                            if let Some(d) = durable.as_mut() {
                                if d.sync().is_err() {
                                    metrics.persist_failures.inc();
                                }
                            }
                            barrier.count_down();
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EagleParams, ShardParams};
    use crate::coordinator::router::EagleRouter;
    use crate::util::{l2_normalize, Rng};
    use crate::vectordb::flat::FlatStore;

    const DIM: usize = 16;
    const N_MODELS: usize = 5;

    fn unit(rng: &mut Rng) -> Vec<f32> {
        let mut v: Vec<f32> = (0..DIM).map(|_| rng.normal() as f32).collect();
        l2_normalize(&mut v);
        v
    }

    fn rand_verdict(rng: &mut Rng) -> Verdict {
        let a = rng.below(N_MODELS);
        let mut b = rng.below(N_MODELS - 1);
        if b >= a {
            b += 1;
        }
        let score_a = [0.0, 0.5, 1.0][rng.below(3)];
        Verdict { embedding: unit(rng), model_a: a, model_b: b, score_a }
    }

    fn start_pipeline(k: usize, publish_every: usize) -> IngestPipeline {
        let router = ShardedRouter::new(
            EagleParams::default(),
            N_MODELS,
            DIM,
            EpochParams { publish_every, publish_interval_ms: 5 },
            ShardParams { count: k, hash_seed: 0xEA61E },
        );
        IngestPipeline::start(
            router,
            None,
            IngestOptions {
                epoch: EpochParams { publish_every, publish_interval_ms: 5 },
                ..Default::default()
            },
        )
    }

    #[test]
    fn pipeline_matches_reference_replay_at_k3() {
        let mut rng = Rng::new(41);
        let pipeline = start_pipeline(3, 7);
        let mut reference = EagleRouter::new(EagleParams::default(), N_MODELS, FlatStore::new(DIM));
        let verdicts: Vec<Verdict> = (0..400).map(|_| rand_verdict(&mut rng)).collect();
        for v in &verdicts {
            reference.observe(v.clone().into_observation().unwrap());
            assert!(pipeline.push_verdict(v.clone()));
        }
        assert!(pipeline.flush());
        let m = pipeline.metrics();
        assert_eq!(m.queued.get(), 400);
        assert_eq!(m.folded_global.get(), 400);
        assert_eq!(m.applied.get(), 400);
        assert_eq!(m.dropped_total(), 0);
        let per_shard: u64 = (0..3).map(|s| m.shard(s).applied.get()).sum();
        assert_eq!(per_shard, 400);

        // flush made everything visible: scores == in-order replay
        let snap = pipeline.handle().load();
        assert_eq!(snap.store_len(), 400);
        assert_eq!(snap.history_len(), 400);
        assert_eq!(snap.global_ratings(), &reference.global().ratings()[..]);
        for _ in 0..4 {
            let q = unit(&mut rng);
            assert_eq!(snap.scores(&q), reference.combined_scores(&q));
        }
        pipeline.shutdown();
    }

    #[test]
    fn concurrent_producers_preserve_global_history_count() {
        let pipeline = Arc::new(start_pipeline(4, 16));
        let producers: Vec<_> = (0..4)
            .map(|t| {
                let p = pipeline.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    let mut accepted = 0u64;
                    for _ in 0..200 {
                        if p.push_verdict(rand_verdict(&mut rng)) {
                            accepted += 1;
                        }
                    }
                    accepted
                })
            })
            .collect();
        let accepted: u64 = producers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(pipeline.flush());
        // queues are far below capacity at this volume: nothing drops
        let m = pipeline.metrics();
        assert_eq!(accepted, 800);
        assert_eq!(m.dropped_total(), 0);
        assert_eq!(m.folded_global.get(), 800);
        assert_eq!(m.applied.get(), 800);
        let snap = pipeline.handle().load();
        assert_eq!(snap.history_len(), 800);
        assert_eq!(snap.store_len(), 800);
        pipeline.shutdown();
    }

    #[test]
    fn full_lane_backpressure_never_loses_acknowledged_records() {
        // regression: a full shard-lane queue used to drop records the
        // client had already been acknowledged for. With lane queues
        // squeezed to a single message, the dispatcher outruns the
        // appliers constantly; every accepted record must still land.
        let mut rng = Rng::new(48);
        let epoch = EpochParams { publish_every: 64, publish_interval_ms: 5 };
        let router = ShardedRouter::new(
            EagleParams::default(),
            N_MODELS,
            DIM,
            epoch.clone(),
            ShardParams { count: 2, hash_seed: 0xEA61E },
        );
        let pipeline = IngestPipeline::start(
            router,
            None,
            IngestOptions {
                queue_capacity: 8192,
                lane_queue_capacity: 1,
                epoch,
                linger: Duration::ZERO,
                persist: None,
            },
        );
        const RECORDS: u64 = 2000;
        let mut accepted = 0u64;
        for _ in 0..RECORDS {
            if pipeline.push_verdict(rand_verdict(&mut rng)) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, RECORDS, "raw queue should not overflow here");
        assert!(pipeline.flush());
        let m = pipeline.metrics();
        // the ack contract: everything accepted is folded and applied
        assert_eq!(m.dropped_total(), 0);
        assert_eq!(m.folded_global.get(), RECORDS);
        assert_eq!(m.applied.get(), RECORDS);
        let snap = pipeline.handle().load();
        assert_eq!(snap.store_len(), RECORDS as usize);
        assert_eq!(snap.history_len(), RECORDS as usize);
        pipeline.shutdown();
    }

    #[test]
    fn invalid_scores_and_raw_without_embedder_are_counted_drops() {
        let mut rng = Rng::new(43);
        let pipeline = start_pipeline(2, 4);
        // invalid score: decodes to no outcome
        let mut bad = rand_verdict(&mut rng);
        bad.score_a = 0.25;
        assert!(pipeline.push_verdict(bad));
        // raw text without an embed handle: counted as embed drop
        assert!(pipeline.push_raw(RawVerdict {
            text: "no embedder available".into(),
            model_a: 0,
            model_b: 1,
            score_a: 1.0,
        }));
        let good = rand_verdict(&mut rng);
        assert!(pipeline.push_verdict(good));
        assert!(pipeline.flush());
        let m = pipeline.metrics();
        assert_eq!(m.dropped_invalid.get(), 1);
        assert_eq!(m.dropped_embed.get(), 1);
        assert_eq!(m.applied.get(), 1);
        assert_eq!(pipeline.handle().load().store_len(), 1);
        pipeline.shutdown();
    }

    #[test]
    fn shutdown_publishes_queued_tail() {
        let mut rng = Rng::new(44);
        // record cadence far above the stream: publication relies on the
        // beat and the shutdown flush
        let pipeline = start_pipeline(2, 1_000_000);
        for _ in 0..30 {
            assert!(pipeline.push_verdict(rand_verdict(&mut rng)));
        }
        pipeline.shutdown();
        let snap = pipeline.handle().load();
        assert_eq!(snap.store_len(), 30);
        assert_eq!(snap.history_len(), 30);
        // shutdown is idempotent, flush after shutdown reports failure
        pipeline.shutdown();
        assert!(!pipeline.flush());
        assert!(!pipeline.push_verdict(rand_verdict(&mut rng)));
    }

    #[test]
    fn durable_sink_appends_syncs_and_recovers_through_the_pipeline() {
        use crate::coordinator::durable::{DurableOptions, DurableStore, StoreMeta};
        let mut rng = Rng::new(46);
        let dir = std::env::temp_dir()
            .join(format!("eagle_ingest_durable_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let shards = ShardParams { count: 2, hash_seed: 0xEA61E };
        let store = DurableStore::create(
            &dir,
            StoreMeta {
                params: EagleParams::default(),
                n_models: N_MODELS,
                dim: DIM,
                shards: shards.clone(),
            },
            DurableOptions { seal_bytes: 2048, fsync: false, mmap: true },
        )
        .unwrap();
        let epoch = EpochParams { publish_every: 8, publish_interval_ms: 5 };
        let router =
            ShardedRouter::new(EagleParams::default(), N_MODELS, DIM, epoch.clone(), shards);
        let pipeline = IngestPipeline::start(
            router,
            None,
            IngestOptions {
                epoch,
                persist: Some(PersistTarget { store, interval: Duration::from_millis(5) }),
                ..Default::default()
            },
        );
        let mut reference = EagleRouter::new(EagleParams::default(), N_MODELS, FlatStore::new(DIM));
        for _ in 0..200 {
            let v = rand_verdict(&mut rng);
            reference.observe(v.clone().into_observation().unwrap());
            assert!(pipeline.push_verdict(v));
        }
        // the admin cut flushes, fsyncs, and advances the checkpoint
        assert!(pipeline.persist_now());
        assert!(pipeline.metrics().persists.get() >= 1);
        assert_eq!(pipeline.metrics().persist_failures.get(), 0);
        pipeline.shutdown();

        let (_store, recovery) =
            DurableStore::open(&dir, DurableOptions { seal_bytes: 2048, fsync: false, mmap: true })
                .unwrap();
        assert_eq!(recovery.total_records(), 200);
        assert_eq!(recovery.torn_bytes, 0);
        let mut recovered = recovery
            .into_router(EpochParams { publish_every: 8, publish_interval_ms: 5 })
            .unwrap();
        assert_eq!(recovered.store_len(), 200);
        assert_eq!(recovered.history_len(), 200);
        recovered.publish_all();
        let snap = recovered.handle().load();
        assert_eq!(snap.global_ratings(), &reference.global().ratings()[..]);
        for _ in 0..4 {
            let q = unit(&mut rng);
            assert_eq!(snap.scores(&q), reference.combined_scores(&q));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn linger_buckets_trickle_feedback_into_batches() {
        // a trickle of records spaced out in time must NOT dispatch as
        // batch-of-1: the linger window buckets them (mirroring the embed
        // engine's batcher). Generous timing so loaded CI cannot flake.
        let mut rng = Rng::new(47);
        let router = ShardedRouter::new(
            EagleParams::default(),
            N_MODELS,
            DIM,
            EpochParams { publish_every: 1024, publish_interval_ms: 2_000 },
            ShardParams { count: 1, hash_seed: 0xEA61E },
        );
        let pipeline = IngestPipeline::start(
            router,
            None,
            IngestOptions {
                epoch: EpochParams { publish_every: 1024, publish_interval_ms: 2_000 },
                linger: Duration::from_millis(400),
                ..Default::default()
            },
        );
        const RECORDS: u64 = 24;
        for _ in 0..RECORDS {
            assert!(pipeline.push_verdict(rand_verdict(&mut rng)));
            std::thread::sleep(Duration::from_millis(2));
        }
        pipeline.flush();
        let m = pipeline.metrics();
        assert_eq!(m.folded_global.get(), RECORDS);
        let batches = m.dispatch_batches.get();
        assert!(batches >= 1);
        assert!(
            m.folded_global.get() >= 2 * batches,
            "linger failed: {RECORDS} records dispatched in {batches} batches \
             (mean batch < 2)"
        );
        pipeline.shutdown();
    }

    #[test]
    fn flush_barrier_counts_down_exactly() {
        let b = FlushBarrier::new(2);
        let waiter = {
            let b = b.clone();
            std::thread::spawn(move || b.wait())
        };
        b.count_down();
        assert!(!waiter.is_finished());
        b.count_down();
        waiter.join().unwrap();
        // extra count_downs are harmless; zero-count barriers don't wait
        b.count_down();
        FlushBarrier::new(0).wait();
    }
}
