//! Snapshot / restore of Eagle router state.
//!
//! A snapshot holds the global ELO table plus every stored (embedding,
//! comparison) entry — everything needed to reconstruct the router after a
//! restart without replaying the feedback firehose. JSON on disk
//! (deterministic key order via our codec), versioned for forward
//! compatibility.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::EagleParams;
use crate::elo::{Comparison, Outcome};
use crate::json::{self, Value};
use crate::vectordb::flat::FlatStore;
use crate::vectordb::{ReadIndex, VectorIndex};

use super::router::{EagleRouter, Observation};
#[cfg(test)]
use super::Router as _;

const FORMAT_VERSION: f64 = 1.0;

/// Serialize routing state from parts over any *read-only* index: the
/// writer-side [`crate::vectordb::view::SegmentStore`], a flat store, or
/// a published snapshot's frozen view all pass through here. Restore
/// always rebuilds onto a flat store.
pub fn snapshot_parts<R: ReadIndex + ?Sized>(
    params: &EagleParams,
    n_models: usize,
    global_ratings: &[f64],
    history_len: usize,
    store: &R,
) -> String {
    let mut entries = Vec::with_capacity(store.len());
    for id in 0..store.len() as u32 {
        let fb = store.feedback(id);
        let cmps: Vec<Value> = fb
            .comparisons
            .iter()
            .map(|c| {
                json::obj(vec![
                    ("a", json::num(c.a as f64)),
                    ("b", json::num(c.b as f64)),
                    ("s", json::num(c.outcome.encode())),
                ])
            })
            .collect();
        entries.push(json::obj(vec![
            ("v", json::f32_arr(store.vector(id))),
            ("c", Value::Arr(cmps)),
        ]));
    }
    json::obj(vec![
        ("format_version", json::num(FORMAT_VERSION)),
        ("dim", json::num(store.dim() as f64)),
        ("p", json::num(params.p)),
        ("n_neighbors", json::num(params.n_neighbors as f64)),
        ("k_factor", json::num(params.k_factor)),
        ("n_models", json::num(n_models as f64)),
        (
            "global_ratings",
            Value::Arr(global_ratings.iter().map(|&r| json::num(r)).collect()),
        ),
        ("history_len", json::num(history_len as f64)),
        ("entries", Value::Arr(entries)),
    ])
    .to_json()
}

/// Serialize a router to a JSON string.
pub fn snapshot<I: VectorIndex + Send>(router: &EagleRouter<I>) -> String {
    snapshot_parts(
        router.params(),
        router.n_models(),
        &router.global().ratings(),
        router.feedback_len(),
        router.store(),
    )
}

/// Restore a router from a snapshot string.
///
/// The store is rebuilt from entries and the global table is restored
/// verbatim (not replayed — replay order is already folded into the
/// ratings).
pub fn restore(text: &str) -> Result<EagleRouter<FlatStore>> {
    let v = json::parse(text).map_err(|e| anyhow!("snapshot parse: {e}"))?;
    let version = v.get("format_version").as_f64().context("format_version")?;
    if version > FORMAT_VERSION {
        bail!("snapshot version {version} is newer than supported {FORMAT_VERSION}");
    }
    let params = EagleParams {
        p: v.get("p").as_f64().context("p")?,
        n_neighbors: v.get("n_neighbors").as_usize().context("n_neighbors")?,
        k_factor: v.get("k_factor").as_f64().context("k_factor")?,
    };
    let n_models = v.get("n_models").as_usize().context("n_models")?;
    let ratings: Vec<f64> = v
        .get("global_ratings")
        .as_arr()
        .context("global_ratings")?
        .iter()
        .map(|r| r.as_f64().context("rating"))
        .collect::<Result<_>>()?;
    if ratings.len() != n_models {
        bail!("rating count {} != n_models {}", ratings.len(), n_models);
    }

    let entries = v.get("entries").as_arr().context("entries")?;
    let dim = v
        .get("dim")
        .as_usize()
        .or_else(|| entries.first().and_then(|e| e.get("v").as_arr().map(|a| a.len())))
        .unwrap_or(1)
        .max(1);
    let mut store = FlatStore::with_capacity(dim, entries.len());
    let mut observations = Vec::with_capacity(entries.len());
    for e in entries {
        let vec: Vec<f32> = e
            .get("v")
            .as_arr()
            .context("entry.v")?
            .iter()
            .map(|x| x.as_f64().map(|f| f as f32).context("entry coord"))
            .collect::<Result<_>>()?;
        let mut comparisons = Vec::new();
        for c in e.get("c").as_arr().context("entry.c")? {
            let a = c.get("a").as_usize().context("entry.a")?;
            let b = c.get("b").as_usize().context("entry.b")?;
            if a >= n_models || b >= n_models {
                bail!("entry references model {} >= n_models {}", a.max(b), n_models);
            }
            let outcome = Outcome::decode(c.get("s").as_f64().context("entry.s")?)
                .context("entry outcome")?;
            comparisons.push(Comparison { a, b, outcome });
        }
        observations.push(Observation { embedding: vec, comparisons });
    }
    for obs in &observations {
        store.add(
            &obs.embedding,
            crate::vectordb::Feedback { comparisons: obs.comparisons.clone() },
        );
    }

    // Rebuild with restored ratings: create empty router, then overwrite
    // global by replay-free seeding. We reconstruct via fit on an empty
    // history and inject state through the public-but-low-level API.
    let history_len = v
        .get("history_len")
        .as_usize()
        .unwrap_or_else(|| observations.iter().map(|o| o.comparisons.len()).sum());
    let mut router = EagleRouter::new(params, n_models, store);
    router.restore_global(&ratings, history_len);
    Ok(router)
}

/// Write serialized snapshot text to disk atomically (tmp + rename).
/// Shared by the flat-router and sharded-router persistence paths.
pub fn write_atomic(path: &Path, text: &str) -> Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
    Ok(())
}

/// Write a snapshot to disk atomically (tmp + rename).
pub fn save_to<I: VectorIndex + Send>(router: &EagleRouter<I>, path: &Path) -> Result<()> {
    write_atomic(path, &snapshot(router))
}

/// Load a snapshot from disk.
pub fn load_from(path: &Path) -> Result<EagleRouter<FlatStore>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    restore(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{l2_normalize, Rng};

    fn build_router(seed: u64, n_obs: usize) -> EagleRouter<FlatStore> {
        let mut rng = Rng::new(seed);
        let params = EagleParams::default();
        let obs: Vec<Observation> = (0..n_obs)
            .map(|_| {
                let mut v: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
                l2_normalize(&mut v);
                let a = rng.below(4);
                let mut b = rng.below(3);
                if b >= a {
                    b += 1;
                }
                let outcome = match rng.below(3) {
                    0 => Outcome::WinA,
                    1 => Outcome::WinB,
                    _ => Outcome::Draw,
                };
                Observation::single(v, Comparison { a, b, outcome })
            })
            .collect();
        EagleRouter::fit(params, 4, FlatStore::new(8), &obs)
    }

    #[test]
    fn roundtrip_preserves_scores() {
        let router = build_router(1, 120);
        let text = snapshot(&router);
        let restored = restore(&text).unwrap();

        assert_eq!(restored.n_models(), router.n_models());
        assert_eq!(restored.feedback_len(), router.feedback_len());
        assert_eq!(restored.store().len(), router.store().len());

        let mut rng = Rng::new(99);
        for _ in 0..10 {
            let mut q: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
            l2_normalize(&mut q);
            let a = router.scores(&q);
            let b = restored.scores(&q);
            for m in 0..4 {
                assert!((a[m] - b[m]).abs() < 1e-6, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn roundtrip_empty_router() {
        let router = EagleRouter::new(EagleParams::default(), 3, FlatStore::new(4));
        let restored = restore(&snapshot(&router)).unwrap();
        assert_eq!(restored.store().len(), 0);
        assert_eq!(restored.scores(&[1.0, 0.0, 0.0, 0.0]), router.scores(&[1.0, 0.0, 0.0, 0.0]));
    }

    #[test]
    fn restored_router_accepts_updates() {
        let router = build_router(2, 50);
        let mut restored = restore(&snapshot(&router)).unwrap();
        restored.observe(Observation::single(
            vec![1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            Comparison { a: 0, b: 1, outcome: Outcome::WinA },
        ));
        assert_eq!(restored.feedback_len(), 51);
    }

    #[test]
    fn segment_store_router_snapshots_equivalently() {
        // the server's writer-side router persists through the same path
        use crate::vectordb::view::SegmentStore;
        let flat_router = build_router(7, 80);
        let seg_router = build_router(7, 80)
            .map_store(|flat| SegmentStore::from_flat(&flat));
        assert_eq!(snapshot(&flat_router), snapshot(&seg_router));
        let restored = restore(&snapshot(&seg_router)).unwrap();
        assert_eq!(restored.feedback_len(), 80);
        let q = vec![0.5f32; 8];
        assert_eq!(restored.scores(&q), flat_router.scores(&q));
    }

    #[test]
    fn rejects_newer_version() {
        let router = build_router(3, 5);
        let text = snapshot(&router).replace("\"format_version\":1", "\"format_version\":99");
        assert!(restore(&text).is_err());
    }

    #[test]
    fn rejects_corrupt_entries() {
        assert!(restore("{\"format_version\":1}").is_err());
        assert!(restore("not json").is_err());
        // out-of-range model index
        let bad = r#"{"format_version":1,"p":0.5,"n_neighbors":20,"k_factor":32,
            "n_models":2,"global_ratings":[1000,1000],"history_len":1,
            "entries":[{"v":[1.0],"c":[{"a":0,"b":5,"s":1}]}]}"#;
        assert!(restore(bad).is_err());
    }

    #[test]
    fn save_load_disk_roundtrip() {
        let router = build_router(4, 30);
        let dir = std::env::temp_dir()
            .join(format!("eagle_state_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        save_to(&router, &path).unwrap();
        let restored = load_from(&path).unwrap();
        assert_eq!(restored.feedback_len(), 30);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
