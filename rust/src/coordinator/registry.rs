//! Model registry: what the coordinator knows about each candidate LLM.
//!
//! Each entry carries a [`CostCurve`] — expected $ spend as a function of
//! estimated query volume — which the routing policies evaluate per query
//! (RouterBench frames routing as a cost/quality Pareto problem, so cost
//! is first-class here, not an afterthought). The serving layer
//! additionally tracks availability so an operator can drain a model from
//! rotation without redeploying.

use crate::routerbench::models::MODELS;

/// Expected $ cost of one query as a function of its estimated prompt
/// volume: `cost(t) = base + per_token * (mean_tokens + t)`.
///
/// `mean_tokens` is the model's historical mean prompt+completion volume,
/// so `cost(0)` is the flat expected per-query cost the budget policy has
/// always used; a longer-than-average prompt adds `per_token` per
/// estimated token on top. A flat curve (`per_token == 0`) prices every
/// query at `base` regardless of length.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostCurve {
    /// Fixed $ component per query.
    pub base: f64,
    /// $ per token of query volume.
    pub per_token: f64,
    /// Mean prompt+completion tokens this model spends per query.
    pub mean_tokens: f64,
}

impl CostCurve {
    /// A length-independent curve: every query costs exactly `cost`.
    pub fn flat(cost: f64) -> CostCurve {
        CostCurve { base: cost, per_token: 0.0, mean_tokens: 0.0 }
    }

    /// A metered curve from a $/1M-token price sheet entry.
    pub fn metered(price_per_mtok: f64, mean_tokens: f64) -> CostCurve {
        CostCurve { base: 0.0, per_token: price_per_mtok / 1e6, mean_tokens }
    }

    /// Expected $ cost of a query whose prompt adds `prompt_tokens`
    /// estimated tokens on top of the model's mean volume.
    pub fn cost(&self, prompt_tokens: f64) -> f64 {
        self.base + self.per_token * (self.mean_tokens + prompt_tokens)
    }

    /// The flat expected per-query cost (`cost(0)`), the value the
    /// original budget policy compared against.
    pub fn expected(&self) -> f64 {
        self.cost(0.0)
    }
}

/// One registered model.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    /// Expected $ cost of one query (== `cost_curve.expected()`; kept as
    /// a field because the flat budget policy and every report read it).
    pub expected_cost: f64,
    /// Cost as a function of estimated query volume.
    pub cost_curve: CostCurve,
    /// Whether the model may be routed to.
    pub available: bool,
}

impl ModelEntry {
    /// Entry with an explicit cost curve.
    pub fn new(name: impl Into<String>, cost_curve: CostCurve) -> ModelEntry {
        ModelEntry {
            name: name.into(),
            expected_cost: cost_curve.expected(),
            cost_curve,
            available: true,
        }
    }

    /// Entry with a length-independent cost (tests, ablations).
    pub fn flat(name: impl Into<String>, cost: f64) -> ModelEntry {
        ModelEntry::new(name, CostCurve::flat(cost))
    }
}

/// The model pool.
#[derive(Debug, Clone, Default)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

impl ModelRegistry {
    /// Registry over the RouterBench model pool, with metered cost curves
    /// from each model's price sheet (`expected_cost` is unchanged from
    /// the flat registry: the metered curve at mean volume).
    pub fn routerbench() -> Self {
        ModelRegistry {
            entries: MODELS
                .iter()
                .map(|m| {
                    ModelEntry::new(m.name, CostCurve::metered(m.price_per_mtok, m.mean_tokens))
                })
                .collect(),
        }
    }

    /// Custom registry.
    pub fn new(entries: Vec<ModelEntry>) -> Self {
        ModelRegistry { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entry(&self, i: usize) -> &ModelEntry {
        &self.entries[i]
    }

    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name)
    }

    /// Expected costs in model order.
    pub fn costs(&self) -> Vec<f64> {
        self.entries.iter().map(|e| e.expected_cost).collect()
    }

    /// Cost curves in model order.
    pub fn cost_curves(&self) -> Vec<CostCurve> {
        self.entries.iter().map(|e| e.cost_curve).collect()
    }

    /// Register or replace a model's cost curve (price-sheet update; the
    /// flat `expected_cost` follows the curve).
    pub fn set_cost_curve(&mut self, i: usize, curve: CostCurve) {
        self.entries[i].cost_curve = curve;
        self.entries[i].expected_cost = curve.expected();
    }

    /// Mark a model (un)available (operator drain).
    pub fn set_available(&mut self, i: usize, available: bool) {
        self.entries[i].available = available;
    }

    /// Cheapest available model (the universal fallback).
    pub fn cheapest_available(&self) -> Option<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.available)
            .min_by(|a, b| a.1.expected_cost.partial_cmp(&b.1.expected_cost).unwrap())
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routerbench_registry_matches_models() {
        let r = ModelRegistry::routerbench();
        assert_eq!(r.len(), MODELS.len());
        assert_eq!(r.index_of("gpt-4"), Some(0));
        assert!(r.entry(0).expected_cost > r.entry(r.index_of("mistral-7b-chat").unwrap()).expected_cost);
    }

    #[test]
    fn metered_curve_expected_matches_flat_cost() {
        // the curve at mean volume must reproduce the price-sheet expected
        // cost bit-identically — the flat budget policy depends on it
        let r = ModelRegistry::routerbench();
        for (e, m) in r.entries().iter().zip(MODELS) {
            assert_eq!(e.expected_cost, m.expected_cost(), "{}", e.name);
            assert_eq!(e.cost_curve.expected(), m.expected_cost(), "{}", e.name);
        }
    }

    #[test]
    fn cost_curves_are_monotone_in_prompt_volume() {
        let r = ModelRegistry::routerbench();
        for e in r.entries() {
            let short = e.cost_curve.cost(10.0);
            let long = e.cost_curve.cost(4000.0);
            assert!(long > short, "{}: {long} <= {short}", e.name);
        }
        let flat = CostCurve::flat(0.25);
        assert_eq!(flat.cost(10.0), flat.cost(4000.0));
        assert_eq!(flat.expected(), 0.25);
    }

    #[test]
    fn set_cost_curve_updates_expected_cost() {
        let mut r = ModelRegistry::routerbench();
        r.set_cost_curve(0, CostCurve::flat(1.5));
        assert_eq!(r.entry(0).expected_cost, 1.5);
        assert_eq!(r.costs()[0], 1.5);
    }

    #[test]
    fn cheapest_available_respects_drain() {
        let mut r = ModelRegistry::routerbench();
        let cheapest = r.cheapest_available().unwrap();
        r.set_available(cheapest, false);
        let second = r.cheapest_available().unwrap();
        assert_ne!(cheapest, second);
        assert!(r.entry(second).expected_cost >= r.entry(cheapest).expected_cost);
    }

    #[test]
    fn unknown_model_none() {
        let r = ModelRegistry::routerbench();
        assert_eq!(r.index_of("gpt-9"), None);
    }
}
