//! Model registry: what the coordinator knows about each candidate LLM.
//!
//! The router and the budget policy only need names and expected per-query
//! costs; the serving layer additionally tracks availability so an
//! operator can drain a model from rotation without redeploying.

use crate::routerbench::models::MODELS;

/// One registered model.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    /// Expected $ cost of one query (used by the budget policy).
    pub expected_cost: f64,
    /// Whether the model may be routed to.
    pub available: bool,
}

/// The model pool.
#[derive(Debug, Clone, Default)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

impl ModelRegistry {
    /// Registry over the RouterBench model pool.
    pub fn routerbench() -> Self {
        ModelRegistry {
            entries: MODELS
                .iter()
                .map(|m| ModelEntry {
                    name: m.name.to_string(),
                    expected_cost: m.expected_cost(),
                    available: true,
                })
                .collect(),
        }
    }

    /// Custom registry.
    pub fn new(entries: Vec<ModelEntry>) -> Self {
        ModelRegistry { entries }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entry(&self, i: usize) -> &ModelEntry {
        &self.entries[i]
    }

    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name)
    }

    /// Expected costs in model order.
    pub fn costs(&self) -> Vec<f64> {
        self.entries.iter().map(|e| e.expected_cost).collect()
    }

    /// Mark a model (un)available (operator drain).
    pub fn set_available(&mut self, i: usize, available: bool) {
        self.entries[i].available = available;
    }

    /// Cheapest available model (the universal fallback).
    pub fn cheapest_available(&self) -> Option<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.available)
            .min_by(|a, b| a.1.expected_cost.partial_cmp(&b.1.expected_cost).unwrap())
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routerbench_registry_matches_models() {
        let r = ModelRegistry::routerbench();
        assert_eq!(r.len(), MODELS.len());
        assert_eq!(r.index_of("gpt-4"), Some(0));
        assert!(r.entry(0).expected_cost > r.entry(r.index_of("mistral-7b-chat").unwrap()).expected_cost);
    }

    #[test]
    fn cheapest_available_respects_drain() {
        let mut r = ModelRegistry::routerbench();
        let cheapest = r.cheapest_available().unwrap();
        r.set_available(cheapest, false);
        let second = r.cheapest_available().unwrap();
        assert_ne!(cheapest, second);
        assert!(r.entry(second).expected_cost >= r.entry(cheapest).expected_cost);
    }

    #[test]
    fn unknown_model_none() {
        let r = ModelRegistry::routerbench();
        assert_eq!(r.index_of("gpt-9"), None);
    }
}
